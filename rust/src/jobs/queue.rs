//! The global job queue managed by the scheduler (paper §III-A): arrival
//! admission, status tracking, and the per-round waiting set.

use crate::jobs::job::{Job, JobId, JobStatus};
use std::collections::BTreeMap;

/// Owns all jobs through their lifecycle.
#[derive(Clone, Debug, Default)]
pub struct JobQueue {
    jobs: BTreeMap<JobId, Job>,
}

impl JobQueue {
    /// Empty queue.
    pub fn new() -> Self {
        JobQueue::default()
    }

    /// Admit a job (panics on duplicate ids — admission bug).
    pub fn admit(&mut self, job: Job) {
        assert!(
            !self.jobs.contains_key(&job.id),
            "duplicate job id {}",
            job.id
        );
        self.jobs.insert(job.id, job);
    }

    /// Look up a job.
    pub fn get(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// Look up a job mutably.
    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        self.jobs.get_mut(&id)
    }

    /// Number of jobs ever admitted.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether no job was admitted yet.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// All jobs in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    /// All jobs in id order, mutably.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Job> {
        self.jobs.values_mut()
    }

    /// Jobs that have arrived by `now` and are not complete — the waiting
    /// set `Q` a scheduler sees in a round.
    pub fn active_at(&self, now: f64) -> Vec<JobId> {
        self.jobs
            .values()
            .filter(|j| j.arrival <= now && j.status != JobStatus::Completed)
            .map(|j| j.id)
            .collect()
    }

    /// Whether every admitted job completed.
    pub fn all_complete(&self) -> bool {
        self.jobs
            .values()
            .all(|j| j.status == JobStatus::Completed)
    }

    /// The completed jobs, in id order.
    pub fn completed(&self) -> Vec<&Job> {
        self.jobs
            .values()
            .filter(|j| j.status == JobStatus::Completed)
            .collect()
    }

    /// Earliest arrival among jobs not yet arrived at `now` (next event).
    pub fn next_arrival_after(&self, now: f64) -> Option<f64> {
        self.jobs
            .values()
            .filter(|j| j.arrival > now)
            .map(|j| j.arrival)
            .fold(None, |acc, a| {
                Some(acc.map_or(a, |b: f64| b.min(a)))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::model::DlModel;

    fn mk(id: u64, arrival: f64) -> Job {
        Job::new(id, DlModel::Lstm, arrival, 1, 1, 10)
    }

    #[test]
    fn admission_and_lookup() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0));
        q.admit(mk(2, 5.0));
        assert_eq!(q.len(), 2);
        assert!(q.get(JobId(1)).is_some());
        assert!(q.get(JobId(3)).is_none());
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_admission_panics() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0));
        q.admit(mk(1, 1.0));
    }

    #[test]
    fn active_set_respects_arrival_and_completion() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0));
        q.admit(mk(2, 100.0));
        assert_eq!(q.active_at(50.0), vec![JobId(1)]);
        assert_eq!(q.active_at(100.0).len(), 2);
        q.get_mut(JobId(1)).unwrap().status = JobStatus::Completed;
        assert_eq!(q.active_at(100.0), vec![JobId(2)]);
        assert!(!q.all_complete());
        q.get_mut(JobId(2)).unwrap().status = JobStatus::Completed;
        assert!(q.all_complete());
    }

    #[test]
    fn next_arrival() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 10.0));
        q.admit(mk(2, 30.0));
        assert_eq!(q.next_arrival_after(0.0), Some(10.0));
        assert_eq!(q.next_arrival_after(10.0), Some(30.0));
        assert_eq!(q.next_arrival_after(30.0), None);
    }
}
