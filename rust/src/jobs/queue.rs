//! The global job queue managed by the scheduler (paper §III-A): arrival
//! admission, status tracking, and the per-round waiting set.
//!
//! # Delta-driven round pipeline
//!
//! Round-based schedulers are naturally incremental: between two rounds
//! only *arrivals*, *completions*, *preemptions*, and cluster *events*
//! change the problem. The queue therefore maintains two indexes next to
//! the job map:
//!
//! - `pending` — jobs admitted but not yet surfaced to a round, ordered
//!   by `(arrival, id)`;
//! - `active` — the persistent waiting set: surfaced and not completed.
//!
//! [`JobQueue::poll_round`] advances the arrival watermark, drains the
//! newly-arrived jobs from `pending` into `active`, and returns a
//! [`RoundDelta`] snapshot of everything that changed since the previous
//! poll. [`JobQueue::waiting`] and [`JobQueue::next_arrival_after`] then
//! answer from the indexes in O(active) / O(log n) instead of scanning
//! every job ever admitted — the difference between O(delta) and
//! O(universe) per round at the 1M-job streaming scale.
//!
//! # Index contract
//!
//! The indexes are authoritative only if lifecycle transitions go
//! through the queue API: [`JobQueue::admit`] to add,
//! [`JobQueue::complete`] to finish, [`JobQueue::note_preempted`] to
//! record a drain preemption. Mutating `status` directly via
//! [`JobQueue::get_mut`]/[`JobQueue::iter_mut`] leaves `progress` /
//! bookkeeping fields untouched by the indexes and desynchronizes
//! [`JobQueue::waiting`] and [`JobQueue::all_complete`] (the full-scan
//! [`JobQueue::active_at`] still sees it). The property suite pins
//! index-vs-rebuild agreement over the API
//! (`tests/prop_invariants.rs::prop_queue_indexes_agree_with_rebuild`).

use crate::jobs::job::{Job, JobId, JobStatus};
use std::collections::{BTreeMap, BTreeSet};

/// Admission failure: the id is already in the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmitError {
    /// The duplicate id.
    pub id: JobId,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "duplicate job id {}", self.id)
    }
}

impl std::error::Error for AdmitError {}

/// Everything that changed in the queue since the previous
/// [`JobQueue::poll_round`] — the incremental view of a round boundary
/// that delta-aware schedulers consume instead of re-deriving state from
/// the full job list.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoundDelta {
    /// Jobs whose arrival time was crossed by this poll, in
    /// `(arrival, id)` order.
    pub arrivals: Vec<JobId>,
    /// Jobs completed (via [`JobQueue::complete`]) since the last poll.
    pub completions: Vec<JobId>,
    /// Jobs drain-preempted (via [`JobQueue::note_preempted`]) since the
    /// last poll.
    pub preemptions: Vec<JobId>,
    /// Cluster timeline events applied at this round boundary. The queue
    /// cannot see the cluster; the sim engines stamp this after polling.
    pub events: u64,
}

impl RoundDelta {
    /// Whether nothing changed at this round boundary.
    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
            && self.completions.is_empty()
            && self.preemptions.is_empty()
            && self.events == 0
    }

    /// Fold `other` into `self` (idle-skipped round boundaries carry
    /// their delta forward into the next scheduled round).
    pub fn merge(&mut self, other: RoundDelta) {
        self.arrivals.extend(other.arrivals);
        self.completions.extend(other.completions);
        self.preemptions.extend(other.preemptions);
        self.events += other.events;
    }
}

/// Monotone total-order key for finite arrival times (IEEE-754 sign
/// flip), so `f64` arrivals can index a `BTreeSet`.
fn arrival_key(arrival: f64) -> u64 {
    let bits = arrival.to_bits();
    if bits >> 63 == 1 {
        !bits
    } else {
        bits | (1 << 63)
    }
}

/// Inverse of [`arrival_key`].
fn key_arrival(key: u64) -> f64 {
    if key >> 63 == 1 {
        f64::from_bits(key & !(1 << 63))
    } else {
        f64::from_bits(!key)
    }
}

/// Owns all jobs through their lifecycle.
#[derive(Clone, Debug)]
pub struct JobQueue {
    jobs: BTreeMap<JobId, Job>,
    /// Admitted, not yet surfaced by a poll: `(arrival_key, id)` order.
    pending: BTreeSet<(u64, JobId)>,
    /// Surfaced (arrival <= watermark) and not completed, in id order —
    /// iteration order matches [`JobQueue::active_at`]'s output.
    active: BTreeSet<JobId>,
    /// Jobs moved to `Completed` via [`JobQueue::complete`].
    completed_count: usize,
    /// Arrival watermark of the latest [`JobQueue::poll_round`].
    polled_to: f64,
    /// Completions buffered for the next [`RoundDelta`].
    delta_completions: Vec<JobId>,
    /// Preemptions buffered for the next [`RoundDelta`].
    delta_preemptions: Vec<JobId>,
}

impl Default for JobQueue {
    fn default() -> Self {
        JobQueue {
            jobs: BTreeMap::new(),
            pending: BTreeSet::new(),
            active: BTreeSet::new(),
            completed_count: 0,
            polled_to: f64::NEG_INFINITY,
            delta_completions: Vec::new(),
            delta_preemptions: Vec::new(),
        }
    }
}

impl JobQueue {
    /// Empty queue.
    pub fn new() -> Self {
        JobQueue::default()
    }

    /// Admit a job. Fails (leaving the queue untouched) if the id was
    /// already admitted. The job enters the arrival index and surfaces
    /// in the [`RoundDelta`] of the first poll at or past its arrival.
    pub fn admit(&mut self, job: Job) -> Result<(), AdmitError> {
        if self.jobs.contains_key(&job.id) {
            return Err(AdmitError { id: job.id });
        }
        if job.status == JobStatus::Completed {
            self.completed_count += 1;
        } else {
            self.pending.insert((arrival_key(job.arrival), job.id));
        }
        self.jobs.insert(job.id, job);
        Ok(())
    }

    /// Look up a job.
    pub fn get(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    /// Look up a job mutably. See the index contract in the module docs:
    /// lifecycle transitions must go through [`JobQueue::complete`], not
    /// a direct `status` write.
    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        self.jobs.get_mut(&id)
    }

    /// Number of jobs ever admitted.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Whether no job was admitted yet.
    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// All jobs in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    /// All jobs in id order, mutably.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut Job> {
        self.jobs.values_mut()
    }

    /// Advance the arrival watermark to `now` and return the
    /// [`RoundDelta`] accumulated since the previous poll: jobs whose
    /// arrival was crossed (drained from the pending index into the
    /// active set) plus buffered completions and preemptions. O(delta).
    pub fn poll_round(&mut self, now: f64) -> RoundDelta {
        if now > self.polled_to {
            self.polled_to = now;
        }
        let bound = arrival_key(self.polled_to);
        let mut arrivals = Vec::new();
        while let Some(&(key, id)) = self.pending.first() {
            if key > bound {
                break;
            }
            self.pending.pop_first();
            self.active.insert(id);
            arrivals.push(id);
        }
        RoundDelta {
            arrivals,
            completions: std::mem::take(&mut self.delta_completions),
            preemptions: std::mem::take(&mut self.delta_preemptions),
            events: 0,
        }
    }

    /// The persistent waiting set `Q` as of the last poll, in id order —
    /// the indexed O(active) counterpart of [`JobQueue::active_at`].
    pub fn waiting(&self) -> Vec<JobId> {
        self.active.iter().copied().collect()
    }

    /// Size of the persistent waiting set (O(1)).
    pub fn waiting_len(&self) -> usize {
        self.active.len()
    }

    /// The arrival watermark of the latest [`JobQueue::poll_round`]
    /// (`-inf` before the first poll).
    pub fn polled_to(&self) -> f64 {
        self.polled_to
    }

    /// Complete a job: stamps `Completed` + `finish_time`, removes it
    /// from the waiting/arrival indexes, and buffers it into the next
    /// [`RoundDelta`]. Returns `false` (and does nothing) if the id is
    /// unknown or already completed.
    pub fn complete(&mut self, id: JobId, finish_time: f64) -> bool {
        let Some(job) = self.jobs.get_mut(&id) else {
            return false;
        };
        if job.status == JobStatus::Completed {
            return false;
        }
        let arrival = job.arrival;
        job.status = JobStatus::Completed;
        job.finish_time = Some(finish_time);
        self.completed_count += 1;
        if !self.active.remove(&id) {
            self.pending.remove(&(arrival_key(arrival), id));
        }
        self.delta_completions.push(id);
        true
    }

    /// Record a drain preemption for the next [`RoundDelta`]. The job
    /// stays in the waiting set (the scheduler re-places it); this only
    /// feeds the delta consumers.
    pub fn note_preempted(&mut self, id: JobId) {
        if self.active.contains(&id) {
            self.delta_preemptions.push(id);
        }
    }

    /// Jobs that have arrived by `now` and are not complete — the waiting
    /// set `Q` a scheduler sees in a round. Full O(n) scan retained as
    /// the reference/compat path; round loops should poll and use
    /// [`JobQueue::waiting`].
    pub fn active_at(&self, now: f64) -> Vec<JobId> {
        self.jobs
            .values()
            .filter(|j| j.arrival <= now && j.status != JobStatus::Completed)
            .map(|j| j.id)
            .collect()
    }

    /// Whether every admitted job completed (O(1); counts transitions
    /// made through [`JobQueue::complete`]).
    pub fn all_complete(&self) -> bool {
        self.completed_count == self.jobs.len()
    }

    /// The completed jobs, in id order.
    pub fn completed(&self) -> Vec<&Job> {
        self.jobs
            .values()
            .filter(|j| j.status == JobStatus::Completed)
            .collect()
    }

    /// Earliest arrival among non-completed jobs not yet arrived at
    /// `now` (next event; completing a future job — e.g. cancelling it
    /// before it arrives — removes it from consideration on both
    /// paths). At or past the poll watermark this is an O(log n) range
    /// probe of the pending index; behind the watermark it falls back to
    /// the full scan.
    pub fn next_arrival_after(&self, now: f64) -> Option<f64> {
        if now >= self.polled_to {
            // Every job with arrival > now is still pending (arrivals
            // drain only up to the watermark <= now).
            let from = (arrival_key(now).wrapping_add(1), JobId(0));
            return self.pending.range(from..).next().map(|&(k, _)| key_arrival(k));
        }
        self.jobs
            .values()
            .filter(|j| j.arrival > now && j.status != JobStatus::Completed)
            .map(|j| j.arrival)
            .fold(None, |acc, a| Some(acc.map_or(a, |b: f64| b.min(a))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::model::DlModel;

    fn mk(id: u64, arrival: f64) -> Job {
        Job::new(id, DlModel::Lstm, arrival, 1, 1, 10)
    }

    #[test]
    fn admission_and_lookup() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0)).unwrap();
        q.admit(mk(2, 5.0)).unwrap();
        assert_eq!(q.len(), 2);
        assert!(q.get(JobId(1)).is_some());
        assert!(q.get(JobId(3)).is_none());
    }

    #[test]
    fn duplicate_admission_is_an_error() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0)).unwrap();
        let err = q.admit(mk(1, 1.0)).unwrap_err();
        assert_eq!(err, AdmitError { id: JobId(1) });
        assert!(err.to_string().contains("duplicate job id J1"));
        // The queue is untouched by the rejected admission.
        assert_eq!(q.len(), 1);
        assert_eq!(q.get(JobId(1)).unwrap().arrival, 0.0);
    }

    #[test]
    fn active_set_respects_arrival_and_completion() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0)).unwrap();
        q.admit(mk(2, 100.0)).unwrap();
        assert_eq!(q.active_at(50.0), vec![JobId(1)]);
        assert_eq!(q.active_at(100.0).len(), 2);
        q.complete(JobId(1), 60.0);
        assert_eq!(q.active_at(100.0), vec![JobId(2)]);
        assert!(!q.all_complete());
        q.complete(JobId(2), 130.0);
        assert!(q.all_complete());
    }

    #[test]
    fn next_arrival() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 10.0)).unwrap();
        q.admit(mk(2, 30.0)).unwrap();
        assert_eq!(q.next_arrival_after(0.0), Some(10.0));
        assert_eq!(q.next_arrival_after(10.0), Some(30.0));
        assert_eq!(q.next_arrival_after(30.0), None);
    }

    #[test]
    fn next_arrival_agrees_with_index_after_polls() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 10.0)).unwrap();
        q.admit(mk(2, 30.0)).unwrap();
        q.admit(mk(3, 30.0)).unwrap();
        q.poll_round(10.0);
        // At/past the watermark: answered from the pending index.
        assert_eq!(q.next_arrival_after(10.0), Some(30.0));
        assert_eq!(q.next_arrival_after(29.0), Some(30.0));
        assert_eq!(q.next_arrival_after(30.0), None);
        // Behind the watermark: the full-scan fallback still answers.
        assert_eq!(q.next_arrival_after(5.0), Some(10.0));
        q.poll_round(30.0);
        assert_eq!(q.next_arrival_after(30.0), None);
        assert_eq!(q.waiting(), vec![JobId(1), JobId(2), JobId(3)]);
    }

    #[test]
    fn poll_round_reports_arrivals_completions_preemptions() {
        let mut q = JobQueue::new();
        q.admit(mk(1, 0.0)).unwrap();
        q.admit(mk(2, 5.0)).unwrap();
        q.admit(mk(3, 50.0)).unwrap();
        let d = q.poll_round(10.0);
        assert_eq!(d.arrivals, vec![JobId(1), JobId(2)]);
        assert!(d.completions.is_empty() && d.preemptions.is_empty());
        assert_eq!(q.waiting(), vec![JobId(1), JobId(2)]);

        q.complete(JobId(1), 12.0);
        q.note_preempted(JobId(2));
        let d = q.poll_round(50.0);
        assert_eq!(d.arrivals, vec![JobId(3)]);
        assert_eq!(d.completions, vec![JobId(1)]);
        assert_eq!(d.preemptions, vec![JobId(2)]);
        assert_eq!(q.waiting(), vec![JobId(2), JobId(3)]);
        assert_eq!(q.get(JobId(1)).unwrap().finish_time, Some(12.0));

        // Nothing changed since: the next delta is empty.
        assert!(q.poll_round(50.0).is_empty());
        // Completing twice is a no-op and reports nothing new.
        assert!(!q.complete(JobId(1), 99.0));
        assert!(q.poll_round(50.0).is_empty());
    }

    #[test]
    fn waiting_matches_full_scan_and_arrival_order_breaks_ties() {
        let mut q = JobQueue::new();
        // Same arrival, ids out of order; plus a later arrival.
        q.admit(mk(7, 1.0)).unwrap();
        q.admit(mk(3, 1.0)).unwrap();
        q.admit(mk(5, 2.0)).unwrap();
        let d = q.poll_round(1.5);
        // Arrival order, id-tiebreak within the same arrival.
        assert_eq!(d.arrivals, vec![JobId(3), JobId(7)]);
        // Waiting set is id-ordered, exactly like active_at.
        assert_eq!(q.waiting(), q.active_at(1.5));
        q.poll_round(2.0);
        assert_eq!(q.waiting(), q.active_at(2.0));
        assert_eq!(q.waiting_len(), 3);
    }

    #[test]
    fn delta_merge_accumulates_idle_rounds() {
        let mut a = RoundDelta {
            arrivals: vec![JobId(1)],
            completions: vec![],
            preemptions: vec![JobId(2)],
            events: 1,
        };
        let b = RoundDelta {
            arrivals: vec![JobId(3)],
            completions: vec![JobId(1)],
            preemptions: vec![],
            events: 2,
        };
        a.merge(b);
        assert_eq!(a.arrivals, vec![JobId(1), JobId(3)]);
        assert_eq!(a.completions, vec![JobId(1)]);
        assert_eq!(a.preemptions, vec![JobId(2)]);
        assert_eq!(a.events, 3);
        assert!(!a.is_empty());
        assert!(RoundDelta::default().is_empty());
    }
}
