//! Jobs: the DL model catalogue (Tables II/III), the job abstraction
//! (Table I notation), throughput modelling (Eq. 10 + online refinement),
//! and the global queue.

pub mod job;
pub mod model;
pub mod queue;
pub mod throughput;

pub use job::{Job, JobId, JobStatus};
pub use model::{DlModel, QualityMetric, SizeClass};
pub use queue::JobQueue;
