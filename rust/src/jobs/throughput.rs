//! Throughput modelling: measured anchors, the paper's Eq. (10) initial
//! estimator, and the online refinement loop (§V-A "Initial Throughput
//! Estimation").
//!
//! ```text
//! Throughput = PMI * batch_size * pcie_scaling / (model_weight * dataset_size)
//! ```
//!
//! The estimator is calibrated per model so that its V100 prediction equals
//! the measured V100 anchor; other GPU types then scale by their PMI and
//! PCIe terms. During emulated execution, measured samples are folded in
//! with an exponential moving average, reproducing the paper's progressive
//! refinement.

use crate::cluster::gpu::{GpuType, PcieGen};
use crate::jobs::model::DlModel;
use std::collections::BTreeMap;

/// Raw Eq. (10) value (uncalibrated).
pub fn eq10_raw(model: DlModel, gpu: GpuType, pcie: PcieGen) -> f64 {
    gpu.pmi() * model.batch_size() * pcie.scaling()
        / (model.weight_scale() * model.size_class().dataset_scale())
}

/// Eq. (10) estimate calibrated to the model's V100 anchor, in
/// iterations/second.
pub fn estimate(model: DlModel, gpu: GpuType, pcie: PcieGen) -> f64 {
    let anchor = model
        .anchor_throughput(GpuType::V100)
        .expect("V100 anchor always present");
    let raw_v100 = eq10_raw(model, GpuType::V100, PcieGen::Gen3);
    anchor * eq10_raw(model, gpu, pcie) / raw_v100
}

/// A job's throughput row over the GPU types of a cluster: measured anchors
/// where available, Eq. (10) estimates elsewhere.
pub fn throughput_row(model: DlModel, gpu_pcie: &[(GpuType, PcieGen)])
                      -> BTreeMap<GpuType, f64> {
    let mut row = BTreeMap::new();
    for &(gpu, pcie) in gpu_pcie {
        let x = model
            .anchor_throughput(gpu)
            .unwrap_or_else(|| estimate(model, gpu, pcie));
        row.insert(gpu, x);
    }
    row
}

/// Online estimator: starts from Eq. (10)/anchors and folds in measured
/// iterations/sec samples (EMA), as the Job Tracker receives per-round
/// reports.
#[derive(Clone, Debug)]
pub struct OnlineEstimator {
    /// Current estimates keyed by (model, gpu type).
    estimates: BTreeMap<(DlModel, GpuType), f64>,
    /// Number of measurements folded in per key.
    samples: BTreeMap<(DlModel, GpuType), usize>,
    /// EMA factor for new measurements.
    pub alpha: f64,
}

impl OnlineEstimator {
    /// Estimator with EMA factor `alpha` for new measurements.
    pub fn new(alpha: f64) -> Self {
        OnlineEstimator {
            estimates: BTreeMap::new(),
            samples: BTreeMap::new(),
            alpha,
        }
    }

    /// Current estimate; seeds from anchors/Eq. (10) on first access.
    pub fn get(&mut self, model: DlModel, gpu: GpuType, pcie: PcieGen) -> f64 {
        *self
            .estimates
            .entry((model, gpu))
            .or_insert_with(|| {
                model
                    .anchor_throughput(gpu)
                    .unwrap_or_else(|| estimate(model, gpu, pcie))
            })
    }

    /// Fold in one measured sample (iterations/sec on one GPU).
    pub fn observe(&mut self, model: DlModel, gpu: GpuType, measured: f64) {
        let e = self.estimates.entry((model, gpu)).or_insert(measured);
        *e = (1.0 - self.alpha) * *e + self.alpha * measured;
        *self.samples.entry((model, gpu)).or_insert(0) += 1;
    }

    /// Measurements folded in for one `(model, gpu)` key.
    pub fn sample_count(&self, model: DlModel, gpu: GpuType) -> usize {
        self.samples.get(&(model, gpu)).copied().unwrap_or(0)
    }

    /// Mean absolute relative error against a ground-truth function —
    /// used by the estimator-quality ablation bench.
    pub fn relative_error(
        &mut self,
        pairs: &[(DlModel, GpuType, PcieGen)],
        truth: impl Fn(DlModel, GpuType) -> f64,
    ) -> f64 {
        let mut err = 0.0;
        for &(m, g, p) in pairs {
            let e = self.get(m, g, p);
            let t = truth(m, g);
            err += ((e - t) / t).abs();
        }
        err / pairs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimate_matches_anchor_on_v100() {
        for m in DlModel::ALL {
            let est = estimate(m, GpuType::V100, PcieGen::Gen3);
            let anchor = m.anchor_throughput(GpuType::V100).unwrap();
            assert!((est - anchor).abs() / anchor < 1e-9, "{m:?}");
        }
    }

    #[test]
    fn estimate_monotone_in_pmi() {
        // Faster GPUs (higher PMI) get higher estimates.
        for m in DlModel::ALL {
            let t4 = estimate(m, GpuType::T4, PcieGen::Gen3);
            let t400 = estimate(m, GpuType::T400, PcieGen::Gen3);
            let r3090 = estimate(m, GpuType::Rtx3090, PcieGen::Gen3);
            assert!(r3090 > t4 && t4 > t400, "{m:?}");
        }
    }

    #[test]
    fn pcie_gen4_improves_estimate() {
        let g3 = estimate(DlModel::MiMa, GpuType::Rtx3090, PcieGen::Gen3);
        let g4 = estimate(DlModel::MiMa, GpuType::Rtx3090, PcieGen::Gen4);
        assert!(g4 > g3);
    }

    #[test]
    fn throughput_row_prefers_anchors() {
        let row = throughput_row(
            DlModel::ResNet50,
            &[
                (GpuType::V100, PcieGen::Gen3),
                (GpuType::K80, PcieGen::Gen3),
                (GpuType::T4, PcieGen::Gen3),
            ],
        );
        assert_eq!(row[&GpuType::V100], 3.2);
        assert_eq!(row[&GpuType::K80], 0.32); // anchor, not estimate
        assert!(row[&GpuType::T4] > 0.0);
    }

    #[test]
    fn online_estimator_converges_to_measurements() {
        let mut est = OnlineEstimator::new(0.5);
        let initial = est.get(DlModel::Lstm, GpuType::T4, PcieGen::Gen3);
        let truth = initial * 2.0;
        for _ in 0..20 {
            est.observe(DlModel::Lstm, GpuType::T4, truth);
        }
        let now = est.get(DlModel::Lstm, GpuType::T4, PcieGen::Gen3);
        assert!((now - truth).abs() / truth < 1e-3);
        assert_eq!(est.sample_count(DlModel::Lstm, GpuType::T4), 20);
    }

    #[test]
    fn relative_error_decreases_with_observations() {
        let mut est = OnlineEstimator::new(0.5);
        let pairs = [(DlModel::MiMa, GpuType::TitanRtx, PcieGen::Gen3)];
        let truth =
            |m: DlModel, g: GpuType| estimate(m, g, PcieGen::Gen3) * 1.5;
        let before = est.relative_error(&pairs, truth);
        for _ in 0..10 {
            est.observe(DlModel::MiMa, GpuType::TitanRtx,
                        truth(DlModel::MiMa, GpuType::TitanRtx));
        }
        let after = est.relative_error(&pairs, truth);
        assert!(after < before);
    }
}
