//! The unit of scheduling: one DL training job (paper Table I notation).

use crate::cluster::gpu::GpuType;
use crate::jobs::model::DlModel;
use std::collections::BTreeMap;

/// Job identifier. HadarE's fork-copy ids are derived from parent ids via
/// the paper's formula (see `forking::forker`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "J{}", self.0)
    }
}

/// Lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Waiting for an allocation (including after a drain preemption).
    Queued,
    /// Held an allocation in the last scheduled round.
    Running,
    /// All `E_j * N_j` iterations done.
    Completed,
}

/// One DL training job `j`:
/// arrival `a_j`, demand `W_j`, length `E_j * N_j` iterations, and its
/// per-GPU-type throughput row `X_j^r` (iterations/second).
#[derive(Clone, Debug)]
pub struct Job {
    /// Job id `j`.
    pub id: JobId,
    /// The DL model being trained (Tables II/III catalogue).
    pub model: DlModel,
    /// `a_j` (seconds).
    pub arrival: f64,
    /// `W_j`: number of workers requested (gang — all or nothing).
    pub gpus_requested: usize,
    /// `E_j`: epochs.
    pub epochs: u64,
    /// `N_j`: iterations (data chunks) per epoch.
    pub iters_per_epoch: u64,
    /// `X_j^r` — iterations/second on one GPU of each type.
    pub throughput: BTreeMap<GpuType, f64>,
    /// Completed iterations so far (monotone).
    pub progress: f64,
    /// Lifecycle state.
    pub status: JobStatus,
    /// `f_j` once complete (seconds).
    pub finish_time: Option<f64>,
    /// Utility weight (1.0 unless a policy weighs jobs).
    pub weight: f64,
    /// Parent id if this job is a HadarE fork copy.
    pub parent: Option<JobId>,
}

impl Job {
    /// Build a job with an empty throughput row (fill it with
    /// [`Job::set_throughput`] or `jobs::throughput::throughput_row`).
    pub fn new(id: u64, model: DlModel, arrival: f64, gpus: usize,
               epochs: u64, iters_per_epoch: u64) -> Self {
        Job {
            id: JobId(id),
            model,
            arrival,
            gpus_requested: gpus,
            epochs,
            iters_per_epoch,
            throughput: BTreeMap::new(),
            progress: 0.0,
            status: JobStatus::Queued,
            finish_time: None,
            weight: 1.0,
            parent: None,
        }
    }

    /// `E_j * N_j`.
    pub fn total_iters(&self) -> f64 {
        (self.epochs * self.iters_per_epoch) as f64
    }

    /// Iterations left (0 within float tolerance of completion).
    pub fn remaining_iters(&self) -> f64 {
        let rem = self.total_iters() - self.progress;
        // Relative tolerance: float progress accumulation across rounds.
        if rem <= 1e-9 * self.total_iters().max(1.0) {
            0.0
        } else {
            rem
        }
    }

    /// Whether all iterations are done.
    pub fn is_complete(&self) -> bool {
        self.remaining_iters() <= 0.0
    }

    /// `X_j^r`; 0 for types this job has no measurement for.
    pub fn throughput_on(&self, gpu: GpuType) -> f64 {
        self.throughput.get(&gpu).copied().unwrap_or(0.0)
    }

    /// Set `X_j^r` for one GPU type.
    pub fn set_throughput(&mut self, gpu: GpuType, iters_per_sec: f64) {
        self.throughput.insert(gpu, iters_per_sec);
    }

    /// Fastest / slowest single-GPU throughputs (Eqs. (6)-(7) use the
    /// corresponding t_min / t_max).
    pub fn max_throughput(&self) -> f64 {
        self.throughput.values().cloned().fold(0.0, f64::max)
    }

    /// Slowest positive single-GPU throughput.
    pub fn min_throughput(&self) -> f64 {
        self.throughput
            .values()
            .cloned()
            .filter(|&x| x > 0.0)
            .fold(f64::INFINITY, f64::min)
    }

    /// `t_j^min` / `t_j^max` from §III-B: best/worst-case runtime given the
    /// requested gang size.
    pub fn t_min(&self) -> f64 {
        self.total_iters()
            / (self.gpus_requested as f64 * self.max_throughput())
    }

    /// Worst-case runtime `t_j^max` (see [`Job::t_min`]).
    pub fn t_max(&self) -> f64 {
        self.total_iters()
            / (self.gpus_requested as f64 * self.min_throughput())
    }

    /// Job utility `U_j(tau)` for completion duration `tau`: the paper's
    /// *effective throughput* special case — completed iterations per
    /// second over the job's lifetime, weighted.
    pub fn utility(&self, duration: f64) -> f64 {
        if duration <= 0.0 {
            return 0.0;
        }
        self.weight * self.total_iters() / duration
    }

    /// Completion time `f_j - a_j` if finished.
    pub fn completion_time(&self) -> Option<f64> {
        self.finish_time.map(|f| f - self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        let mut j = Job::new(1, DlModel::ResNet18, 10.0, 2, 4, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        j
    }

    #[test]
    fn iteration_accounting() {
        let mut j = job();
        assert_eq!(j.total_iters(), 400.0);
        assert_eq!(j.remaining_iters(), 400.0);
        j.progress = 150.0;
        assert_eq!(j.remaining_iters(), 250.0);
        assert!(!j.is_complete());
        j.progress = 400.0;
        assert!(j.is_complete());
    }

    #[test]
    fn throughput_extremes_and_times() {
        let j = job();
        assert_eq!(j.max_throughput(), 40.0);
        assert_eq!(j.min_throughput(), 8.0);
        assert!((j.t_min() - 400.0 / 80.0).abs() < 1e-9);
        assert!((j.t_max() - 400.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn utility_is_effective_throughput() {
        let j = job();
        assert!((j.utility(100.0) - 4.0).abs() < 1e-9);
        // Non-increasing in duration.
        assert!(j.utility(50.0) > j.utility(100.0));
        assert_eq!(j.utility(0.0), 0.0);
    }

    #[test]
    fn completion_time() {
        let mut j = job();
        assert_eq!(j.completion_time(), None);
        j.finish_time = Some(110.0);
        assert_eq!(j.completion_time(), Some(100.0));
    }
}
