//! DL model catalogue — the paper's Table II (trace-driven evaluation) and
//! Table III (physical clusters) workloads.
//!
//! Each catalogue entry carries what the two evaluation paths need:
//! * the scheduler's throughput model: measured V100/P100/K80 anchors
//!   (Gavel-style measurements, synthesised per DESIGN.md §Substitutions)
//!   plus the Eq. (10) terms (model weight scale, dataset size, batch);
//! * the emulation path's mapping onto an AOT-lowered transformer-LM
//!   variant (`python/compile/model.py::VARIANTS`) and its quality metric.

use crate::cluster::gpu::GpuType;

/// Dataset/GPU-hour size classes (paper §IV-A: S 0-1, M 1-10, L 10-50,
/// XL 60-100 GPU-hours).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SizeClass {
    /// Small: 0-1 GPU-hours.
    S,
    /// Medium: 1-10 GPU-hours.
    M,
    /// Large: 10-50 GPU-hours.
    L,
    /// Extra-large: 60-100 GPU-hours.
    XL,
}

impl SizeClass {
    /// All classes, smallest first.
    pub const ALL: [SizeClass; 4] =
        [SizeClass::S, SizeClass::M, SizeClass::L, SizeClass::XL];

    /// Short class name (`"S"` … `"XL"`).
    pub fn name(&self) -> &'static str {
        match self {
            SizeClass::S => "S",
            SizeClass::M => "M",
            SizeClass::L => "L",
            SizeClass::XL => "XL",
        }
    }

    /// GPU-hour range used to bucket trace jobs (paper §IV-A).
    pub fn gpu_hour_range(&self) -> (f64, f64) {
        match self {
            SizeClass::S => (0.0, 1.0),
            SizeClass::M => (1.0, 10.0),
            SizeClass::L => (10.0, 50.0),
            SizeClass::XL => (60.0, 100.0),
        }
    }

    /// Eq. (10) `dataset_size` scale.
    pub fn dataset_scale(&self) -> f64 {
        match self {
            SizeClass::S => 1.0,
            SizeClass::M => 2.0,
            SizeClass::L => 4.0,
            SizeClass::XL => 8.0,
        }
    }
}

/// Inference-quality metric reported in Table IV.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QualityMetric {
    /// higher is better
    Acc,
    /// lower is better
    Mse,
}

/// The DL models of Tables II & III.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DlModel {
    /// Image Classification / ImageNet (XL) — Table II.
    ResNet50,
    /// Image Classification / CIFAR-10 (S) — code IC.
    ResNet18,
    /// Language Modeling / Wikitext-2 (L) — code LM.
    Lstm,
    /// Image-to-Image / monet2photo (M) — Table II.
    CycleGan,
    /// Language Translation / Multi30k (L) — code LT.
    Transformer,
    /// Recommendation / ML-20M (XL) — code RS.
    Recoder,
    /// Weather prediction / Mesonet+HRRR (M) — code MM.
    MiMa,
}

impl DlModel {
    /// Every catalogued model.
    pub const ALL: [DlModel; 7] = [
        DlModel::ResNet50,
        DlModel::ResNet18,
        DlModel::Lstm,
        DlModel::CycleGan,
        DlModel::Transformer,
        DlModel::Recoder,
        DlModel::MiMa,
    ];

    /// Table II models (trace-driven simulation).
    pub const TABLE2: [DlModel; 5] = [
        DlModel::ResNet50,
        DlModel::ResNet18,
        DlModel::Lstm,
        DlModel::CycleGan,
        DlModel::Transformer,
    ];

    /// Table III models (physical clusters). Short codes: IC LM LT RS MM.
    pub const TABLE3: [DlModel; 5] = [
        DlModel::ResNet18,
        DlModel::Lstm,
        DlModel::Transformer,
        DlModel::Recoder,
        DlModel::MiMa,
    ];

    /// Display name (paper spelling).
    pub fn name(&self) -> &'static str {
        match self {
            DlModel::ResNet50 => "ResNet-50",
            DlModel::ResNet18 => "ResNet-18",
            DlModel::Lstm => "LSTM",
            DlModel::CycleGan => "CycleGAN",
            DlModel::Transformer => "Transformer",
            DlModel::Recoder => "Recoder",
            DlModel::MiMa => "MiMa",
        }
    }

    /// Short workload code used in the paper's mix notation (M-4 = <IC, LM,
    /// LT, MM> etc.).
    pub fn code(&self) -> &'static str {
        match self {
            DlModel::ResNet50 => "IC*",
            DlModel::ResNet18 => "IC",
            DlModel::Lstm => "LM",
            DlModel::CycleGan => "I2I",
            DlModel::Transformer => "LT",
            DlModel::Recoder => "RS",
            DlModel::MiMa => "MM",
        }
    }

    /// Training task column of Tables II/III.
    pub fn task(&self) -> &'static str {
        match self {
            DlModel::ResNet50 | DlModel::ResNet18 => "Image Classification",
            DlModel::Lstm => "Language Modeling",
            DlModel::CycleGan => "Image-to-Image Translation",
            DlModel::Transformer => "Language Translation",
            DlModel::Recoder => "Recommendation System",
            DlModel::MiMa => "MiMa Weather Predictions",
        }
    }

    /// Dataset column of Tables II/III.
    pub fn dataset(&self) -> &'static str {
        match self {
            DlModel::ResNet50 => "ImageNet",
            DlModel::ResNet18 => "CIFAR-10",
            DlModel::Lstm => "Wikitext-2",
            DlModel::CycleGan => "Monet2photo",
            DlModel::Transformer => "Multi30K (de-en)",
            DlModel::Recoder => "ML-20M",
            DlModel::MiMa => "Mesonet + WRF-HRRR",
        }
    }

    /// GPU-hour size class.
    pub fn size_class(&self) -> SizeClass {
        match self {
            DlModel::ResNet50 => SizeClass::XL,
            DlModel::ResNet18 => SizeClass::S,
            DlModel::Lstm => SizeClass::L,
            DlModel::CycleGan => SizeClass::M,
            DlModel::Transformer => SizeClass::L,
            DlModel::Recoder => SizeClass::XL,
            DlModel::MiMa => SizeClass::M,
        }
    }

    /// Eq. (10) `model_weight` complexity scale (small → extra-high).
    pub fn weight_scale(&self) -> f64 {
        match self {
            DlModel::ResNet50 => 4.0,
            DlModel::ResNet18 => 1.0,
            DlModel::Lstm => 2.0,
            DlModel::CycleGan => 4.0,
            DlModel::Transformer => 2.0,
            DlModel::Recoder => 4.0,
            DlModel::MiMa => 2.0,
        }
    }

    /// Training mini-batch size (Eq. (10) `batch_size`).
    pub fn batch_size(&self) -> f64 {
        match self {
            DlModel::ResNet50 => 64.0,
            DlModel::ResNet18 => 128.0,
            DlModel::Lstm => 80.0,
            DlModel::CycleGan => 8.0,
            DlModel::Transformer => 128.0,
            DlModel::Recoder => 256.0,
            DlModel::MiMa => 64.0,
        }
    }

    /// Measured anchors (iterations/sec) on the simulated trio, standing in
    /// for Gavel's published throughput tables. Ratios follow the paper's
    /// §I observation: compute-bound CNNs see ~10x V100:K80, lighter models
    /// see much flatter profiles (A3C's ~2x anchor).
    pub fn anchor_throughput(&self, gpu: GpuType) -> Option<f64> {
        let (v100, p100, k80) = match self {
            DlModel::ResNet50 => (3.2, 1.6, 0.32),     // 10.0x
            DlModel::ResNet18 => (40.0, 25.0, 8.0),    // 5.0x
            DlModel::Lstm => (60.0, 40.0, 15.0),       // 4.0x
            DlModel::CycleGan => (7.0, 3.5, 0.9),      // 7.8x
            DlModel::Transformer => (30.0, 18.0, 6.0), // 5.0x
            DlModel::Recoder => (18.0, 12.0, 5.0),     // 3.6x
            DlModel::MiMa => (25.0, 16.0, 7.0),        // 3.6x
        };
        match gpu {
            GpuType::V100 => Some(v100),
            GpuType::P100 => Some(p100),
            GpuType::K80 => Some(k80),
            _ => None,
        }
    }

    /// Which AOT-lowered transformer variant emulates this model in the
    /// physical-cluster path (DESIGN.md §Substitutions).
    pub fn runtime_variant(&self) -> &'static str {
        match self.size_class() {
            SizeClass::S => "tiny",
            SizeClass::M => "tiny",
            SizeClass::L => "small",
            SizeClass::XL => "small",
        }
    }

    /// Table IV metric for this model.
    pub fn quality_metric(&self) -> QualityMetric {
        match self {
            DlModel::ResNet18 | DlModel::ResNet50 | DlModel::Transformer => {
                QualityMetric::Acc
            }
            _ => QualityMetric::Mse,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_cover_sim_trio_only() {
        for m in DlModel::ALL {
            for g in [GpuType::V100, GpuType::P100, GpuType::K80] {
                assert!(m.anchor_throughput(g).is_some());
            }
            assert!(m.anchor_throughput(GpuType::T4).is_none());
        }
    }

    #[test]
    fn resnet50_v100_k80_ratio_matches_paper() {
        let m = DlModel::ResNet50;
        let ratio = m.anchor_throughput(GpuType::V100).unwrap()
            / m.anchor_throughput(GpuType::K80).unwrap();
        assert!((ratio - 10.0).abs() < 0.5, "paper: ~10x, got {ratio}");
    }

    #[test]
    fn throughput_monotone_in_gpu_generation() {
        for m in DlModel::ALL {
            let v = m.anchor_throughput(GpuType::V100).unwrap();
            let p = m.anchor_throughput(GpuType::P100).unwrap();
            let k = m.anchor_throughput(GpuType::K80).unwrap();
            assert!(v > p && p > k, "{m:?}");
        }
    }

    #[test]
    fn size_class_ranges_are_ordered() {
        let mut last_hi = 0.0;
        for s in SizeClass::ALL {
            let (lo, hi) = s.gpu_hour_range();
            assert!(lo >= last_hi - 10.0); // paper has a 50-60 gap
            assert!(hi > lo);
            last_hi = hi;
        }
    }

    #[test]
    fn table3_models_have_variants_and_metrics() {
        for m in DlModel::TABLE3 {
            assert!(["tiny", "small", "medium"].contains(&m.runtime_variant()));
            let _ = m.quality_metric();
        }
    }
}
