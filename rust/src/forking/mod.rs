//! HadarE's forking machinery (paper §V): the Job Forker creates per-node
//! copies of every training job; the Job Tracker aggregates their steps
//! and consolidates their model parameters at round boundaries.

pub mod forker;
pub mod tracker;

pub use forker::{fork, ForkIds};
pub use tracker::{consolidate_weights, JobTracker, ParentProgress};
