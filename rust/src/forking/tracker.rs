//! HadarE's **Job Tracker** (paper §V-A/B): registers forked copies,
//! aggregates completed training steps across copies, divides the
//! remaining work proportionally to node throughputs, and coordinates
//! model-parameter consolidation at round boundaries.
//!
//! The tracker is engine-agnostic: the discrete-time simulator uses the
//! step accounting only; the physical-cluster emulation also routes
//! parameter vectors through [`consolidate_weights`].

use crate::forking::forker::ForkIds;
use crate::jobs::job::JobId;
use std::collections::BTreeMap;

/// Per-parent training state.
#[derive(Clone, Debug)]
pub struct ParentProgress {
    /// Total steps required (the parent's `E_j * N_j`).
    pub total_steps: f64,
    /// Steps aggregated across all copies so far.
    pub done_steps: f64,
    /// Registered copy ids.
    pub copies: Vec<JobId>,
}

impl ParentProgress {
    /// Relative tolerance for float step accumulation across copies.
    const EPS: f64 = 1e-9;

    /// Steps left (0 within float tolerance of completion).
    pub fn remaining(&self) -> f64 {
        let rem = self.total_steps - self.done_steps;
        if rem <= Self::EPS * self.total_steps.max(1.0) {
            0.0
        } else {
            rem
        }
    }

    /// Whether the parent aggregated all its steps.
    pub fn is_complete(&self) -> bool {
        self.remaining() <= 0.0
    }
}

/// The Job Tracker.
#[derive(Clone, Debug)]
pub struct JobTracker {
    /// Copy-id arithmetic shared with the forker.
    pub ids: ForkIds,
    parents: BTreeMap<JobId, ParentProgress>,
    /// Registered parents not yet complete, maintained by [`register`]
    /// and [`report_steps`] so [`all_complete`] is O(1) — the engines
    /// test it every round, which at streaming scale (1M parents) would
    /// otherwise be a full scan per round.
    ///
    /// [`register`]: JobTracker::register
    /// [`report_steps`]: JobTracker::report_steps
    /// [`all_complete`]: JobTracker::all_complete
    incomplete: usize,
}

impl JobTracker {
    /// Empty tracker over the given id scheme.
    pub fn new(ids: ForkIds) -> Self {
        JobTracker {
            ids,
            parents: BTreeMap::new(),
            incomplete: 0,
        }
    }

    /// Register a parent and its forked copies.
    pub fn register(&mut self, parent: JobId, total_steps: f64,
                    copies: &[JobId]) {
        for &c in copies {
            debug_assert_eq!(self.ids.parent_of(c), parent);
        }
        let progress = ParentProgress {
            total_steps,
            done_steps: 0.0,
            copies: copies.to_vec(),
        };
        let now_complete = progress.is_complete();
        let prior = self.parents.insert(parent, progress);
        // Re-registration replaces the prior entry; only its incomplete
        // contribution carries over.
        if let Some(p) = prior {
            if !p.is_complete() {
                self.incomplete -= 1;
            }
        }
        if !now_complete {
            self.incomplete += 1;
        }
    }

    /// One parent's progress.
    pub fn parent(&self, id: JobId) -> Option<&ParentProgress> {
        self.parents.get(&id)
    }

    /// All registered parents in id order.
    pub fn parents(&self) -> impl Iterator<Item = (&JobId, &ParentProgress)> {
        self.parents.iter()
    }

    /// Resolve any id (parent or copy) to its parent.
    pub fn resolve(&self, id: JobId) -> JobId {
        if self.ids.is_copy(id) {
            self.ids.parent_of(id)
        } else {
            id
        }
    }

    /// §V-B result aggregation: sum completed steps reported by a node for
    /// one copy into the parent's total. Returns the parent id.
    pub fn report_steps(&mut self, copy: JobId, steps: f64) -> JobId {
        let parent = self.resolve(copy);
        if let Some(p) = self.parents.get_mut(&parent) {
            let was_complete = p.is_complete();
            p.done_steps = (p.done_steps + steps).min(p.total_steps);
            if !was_complete && p.is_complete() {
                self.incomplete -= 1;
            }
        }
        parent
    }

    /// Whether the (parent of) `id` finished all its steps.
    pub fn is_parent_complete(&self, id: JobId) -> bool {
        let parent = self.resolve(id);
        self.parents
            .get(&parent)
            .map(|p| p.is_complete())
            .unwrap_or(false)
    }

    /// Whether every registered parent completed. O(1): the engines ask
    /// every round, so the answer is a maintained counter, not a scan.
    pub fn all_complete(&self) -> bool {
        self.incomplete == 0
    }

    /// §V-B work division: split the parent's remaining steps across the
    /// gangs assigned this round, proportionally to their **sub-gang**
    /// throughputs — iterations/sec of the parent's model on what each
    /// copy actually booked ([`crate::sched::hadare::alloc_throughput`]:
    /// bottleneck rule × sub-linear multi-GPU scaling; a whole node by
    /// default, one `(node, pool)` under partial-node gangs, and on
    /// single-GPU nodes simply the per-GPU rate). A 4×K80 gang therefore
    /// draws a larger share than a 1×K80 node, but *not* naively 4×. The
    /// shares are what each copy should complete in the next slot, capped
    /// by the gang's slot capacity `x·L`.
    pub fn divide_steps(&self, parent: JobId, node_throughputs: &[f64],
                        slot_secs: f64) -> Vec<f64> {
        let remaining = match self.parents.get(&parent) {
            Some(p) => p.remaining(),
            None => return vec![0.0; node_throughputs.len()],
        };
        let total_x: f64 = node_throughputs.iter().sum();
        if total_x <= 0.0 || remaining <= 0.0 {
            return vec![0.0; node_throughputs.len()];
        }
        node_throughputs
            .iter()
            .map(|&x| {
                let share = remaining * x / total_x;
                // A node cannot exceed its slot capacity x * L.
                share.min(x * slot_secs)
            })
            .collect()
    }
}

/// §V-B result consolidation: weight-average the parameter vectors of the
/// copies trained this round. `weights` are the per-copy step counts (the
/// paper averages; step-weighting is the natural generalisation and is
/// ablated — pass equal weights for the plain average).
pub fn consolidate_weights(copies: &[Vec<f32>], weights: &[f64])
                           -> Vec<f32> {
    assert!(!copies.is_empty());
    assert_eq!(copies.len(), weights.len());
    let dim = copies[0].len();
    assert!(copies.iter().all(|c| c.len() == dim), "shape mismatch");
    let total: f64 = weights.iter().sum();
    let norm: Vec<f64> = if total > 0.0 {
        weights.iter().map(|w| w / total).collect()
    } else {
        vec![1.0 / copies.len() as f64; copies.len()]
    };
    let mut out = vec![0.0f32; dim];
    for (copy, &w) in copies.iter().zip(norm.iter()) {
        for (o, &v) in out.iter_mut().zip(copy.iter()) {
            *o += (w * v as f64) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker() -> JobTracker {
        let ids = ForkIds { max_job_count: 100 };
        let mut t = JobTracker::new(ids);
        t.register(JobId(1), 1000.0,
                   &[JobId(101), JobId(201), JobId(301)]);
        t
    }

    #[test]
    fn aggregation_sums_and_caps() {
        let mut t = tracker();
        assert_eq!(t.report_steps(JobId(101), 300.0), JobId(1));
        t.report_steps(JobId(201), 400.0);
        assert_eq!(t.parent(JobId(1)).unwrap().done_steps, 700.0);
        assert!(!t.is_parent_complete(JobId(301)));
        t.report_steps(JobId(301), 500.0); // overshoot capped
        assert_eq!(t.parent(JobId(1)).unwrap().done_steps, 1000.0);
        assert!(t.is_parent_complete(JobId(1)));
        assert!(t.all_complete());
    }

    #[test]
    fn all_complete_counter_survives_reregistration() {
        let ids = ForkIds { max_job_count: 100 };
        let mut t = JobTracker::new(ids);
        assert!(t.all_complete(), "empty tracker is trivially complete");
        t.register(JobId(1), 100.0, &[JobId(101)]);
        t.register(JobId(2), 0.0, &[JobId(102)]);
        assert!(!t.all_complete(), "parent 1 still has steps");
        // Re-registering an incomplete parent must not double-count it.
        t.register(JobId(1), 50.0, &[JobId(101)]);
        t.report_steps(JobId(101), 50.0);
        assert!(t.is_parent_complete(JobId(1)));
        assert!(t.all_complete(), "counter drained exactly to zero");
        // Reports past completion stay idempotent.
        t.report_steps(JobId(101), 10.0);
        assert!(t.all_complete());
    }

    #[test]
    fn step_division_is_throughput_proportional() {
        let t = tracker();
        let shares = t.divide_steps(JobId(1), &[30.0, 20.0, 10.0], 1e9);
        assert!((shares[0] - 500.0).abs() < 1e-9);
        assert!((shares[1] - 333.3333).abs() < 1e-2);
        assert!((shares[2] - 166.6667).abs() < 1e-2);
        assert!((shares.iter().sum::<f64>() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn step_division_caps_at_slot_capacity() {
        let t = tracker();
        // Slot of 10s at 10 it/s: max 100 steps per node.
        let shares = t.divide_steps(JobId(1), &[10.0, 10.0], 10.0);
        assert!(shares.iter().all(|&s| s <= 100.0 + 1e-9));
    }

    #[test]
    fn zero_throughput_division_is_empty() {
        let t = tracker();
        assert_eq!(t.divide_steps(JobId(1), &[0.0, 0.0], 10.0), vec![0.0, 0.0]);
    }

    #[test]
    fn gang_weights_shift_shares_sublinearly() {
        // A 4-GPU K80 gang at 0.9 marginal efficiency (rate 3.7x the
        // single-GPU node) draws 3.7x the share — more than one node,
        // less than a naive 4x.
        let t = tracker();
        let shares = t.divide_steps(JobId(1), &[37.0, 10.0], 1e9);
        assert!((shares[0] / shares[1] - 3.7).abs() < 1e-9);
        assert!(shares[0] / shares[1] < 4.0);
        assert!((shares.iter().sum::<f64>() - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn consolidation_weighted_average() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 4.0, 5.0];
        // Equal weights -> plain average.
        let avg = consolidate_weights(&[a.clone(), b.clone()], &[1.0, 1.0]);
        assert_eq!(avg, vec![2.0, 3.0, 4.0]);
        // 3:1 weighting.
        let w = consolidate_weights(&[a, b], &[3.0, 1.0]);
        assert!((w[0] - 1.5).abs() < 1e-6);
        // Zero weights fall back to plain average.
        let z = consolidate_weights(&[vec![2.0], vec![4.0]], &[0.0, 0.0]);
        assert_eq!(z, vec![3.0]);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn consolidation_rejects_shape_mismatch() {
        consolidate_weights(&[vec![1.0], vec![1.0, 2.0]], &[1.0, 1.0]);
    }
}
