//! HadarE's **Job Forker** (paper §V-A): fork every training job into `n`
//! copies for an `n`-node cluster, with the paper's job-ID formula
//!
//! ```text
//! job_ID = max_job_count * i + parent_job_id,   i = 1..=copies
//! ```

use crate::jobs::job::{Job, JobId};

/// Copy-ID arithmetic shared by the forker and the tracker.
#[derive(Clone, Copy, Debug)]
pub struct ForkIds {
    /// The paper's `max_job_count`: the largest number of parent jobs
    /// expected to coexist; copy ids live in bands above it.
    pub max_job_count: u64,
}

impl ForkIds {
    /// Id of copy `i` (1-based) of `parent` — the paper's formula.
    pub fn copy_id(&self, parent: JobId, i: u64) -> JobId {
        debug_assert!(i >= 1);
        debug_assert!(parent.0 < self.max_job_count);
        JobId(self.max_job_count * i + parent.0)
    }

    /// Parent of a copy id.
    pub fn parent_of(&self, copy: JobId) -> JobId {
        JobId(copy.0 % self.max_job_count)
    }

    /// The copy's index `i` (1-based).
    pub fn copy_index(&self, copy: JobId) -> u64 {
        copy.0 / self.max_job_count
    }

    /// Whether the id lies in a copy band (vs a parent id).
    pub fn is_copy(&self, id: JobId) -> bool {
        id.0 >= self.max_job_count
    }
}

/// Fork one parent into `copies` copy-jobs. Each copy occupies a single
/// gang slot when scheduled — the whole host node by default (the
/// planner books every GPU from the node spec), or one `(node, pool)`
/// sub-gang under partial-node HadarE — so `gpus_requested` is nominal
/// (1, the paper's §VI single-GPU-node clusters) and ignored by the
/// forking engine. Copies start with the parent's throughput row; their
/// share of work is (re)assigned by the Job Tracker each round in
/// proportion to sub-gang throughput, so copies carry the *parent's*
/// total length for utility purposes.
pub fn fork(parent: &Job, copies: u64, ids: ForkIds) -> Vec<Job> {
    (1..=copies)
        .map(|i| {
            let mut c = parent.clone();
            c.id = ids.copy_id(parent.id, i);
            c.parent = Some(parent.id);
            c.gpus_requested = 1;
            c
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::model::DlModel;

    #[test]
    fn id_formula_matches_paper_and_roundtrips() {
        let ids = ForkIds { max_job_count: 100 };
        let copy = ids.copy_id(JobId(7), 3);
        assert_eq!(copy, JobId(307));
        assert_eq!(ids.parent_of(copy), JobId(7));
        assert_eq!(ids.copy_index(copy), 3);
        assert!(ids.is_copy(copy));
        assert!(!ids.is_copy(JobId(7)));
    }

    #[test]
    fn fork_produces_distinct_single_gpu_copies() {
        let ids = ForkIds { max_job_count: 100 };
        let mut parent = Job::new(5, DlModel::MiMa, 0.0, 1, 20, 100);
        parent.weight = 2.0;
        let copies = fork(&parent, 5, ids);
        assert_eq!(copies.len(), 5);
        let mut seen = std::collections::BTreeSet::new();
        for c in &copies {
            assert!(seen.insert(c.id));
            assert_eq!(c.parent, Some(JobId(5)));
            assert_eq!(c.gpus_requested, 1);
            assert_eq!(c.total_iters(), parent.total_iters());
            assert_eq!(c.weight, 2.0);
        }
    }
}
