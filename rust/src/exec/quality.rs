//! Table IV: inference quality of models trained under HadarE (forking)
//! vs Hadar (no forking).
//!
//! Quality metrics on the synthetic-corpus substrate:
//! * ACC  — top-1 next-token accuracy × 100 (stands in for the paper's
//!          translation/classification accuracy);
//! * MSE  — held-out cross-entropy loss (a squared-error-like "lower is
//!          better" quality signal for the MSE-metric models).

use crate::exec::emulation::TrainedModel;
use crate::jobs::job::JobId;
use crate::jobs::model::{DlModel, QualityMetric};
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::{EvalStep, Runtime};
use crate::runtime::trainer::Corpus;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// One Table IV row.
#[derive(Clone, Debug)]
pub struct QualityRow {
    /// The job compared.
    pub job: JobId,
    /// Its catalogued model.
    pub model: DlModel,
    /// Which quality metric the row reports.
    pub metric: QualityMetric,
    /// Value under HadarE (forking).
    pub forking: f64,
    /// Value under Hadar (no forking).
    pub no_forking: f64,
}

impl QualityRow {
    /// Whether forking matched-or-beat no-forking on this row's metric.
    pub fn forking_wins(&self) -> bool {
        match self.metric {
            QualityMetric::Acc => self.forking >= self.no_forking,
            QualityMetric::Mse => self.forking <= self.no_forking,
        }
    }
}

/// All Table IV rows.
#[derive(Clone, Debug, Default)]
pub struct QualityReport {
    /// One row per compared job.
    pub rows: Vec<QualityRow>,
}

/// Evaluate one trained model on `n_batches` held-out batches; returns
/// (mean loss, mean accuracy).
pub fn eval_model(runtime: &Runtime, eval: &EvalStep, model: &TrainedModel,
                  manifest: &Manifest, train_seed: u64, eval_seed: u64,
                  n_batches: usize) -> Result<(f64, f64)> {
    let v = manifest
        .variant(&model.variant)
        .ok_or_else(|| anyhow!("variant {}", model.variant))?;
    let _ = runtime;
    // Held-out data: the SAME corpus the job trained on (same Markov
    // structure), sampled with an independent stream — generalisation to
    // unseen sequences, not to a different language.
    let corpus = Corpus::new(
        v.vocab, 4,
        crate::exec::emulation::corpus_seed(train_seed, model.job));
    let mut rng = Rng::new(eval_seed ^ 0xE7A1);
    let mut loss_sum = 0.0;
    let mut acc_sum = 0.0;
    for _ in 0..n_batches {
        let toks = corpus.batch(&mut rng, v.batch, v.seq + 1);
        let (l, a) = eval.eval(&model.state, &toks, v.batch, v.seq + 1)?;
        loss_sum += l as f64;
        acc_sum += a as f64;
    }
    Ok((loss_sum / n_batches as f64, acc_sum / n_batches as f64))
}

/// Build the Table IV comparison from two emulation outcomes over the same
/// job set: `forked` (HadarE) and `unforked` (Hadar).
pub fn evaluate_quality(
    jobs: &[(JobId, DlModel)], forked: &[TrainedModel],
    unforked: &[TrainedModel], manifest: &Manifest, train_seed: u64,
    eval_seed: u64,
) -> Result<QualityReport> {
    let runtime = Runtime::cpu()?;
    let mut evals: BTreeMap<String, EvalStep> = BTreeMap::new();
    let f_by_id: BTreeMap<JobId, &TrainedModel> =
        forked.iter().map(|m| (m.job, m)).collect();
    let u_by_id: BTreeMap<JobId, &TrainedModel> =
        unforked.iter().map(|m| (m.job, m)).collect();

    let mut rows = Vec::new();
    for &(id, model) in jobs {
        let (Some(fm), Some(um)) = (f_by_id.get(&id), u_by_id.get(&id))
        else {
            continue;
        };
        let vname = fm.variant.clone();
        if !evals.contains_key(&vname) {
            let v = manifest
                .variant(&vname)
                .ok_or_else(|| anyhow!("variant {vname}"))?;
            evals.insert(vname.clone(), runtime.load_eval(v)?);
        }
        let eval = &evals[&vname];
        let (fl, fa) =
            eval_model(&runtime, eval, fm, manifest, train_seed, eval_seed, 4)?;
        let (ul, ua) =
            eval_model(&runtime, eval, um, manifest, train_seed, eval_seed, 4)?;
        let metric = model.quality_metric();
        let (fv, uv) = match metric {
            QualityMetric::Acc => (fa * 100.0, ua * 100.0),
            QualityMetric::Mse => (fl, ul),
        };
        rows.push(QualityRow {
            job: id,
            model,
            metric,
            forking: fv,
            no_forking: uv,
        });
    }
    Ok(QualityReport { rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forking_wins_semantics() {
        let acc = QualityRow {
            job: JobId(0),
            model: DlModel::Transformer,
            metric: QualityMetric::Acc,
            forking: 54.7,
            no_forking: 52.4,
        };
        assert!(acc.forking_wins());
        let mse = QualityRow {
            job: JobId(1),
            model: DlModel::MiMa,
            metric: QualityMetric::Mse,
            forking: 0.025,
            no_forking: 0.028,
        };
        assert!(mse.forking_wins());
        let worse = QualityRow {
            forking: 0.03,
            ..mse
        };
        assert!(!worse.forking_wins());
    }
}
