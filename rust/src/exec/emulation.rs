//! Replay a scheduler's round log with **real** PJRT training.
//!
//! For Hadar/Gavel the per-round `(job, node, progressed)` records from
//! `sim::engine` drive each job's own `Trainer`; for HadarE the per-copy
//! work log from `sim::hadare_engine` additionally routes every round
//! through the Job Tracker's weight consolidation (§V-B): copies start
//! from the consolidated parent parameters, train their share, and the
//! round ends with a throughput/step-weighted parameter average.

use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::{Job, JobId};
use crate::jobs::queue::JobQueue;
use crate::runtime::artifacts::{Manifest, Variant};
use crate::runtime::client::{ModelState, Runtime, TrainStep};
use crate::runtime::trainer::{consolidate_states, Corpus, Trainer};
use crate::sched::Scheduler;
use crate::sim::engine::{self, SimConfig, SimResult};
use crate::sim::hadare_engine;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Corpus seed for one job — shared by BOTH emulation paths and the
/// quality evaluator so forked and unforked training see the same data
/// distribution (the eval stream itself uses an independent RNG).
pub fn corpus_seed(cfg_seed: u64, job: crate::jobs::job::JobId) -> u64 {
    cfg_seed ^ (job.0 << 4) ^ 0xDA7A
}

/// Emulation parameters: the virtual schedule plus the real-training knobs.
#[derive(Clone, Copy, Debug)]
pub struct EmulationConfig {
    /// The virtual round engine's parameters.
    pub sim: SimConfig,
    /// Virtual-step -> real-step down-sampling (e.g. 0.02 = 1 real step
    /// per 50 virtual iterations).
    pub steps_scale: f64,
    /// Cap on real steps per (job, round) so emulation stays tractable.
    pub max_real_steps_per_round: u64,
    /// SGD learning rate for the real steps.
    pub lr: f32,
    /// Seed for parameter init and data streams.
    pub seed: u64,
}

impl Default for EmulationConfig {
    fn default() -> Self {
        EmulationConfig {
            sim: SimConfig {
                slot_secs: 90.0,
                restart_overhead: 10.0,
                max_rounds: 2_000,
                horizon: 1e7,
            },
            steps_scale: 0.02,
            max_real_steps_per_round: 200,
            lr: 0.1,
            seed: 42,
        }
    }
}

/// A really-trained model at the end of an emulated run.
pub struct TrainedModel {
    /// The job this model belongs to.
    pub job: JobId,
    /// Lowered variant name that was trained.
    pub variant: String,
    /// Final parameters + momenta.
    pub state: ModelState,
    /// (cumulative real step, loss) curve.
    pub losses: Vec<(u64, f32)>,
    /// Real steps this job executed.
    pub real_steps: u64,
}

/// Emulation outcome: scheduling metrics + genuinely trained models.
pub struct EmulationResult {
    /// The virtual schedule's metrics.
    pub sim: SimResult,
    /// One trained model per job.
    pub models: Vec<TrainedModel>,
    /// Total real train steps executed through PJRT.
    pub total_real_steps: u64,
}

fn scale_steps(cfg: &EmulationConfig, virtual_steps: f64) -> u64 {
    ((virtual_steps * cfg.steps_scale).round() as u64)
        .min(cfg.max_real_steps_per_round)
}

/// Shared executable cache: one compiled TrainStep per variant.
pub struct ExecutablePool<'m> {
    runtime: Runtime,
    manifest: &'m Manifest,
    train: BTreeMap<String, TrainStep>,
}

impl<'m> ExecutablePool<'m> {
    /// Pool over one manifest with a fresh PJRT client.
    pub fn new(manifest: &'m Manifest) -> Result<Self> {
        Ok(ExecutablePool {
            runtime: Runtime::cpu()?,
            manifest,
            train: BTreeMap::new(),
        })
    }

    /// The pool's PJRT runtime.
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Variant lookup with a pool-level error.
    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.manifest
            .variant(name)
            .ok_or_else(|| anyhow!("variant '{name}' not in manifest"))
    }

    /// The compiled train-step for a variant (compiled on first use).
    pub fn train_step(&mut self, variant: &str) -> Result<&TrainStep> {
        if !self.train.contains_key(variant) {
            let v = self
                .manifest
                .variant(variant)
                .ok_or_else(|| anyhow!("variant '{variant}'"))?;
            let exe = self.runtime.load_train(v)?;
            self.train.insert(variant.to_string(), exe);
        }
        Ok(&self.train[variant])
    }
}

/// Run a non-forking scheduler (Hadar/Gavel/…) over `jobs` with real
/// training replay.
pub fn run_scheduler_emulation(
    jobs: &[Job], scheduler: &mut dyn Scheduler, cluster: &ClusterSpec,
    manifest: &Manifest, cfg: &EmulationConfig,
) -> Result<EmulationResult> {
    // 1) Virtual schedule.
    let mut queue = JobQueue::new();
    for j in jobs {
        queue.admit(j.clone())?;
    }
    let sim = engine::run(&mut queue, scheduler, cluster, &cfg.sim, true);

    // 2) Real-training replay, one Trainer per job, in round order.
    let mut pool = ExecutablePool::new(manifest)?;
    let mut trainers: BTreeMap<JobId, (String, Trainer)> = BTreeMap::new();
    for j in jobs {
        let vname = j.model.runtime_variant().to_string();
        let v = pool.variant(&vname)?;
        let state = pool.runtime().init_state(v, cfg.seed ^ j.id.0);
        trainers.insert(
            j.id,
            (vname.clone(),
             Trainer::new(state, v.vocab, corpus_seed(cfg.seed, j.id),
                          cfg.lr)),
        );
    }
    let mut total_real = 0u64;
    for rec in &sim.timeline {
        for (&id, rj) in &rec.jobs {
            let steps = scale_steps(cfg, rj.progressed);
            if steps == 0 {
                continue;
            }
            let (vname, trainer) =
                trainers.get_mut(&id).expect("trainer exists");
            let vname = vname.clone();
            let exe = pool.train_step(&vname)?;
            trainer.run_steps(exe, steps)?;
            total_real += steps;
        }
    }

    let models = trainers
        .into_iter()
        .map(|(id, (variant, t))| TrainedModel {
            job: id,
            variant,
            losses: t.losses.clone(),
            real_steps: t.steps_done,
            state: t.state,
        })
        .collect();
    Ok(EmulationResult {
        sim,
        models,
        total_real_steps: total_real,
    })
}

/// Run HadarE over `jobs` with real training + §V-B consolidation replay.
pub fn run_hadare_emulation(
    jobs: &[Job], cluster: &ClusterSpec, manifest: &Manifest,
    cfg: &EmulationConfig, copies: Option<u64>,
) -> Result<EmulationResult> {
    // 1) Virtual schedule with the per-copy work log.
    let hres = hadare_engine::run(jobs, cluster, &cfg.sim, copies);

    // 2) Replay with consolidation at each round boundary.
    let mut pool = ExecutablePool::new(manifest)?;
    // Parent state + corpus (shared across copies so data is the job's).
    struct ParentCtx {
        variant: String,
        state: ModelState,
        corpus: Corpus,
        rng: Rng,
        losses: Vec<(u64, f32)>,
        real_steps: u64,
    }
    let mut parents: BTreeMap<JobId, ParentCtx> = BTreeMap::new();
    for j in jobs {
        let vname = j.model.runtime_variant().to_string();
        let v = pool.variant(&vname)?;
        parents.insert(
            j.id,
            ParentCtx {
                variant: vname,
                state: pool.runtime().init_state(v, cfg.seed ^ j.id.0),
                corpus: Corpus::new(v.vocab, 4,
                                    corpus_seed(cfg.seed, j.id)),
                rng: Rng::new(cfg.seed ^ (j.id.0 << 8)),
                losses: Vec::new(),
                real_steps: 0,
            },
        );
    }

    // Group work log by round.
    let max_round = hres
        .work_log
        .iter()
        .map(|w| w.round)
        .max()
        .unwrap_or(0);
    let mut total_real = 0u64;
    for round in 0..=max_round {
        // parent -> [(copy steps real)]
        let mut by_parent: BTreeMap<JobId, Vec<u64>> = BTreeMap::new();
        for w in hres.work_log.iter().filter(|w| w.round == round) {
            let steps = scale_steps(cfg, w.steps);
            by_parent.entry(w.parent).or_default().push(steps);
        }
        for (pid, copy_steps) in by_parent {
            let pctx = parents.get_mut(&pid).expect("parent ctx");
            let vname = pctx.variant.clone();
            let total: u64 = copy_steps.iter().sum();
            if total == 0 {
                continue;
            }
            let v_vocab;
            let v_batch;
            let v_seq;
            {
                let v = pool.variant(&vname)?;
                v_vocab = v.vocab;
                v_batch = v.batch;
                v_seq = v.seq;
            }
            let _ = v_vocab;
            // Each copy clones the consolidated parent state, trains its
            // share on the parent's data stream, then the round closes
            // with a step-weighted average (§V-B).
            let mut copy_states: Vec<ModelState> = Vec::new();
            let mut weights: Vec<f64> = Vec::new();
            let mut last_losses: Vec<(u64, f32)> = Vec::new();
            for &steps in &copy_steps {
                if steps == 0 {
                    continue;
                }
                let mut st = ModelState {
                    params: clone_literals(&pctx.state.params)?,
                    momenta: clone_literals(&pctx.state.momenta)?,
                };
                let exe = pool.train_step(&vname)?;
                let mut last = f32::NAN;
                for _ in 0..steps {
                    let toks = pctx
                        .corpus
                        .batch(&mut pctx.rng, v_batch, v_seq + 1);
                    last = exe.step(&mut st, &toks, cfg.lr)?;
                    pctx.real_steps += 1;
                    total_real += 1;
                }
                last_losses.push((pctx.real_steps, last));
                copy_states.push(st);
                weights.push(steps as f64);
            }
            if copy_states.is_empty() {
                continue;
            }
            let refs: Vec<&ModelState> = copy_states.iter().collect();
            let v = pool.variant(&vname)?;
            let params = consolidate_states(&refs, &weights, v)?;
            // Momenta: consolidate the same way (keeps SGD state coherent).
            let flats: Vec<Vec<f32>> = copy_states
                .iter()
                .map(|s| crate::runtime::client::flatten_params(&s.momenta))
                .collect::<Result<_>>()?;
            let avg =
                crate::forking::tracker::consolidate_weights(&flats, &weights);
            let momenta =
                crate::runtime::client::unflatten_params(&avg, v)?;
            pctx.state = ModelState { params, momenta };
            pctx.losses.extend(last_losses);
        }
    }

    let models = parents
        .into_iter()
        .map(|(id, p)| TrainedModel {
            job: id,
            variant: p.variant,
            state: p.state,
            losses: p.losses,
            real_steps: p.real_steps,
        })
        .collect();
    Ok(EmulationResult {
        sim: hres.sim,
        models,
        total_real_steps: total_real,
    })
}

/// Deep-copy literals through host vectors.
fn clone_literals(lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    lits.iter()
        .map(|l| {
            let shape = l
                .shape()
                .map_err(|e| anyhow!("literal shape: {e:?}"))?;
            let dims: Vec<usize> = match &shape {
                xla::Shape::Array(a) => {
                    a.dims().iter().map(|&d| d as usize).collect()
                }
                _ => return Err(anyhow!("non-array literal")),
            };
            let data = l
                .to_vec::<f32>()
                .map_err(|e| anyhow!("literal data: {e:?}"))?;
            Ok(crate::runtime::client::literal_f32(&data, &dims))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_steps_rounds_and_caps() {
        let cfg = EmulationConfig {
            steps_scale: 0.1,
            max_real_steps_per_round: 5,
            ..Default::default()
        };
        assert_eq!(scale_steps(&cfg, 0.0), 0);
        assert_eq!(scale_steps(&cfg, 20.0), 2);
        assert_eq!(scale_steps(&cfg, 1000.0), 5); // capped
    }
}
