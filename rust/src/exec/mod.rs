//! Physical-cluster *emulation* (paper §VI): real DL training executed
//! through the PJRT runtime on virtual-clock heterogeneous nodes.
//!
//! The schedule (who trains where, each round) comes from the same
//! engines as the pure simulation; this layer replays it with **real**
//! train steps so Table IV's model-quality comparison and the loss curves
//! of the end-to-end example are genuine measurements, not simulations.
//! Virtual steps are down-sampled to real steps by `steps_scale`
//! (DESIGN.md §Substitutions — the paper's multi-hour GPU workloads would
//! not fit a single-CPU sandbox otherwise).

pub mod emulation;
pub mod quality;

pub use emulation::{EmulationConfig, EmulationResult, TrainedModel};
pub use quality::{evaluate_quality, QualityReport, QualityRow};
