//! Declarative scenario / sweep specifications.
//!
//! A [`SweepSpec`] names the axes of an experiment grid; [`SweepSpec::expand`]
//! takes the cartesian product into concrete [`ScenarioSpec`]s in a stable
//! order (cluster, workload, slot, seed, scheduler — scheduler innermost so
//! the existing figures' row orders are preserved). Specs round-trip
//! through the repo's own [`crate::util::json`], so sweeps can be loaded
//! from a JSON file (`hadar sweep --spec grid.json`).

use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::Job;
use crate::sim::engine::SimConfig;
use crate::trace::philly::{generate, TraceConfig};
use crate::trace::workload::{materialize, physical_jobs};
use crate::util::json::{self, Json};

/// A cluster, either by preset name (`"sim60"`, `"aws5"`, `"testbed5"`,
/// `"motivational"`, `"scaled:<nodes_per_type>x<gpus_per_node>"`) or as an
/// inline [`ClusterSpec`] JSON object.
#[derive(Clone, Debug)]
pub enum ClusterRef {
    Preset(String),
    Inline(ClusterSpec),
}

impl ClusterRef {
    /// Stable label used in scenario ids and artifact records.
    pub fn label(&self) -> String {
        match self {
            ClusterRef::Preset(name) => name.clone(),
            ClusterRef::Inline(c) => c.name.clone(),
        }
    }

    /// Materialise the actual cluster.
    pub fn resolve(&self) -> Result<ClusterSpec, String> {
        match self {
            ClusterRef::Preset(name) => preset(name),
            ClusterRef::Inline(c) => Ok(c.clone()),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ClusterRef::Preset(name) => Json::Str(name.clone()),
            ClusterRef::Inline(c) => c.to_json(),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v {
            Json::Str(name) => {
                // Validate eagerly so bad spec files fail at parse time.
                preset(name)?;
                Ok(ClusterRef::Preset(name.clone()))
            }
            Json::Obj(_) => Ok(ClusterRef::Inline(ClusterSpec::from_json(v)?)),
            _ => Err("cluster: expected a preset name or an inline cluster \
                      object"
                .into()),
        }
    }
}

/// Resolve a cluster preset name.
pub fn preset(name: &str) -> Result<ClusterSpec, String> {
    match name {
        "sim60" => Ok(ClusterSpec::sim60()),
        "aws5" => Ok(ClusterSpec::aws5()),
        "testbed5" => Ok(ClusterSpec::testbed5()),
        "motivational" => Ok(ClusterSpec::motivational()),
        other => {
            if let Some(rest) = other.strip_prefix("scaled:") {
                if let Some((a, b)) = rest.split_once('x') {
                    let npt: usize = a
                        .parse()
                        .map_err(|_| format!("bad scaled preset '{other}'"))?;
                    let gpn: usize = b
                        .parse()
                        .map_err(|_| format!("bad scaled preset '{other}'"))?;
                    if npt == 0 || gpn == 0 {
                        return Err(format!("bad scaled preset '{other}'"));
                    }
                    return Ok(ClusterSpec::scaled(npt, gpn));
                }
            }
            Err(format!(
                "unknown cluster preset '{other}' (known: sim60, aws5, \
                 testbed5, motivational, scaled:<n>x<g>)"
            ))
        }
    }
}

/// What jobs a scenario runs.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSpec {
    /// Philly-shaped synthetic trace (Figs. 3-5): `trace::philly::generate`
    /// + `trace::workload::materialize`, with the optional epoch scaling
    /// the trace figures use for fast runs.
    Trace {
        n_jobs: usize,
        max_gpus: usize,
        all_at_start: bool,
        hours_scale: f64,
    },
    /// Physical workload mix `M-1` … `M-12` (Figs. 8-12):
    /// `trace::workload::physical_jobs`.
    Mix { name: String, epochs_scale: f64 },
}

impl WorkloadSpec {
    /// Stable label used in scenario ids and artifact records.
    pub fn label(&self) -> String {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => {
                let arrivals = if *all_at_start { "" } else { "+poisson" };
                format!("trace{n_jobs}x{max_gpus}@{hours_scale}{arrivals}")
            }
            // Bare mix name at the paper's scale (what the figures use);
            // a non-default scale must show up so ids stay unique.
            WorkloadSpec::Mix { name, epochs_scale } => {
                if *epochs_scale == 1.0 {
                    name.clone()
                } else {
                    format!("{name}@{epochs_scale}")
                }
            }
        }
    }

    /// Build the scenario's job list (deterministic in `seed`).
    pub fn build_jobs(&self, cluster: &ClusterSpec, seed: u64)
                      -> Result<Vec<Job>, String> {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => {
                let trace = generate(&TraceConfig {
                    n_jobs: *n_jobs,
                    seed,
                    all_at_start: *all_at_start,
                    max_gpus: *max_gpus,
                    ..Default::default()
                });
                let mut jobs = materialize(&trace, cluster, seed);
                if *hours_scale != 1.0 {
                    for j in &mut jobs {
                        j.epochs = ((j.epochs as f64 * hours_scale).ceil()
                            as u64)
                            .max(1);
                    }
                }
                Ok(jobs)
            }
            WorkloadSpec::Mix { name, epochs_scale } => {
                physical_jobs(name, cluster, *epochs_scale)
                    .ok_or_else(|| format!("unknown workload mix '{name}'"))
            }
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => Json::obj()
                .set("kind", "trace")
                .set("n_jobs", *n_jobs)
                .set("max_gpus", *max_gpus)
                .set("all_at_start", *all_at_start)
                .set("hours_scale", *hours_scale),
            WorkloadSpec::Mix { name, epochs_scale } => Json::obj()
                .set("kind", "mix")
                .set("name", name.as_str())
                .set("epochs_scale", *epochs_scale),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v.get("kind").as_str() {
            Some("trace") => Ok(WorkloadSpec::Trace {
                n_jobs: v
                    .get("n_jobs")
                    .as_usize()
                    .ok_or("trace workload: 'n_jobs' must be a number")?,
                max_gpus: v.get("max_gpus").as_usize().unwrap_or(8),
                all_at_start: v.get("all_at_start").as_bool().unwrap_or(true),
                hours_scale: v.get("hours_scale").as_f64().unwrap_or(1.0),
            }),
            Some("mix") => {
                let name = v
                    .get("name")
                    .as_str()
                    .ok_or("mix workload: 'name' must be a string")?
                    .to_string();
                // Fail at parse time, not scenarios deep into a sweep.
                if crate::trace::workload::mix(&name).is_none() {
                    return Err(format!("unknown workload mix '{name}'"));
                }
                Ok(WorkloadSpec::Mix {
                    name,
                    epochs_scale: v.get("epochs_scale").as_f64().unwrap_or(1.0),
                })
            }
            _ => Err("workload: 'kind' must be \"trace\" or \"mix\"".into()),
        }
    }
}

// ----------------------------------------------------------- SimConfig JSON

/// Emit a [`SimConfig`] (used by sweep specs and artifact manifests).
pub fn sim_to_json(cfg: &SimConfig) -> Json {
    Json::obj()
        .set("slot_secs", cfg.slot_secs)
        .set("restart_overhead", cfg.restart_overhead)
        .set("max_rounds", cfg.max_rounds)
        .set("horizon", cfg.horizon)
}

/// Parse a [`SimConfig`], taking missing fields from `base`.
pub fn sim_from_json(v: &Json, base: SimConfig) -> SimConfig {
    SimConfig {
        slot_secs: v.get("slot_secs").as_f64().unwrap_or(base.slot_secs),
        restart_overhead: v
            .get("restart_overhead")
            .as_f64()
            .unwrap_or(base.restart_overhead),
        max_rounds: v.get("max_rounds").as_u64().unwrap_or(base.max_rounds),
        horizon: v.get("horizon").as_f64().unwrap_or(base.horizon),
    }
}

// -------------------------------------------------------------- ScenarioSpec

/// One fully-specified simulation scenario. `sim.slot_secs` is
/// authoritative (the sweep's slot axis writes into it).
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub scheduler: String,
    pub cluster: ClusterRef,
    pub workload: WorkloadSpec,
    pub seed: u64,
    pub sim: SimConfig,
}

impl ScenarioSpec {
    /// Stable, human-readable unique id within a sweep.
    pub fn id(&self) -> String {
        format!(
            "{}/{}/{}/slot{}/seed{}",
            self.scheduler,
            self.cluster.label(),
            self.workload.label(),
            self.sim.slot_secs,
            self.seed
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheduler", self.scheduler.as_str())
            .set("cluster", self.cluster.to_json())
            .set("workload", self.workload.to_json())
            .set("seed", self.seed)
            .set("sim", sim_to_json(&self.sim))
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let scheduler = v
            .get("scheduler")
            .as_str()
            .ok_or("scenario: 'scheduler' must be a string")?
            .to_string();
        if !crate::sched::is_known(&scheduler) {
            return Err(format!("unknown scheduler '{scheduler}'"));
        }
        Ok(ScenarioSpec {
            scheduler,
            cluster: ClusterRef::from_json(v.get("cluster"))?,
            workload: WorkloadSpec::from_json(v.get("workload"))?,
            seed: v.get("seed").as_u64().unwrap_or(42),
            sim: sim_from_json(v.get("sim"), SimConfig::default()),
        })
    }
}

// ----------------------------------------------------------------- SweepSpec

/// A declarative experiment grid: the cartesian product of every axis.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    pub schedulers: Vec<String>,
    pub clusters: Vec<ClusterRef>,
    pub workloads: Vec<WorkloadSpec>,
    /// Slot lengths `L` (seconds); each writes into `base.slot_secs`.
    pub slots_secs: Vec<f64>,
    pub seeds: Vec<u64>,
    /// Base simulation config (slot overridden per scenario).
    pub base: SimConfig,
}

impl SweepSpec {
    /// Number of scenarios `expand` will produce.
    pub fn n_scenarios(&self) -> usize {
        self.schedulers.len()
            * self.clusters.len()
            * self.workloads.len()
            * self.slots_secs.len()
            * self.seeds.len()
    }

    /// Cartesian expansion in a stable order: cluster, workload, slot,
    /// seed, scheduler (innermost) — the nesting the hand-rolled figure
    /// loops used, so refactored figures keep their row order.
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut out = Vec::with_capacity(self.n_scenarios());
        for cluster in &self.clusters {
            for workload in &self.workloads {
                for &slot in &self.slots_secs {
                    for &seed in &self.seeds {
                        for sched in &self.schedulers {
                            let mut sim = self.base;
                            sim.slot_secs = slot;
                            out.push(ScenarioSpec {
                                scheduler: sched.clone(),
                                cluster: cluster.clone(),
                                workload: workload.clone(),
                                seed,
                                sim,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Built-in demonstration grid: the four generic schedulers over a
    /// scaled-down Philly trace on `sim60`, two slot lengths x two seeds —
    /// a 16-scenario sweep that finishes in seconds (`hadar sweep` with no
    /// `--spec`, and the `sweep_throughput` bench).
    pub fn demo() -> SweepSpec {
        SweepSpec {
            name: "demo16".into(),
            schedulers: crate::sched::SCHEDULER_NAMES
                .iter()
                .map(|s| s.to_string())
                .collect(),
            clusters: vec![ClusterRef::Preset("sim60".into())],
            workloads: vec![WorkloadSpec::Trace {
                n_jobs: 60,
                max_gpus: 8,
                all_at_start: true,
                hours_scale: 0.2,
            }],
            slots_secs: vec![180.0, 360.0],
            seeds: vec![7, 11],
            base: SimConfig {
                slot_secs: 360.0,
                restart_overhead: 10.0,
                max_rounds: 50_000,
                horizon: 30.0 * 24.0 * 3600.0,
            },
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set(
                "schedulers",
                Json::Arr(
                    self.schedulers
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set(
                "clusters",
                Json::Arr(self.clusters.iter().map(|c| c.to_json()).collect()),
            )
            .set(
                "workloads",
                Json::Arr(
                    self.workloads.iter().map(|w| w.to_json()).collect(),
                ),
            )
            .set("slots_secs", self.slots_secs.clone())
            .set(
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::from(s)).collect()),
            )
            .set("sim", sim_to_json(&self.base))
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let base = sim_from_json(v.get("sim"), SimConfig::default());
        let schedulers: Vec<String> = v
            .get("schedulers")
            .as_arr()
            .ok_or("sweep: 'schedulers' must be an array")?
            .iter()
            .map(|s| {
                let name = s
                    .as_str()
                    .ok_or("sweep: scheduler names must be strings")?;
                if !crate::sched::is_known(name) {
                    return Err(format!(
                        "unknown scheduler '{name}' (known: yarn-cs, \
                         tiresias, gavel, hadar, hadare)"
                    ));
                }
                Ok(name.to_string())
            })
            .collect::<Result<_, _>>()?;
        let clusters: Vec<ClusterRef> = v
            .get("clusters")
            .as_arr()
            .ok_or("sweep: 'clusters' must be an array")?
            .iter()
            .map(ClusterRef::from_json)
            .collect::<Result<_, _>>()?;
        let workloads: Vec<WorkloadSpec> = v
            .get("workloads")
            .as_arr()
            .ok_or("sweep: 'workloads' must be an array")?
            .iter()
            .map(WorkloadSpec::from_json)
            .collect::<Result<_, _>>()?;
        let slots_secs: Vec<f64> = match v.get("slots_secs").as_arr() {
            Some(a) => a
                .iter()
                .map(|s| {
                    s.as_f64().ok_or_else(|| {
                        "sweep: 'slots_secs' must be numbers".to_string()
                    })
                })
                .collect::<Result<_, _>>()?,
            None => vec![base.slot_secs],
        };
        let seeds: Vec<u64> = match v.get("seeds").as_arr() {
            Some(a) => a
                .iter()
                .map(|s| {
                    s.as_u64().ok_or_else(|| {
                        "sweep: 'seeds' must be integers".to_string()
                    })
                })
                .collect::<Result<_, _>>()?,
            None => vec![42],
        };
        if schedulers.is_empty()
            || clusters.is_empty()
            || workloads.is_empty()
            || slots_secs.is_empty()
            || seeds.is_empty()
        {
            return Err("sweep: 'schedulers', 'clusters', 'workloads', \
                        'slots_secs', and 'seeds' must be non-empty"
                .into());
        }
        Ok(SweepSpec {
            name: v.get("name").as_str().unwrap_or("sweep").to_string(),
            schedulers,
            clusters,
            workloads,
            slots_secs,
            seeds,
            base,
        })
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(preset("sim60").unwrap().total_gpus(), 60);
        assert_eq!(preset("aws5").unwrap().total_gpus(), 5);
        assert_eq!(preset("scaled:2x4").unwrap().total_gpus(), 2 * 4 * 3);
        assert!(preset("nope").is_err());
        assert!(preset("scaled:0x4").is_err());
        assert!(preset("scaled:abc").is_err());
    }

    #[test]
    fn demo_grid_is_16_scenarios_with_unique_ids() {
        let spec = SweepSpec::demo();
        assert_eq!(spec.n_scenarios(), 16);
        let scenarios = spec.expand();
        assert_eq!(scenarios.len(), 16);
        let mut ids: Vec<String> = scenarios.iter().map(|s| s.id()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "scenario ids must be unique");
    }

    #[test]
    fn expansion_order_is_stable_and_scheduler_innermost() {
        let spec = SweepSpec::demo();
        let a = spec.expand();
        let b = spec.expand();
        assert!(a.iter().zip(&b).all(|(x, y)| x.id() == y.id()));
        // Scheduler varies fastest.
        assert_eq!(a[0].scheduler, "yarn-cs");
        assert_eq!(a[1].scheduler, "tiresias");
        assert_eq!(a[2].scheduler, "gavel");
        assert_eq!(a[3].scheduler, "hadar");
        // Then seed.
        assert_eq!(a[0].seed, 7);
        assert_eq!(a[4].seed, 11);
        // Then slot.
        assert_eq!(a[0].sim.slot_secs, 180.0);
        assert_eq!(a[8].sim.slot_secs, 360.0);
    }

    #[test]
    fn sweep_json_roundtrip() {
        let spec = SweepSpec::demo();
        let text = spec.to_json().pretty();
        let back = SweepSpec::parse(&text).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.n_scenarios(), spec.n_scenarios());
        let ids_a: Vec<String> =
            spec.expand().iter().map(|s| s.id()).collect();
        let ids_b: Vec<String> =
            back.expand().iter().map(|s| s.id()).collect();
        assert_eq!(ids_a, ids_b);
        assert_eq!(back.base.max_rounds, spec.base.max_rounds);
        assert_eq!(back.base.horizon, spec.base.horizon);
    }

    #[test]
    fn scenario_json_roundtrip_with_inline_cluster() {
        let s = ScenarioSpec {
            scheduler: "hadar".into(),
            cluster: ClusterRef::Inline(ClusterSpec::testbed5()),
            workload: WorkloadSpec::Mix {
                name: "M-5".into(),
                epochs_scale: 1.0,
            },
            seed: 9,
            sim: SimConfig::default(),
        };
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.id(), s.id());
        assert_eq!(back.cluster.resolve().unwrap().total_gpus(), 5);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(SweepSpec::parse("{}").is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["nope"],
                "workloads":[{"kind":"mix","name":"M-1"}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"bogus"}]}"#
        )
        .is_err());
        // Typos in scheduler / mix names fail at parse time, not after
        // half the sweep has run.
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadarr"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-99"}]}"#
        )
        .is_err());
        // Explicitly empty axes must not silently expand to 0 scenarios.
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],"seeds":[]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],"slots_secs":[]}"#
        )
        .is_err());
    }

    #[test]
    fn trace_labels_distinguish_every_field() {
        let base = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let more_gpus = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let poisson = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: false,
            hours_scale: 1.0,
        };
        let scaled = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 0.5,
        };
        let labels = [
            base.label(),
            more_gpus.label(),
            poisson.label(),
            scaled.label(),
        ];
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(labels[i], labels[j]);
            }
        }
    }

    #[test]
    fn trace_workload_builds_scaled_jobs() {
        let cluster = preset("sim60").unwrap();
        let full = WorkloadSpec::Trace {
            n_jobs: 20,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let scaled = WorkloadSpec::Trace {
            n_jobs: 20,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 0.2,
        };
        let a = full.build_jobs(&cluster, 42).unwrap();
        let b = scaled.build_jobs(&cluster, 42).unwrap();
        assert_eq!(a.len(), 20);
        assert_eq!(b.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(y.epochs,
                       ((x.epochs as f64 * 0.2).ceil() as u64).max(1));
        }
    }

    #[test]
    fn mix_labels_stay_bare_at_paper_scale() {
        let paper = WorkloadSpec::Mix {
            name: "M-5".into(),
            epochs_scale: 1.0,
        };
        let scaled = WorkloadSpec::Mix {
            name: "M-5".into(),
            epochs_scale: 0.5,
        };
        // Figures key their cells on the bare mix name.
        assert_eq!(paper.label(), "M-5");
        assert_ne!(paper.label(), scaled.label());
    }

    #[test]
    fn mix_workload_rejects_unknown_mix() {
        let cluster = preset("aws5").unwrap();
        let w = WorkloadSpec::Mix {
            name: "M-99".into(),
            epochs_scale: 1.0,
        };
        assert!(w.build_jobs(&cluster, 0).is_err());
    }
}
