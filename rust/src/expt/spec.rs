//! Declarative scenario / sweep specifications.
//!
//! A [`SweepSpec`] names the axes of an experiment grid; [`SweepSpec::expand`]
//! takes the cartesian product into concrete [`ScenarioSpec`]s in a stable
//! order (cluster, workload, events, slot, seed, scheduler — scheduler
//! innermost so the existing figures' row orders are preserved). Specs
//! round-trip through the repo's own [`crate::util::json`], so sweeps can
//! be loaded from a JSON file (`hadar sweep --spec grid.json`).
//!
//! The `events` axis makes the cluster *dynamic*: each entry is either an
//! explicit [`EventTimeline`] or a seeded [`ChurnConfig`] generator, so a
//! sweep can replay every scheduler against the same churn trace (see
//! `docs/simulation.md`).

use crate::cluster::events::{
    generate_churn, ChurnConfig, EventTimeline,
};
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::Job;
use crate::sim::engine::SimConfig;
use crate::trace::philly::{generate, TraceConfig};
use crate::trace::workload::{materialize, physical_jobs};
use crate::util::json::{self, Json};

/// A cluster, either by preset name (`"sim60"`, `"aws5"`, `"testbed5"`,
/// `"motivational"`, `"scaled:<nodes_per_type>x<gpus_per_node>"`,
/// `"big8"`, `"big:<nodes>x<gpus_per_pool>"`) or as an inline
/// [`ClusterSpec`] JSON object.
#[derive(Clone, Debug)]
pub enum ClusterRef {
    /// A named preset (resolved by [`preset`]).
    Preset(String),
    /// A fully-specified inline cluster.
    Inline(ClusterSpec),
}

impl ClusterRef {
    /// Stable label used in scenario ids and artifact records.
    pub fn label(&self) -> String {
        match self {
            ClusterRef::Preset(name) => name.clone(),
            ClusterRef::Inline(c) => c.name.clone(),
        }
    }

    /// Materialise the actual cluster.
    pub fn resolve(&self) -> Result<ClusterSpec, String> {
        match self {
            ClusterRef::Preset(name) => preset(name),
            ClusterRef::Inline(c) => Ok(c.clone()),
        }
    }

    /// Emit as JSON (a preset name string or an inline cluster object).
    pub fn to_json(&self) -> Json {
        match self {
            ClusterRef::Preset(name) => Json::Str(name.clone()),
            ClusterRef::Inline(c) => c.to_json(),
        }
    }

    /// Parse from JSON; preset names are validated eagerly.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v {
            Json::Str(name) => {
                // Validate eagerly so bad spec files fail at parse time.
                preset(name)?;
                Ok(ClusterRef::Preset(name.clone()))
            }
            Json::Obj(_) => Ok(ClusterRef::Inline(ClusterSpec::from_json(v)?)),
            _ => Err("cluster: expected a preset name or an inline cluster \
                      object"
                .into()),
        }
    }
}

/// Resolve a cluster preset name.
pub fn preset(name: &str) -> Result<ClusterSpec, String> {
    match name {
        "sim60" => Ok(ClusterSpec::sim60()),
        "aws5" => Ok(ClusterSpec::aws5()),
        "testbed5" => Ok(ClusterSpec::testbed5()),
        "motivational" => Ok(ClusterSpec::motivational()),
        "big8" => Ok(ClusterSpec::big8()),
        other => {
            if let Some(rest) = other.strip_prefix("scaled:") {
                if let Some((a, b)) = rest.split_once('x') {
                    let npt: usize = a
                        .parse()
                        .map_err(|_| format!("bad scaled preset '{other}'"))?;
                    let gpn: usize = b
                        .parse()
                        .map_err(|_| format!("bad scaled preset '{other}'"))?;
                    if npt == 0 || gpn == 0 {
                        return Err(format!("bad scaled preset '{other}'"));
                    }
                    return Ok(ClusterSpec::scaled(npt, gpn));
                }
            }
            if let Some(rest) = other.strip_prefix("big:") {
                if let Some((a, b)) = rest.split_once('x') {
                    let n: usize = a
                        .parse()
                        .map_err(|_| format!("bad big preset '{other}'"))?;
                    let gpp: usize = b
                        .parse()
                        .map_err(|_| format!("bad big preset '{other}'"))?;
                    if n == 0 || gpp == 0 {
                        return Err(format!("bad big preset '{other}'"));
                    }
                    return Ok(ClusterSpec::big(n, gpp));
                }
            }
            Err(format!(
                "unknown cluster preset '{other}' (known: sim60, aws5, \
                 testbed5, motivational, scaled:<n>x<g>, big8, big:<n>x<g>)"
            ))
        }
    }
}

/// What jobs a scenario runs.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadSpec {
    /// Philly-shaped synthetic trace (Figs. 3-5): `trace::philly::generate`
    /// + `trace::workload::materialize`, with the optional epoch scaling
    /// the trace figures use for fast runs.
    Trace {
        /// Number of trace jobs.
        n_jobs: usize,
        /// Cap on requested gang sizes.
        max_gpus: usize,
        /// All jobs at t=0 (paper §IV-A) vs Poisson arrivals.
        all_at_start: bool,
        /// Scale on job GPU-hours (1.0 = paper magnitude).
        hours_scale: f64,
    },
    /// Physical workload mix `M-1` … `M-12` (Figs. 8-12):
    /// `trace::workload::physical_jobs`.
    Mix {
        /// Mix name (`"M-1"` … `"M-12"`).
        name: String,
        /// Scale on job epochs (1.0 = paper magnitude).
        epochs_scale: f64,
    },
}

impl WorkloadSpec {
    /// Stable label used in scenario ids and artifact records.
    pub fn label(&self) -> String {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => {
                let arrivals = if *all_at_start { "" } else { "+poisson" };
                format!("trace{n_jobs}x{max_gpus}@{hours_scale}{arrivals}")
            }
            // Bare mix name at the paper's scale (what the figures use);
            // a non-default scale must show up so ids stay unique.
            WorkloadSpec::Mix { name, epochs_scale } => {
                if *epochs_scale == 1.0 {
                    name.clone()
                } else {
                    format!("{name}@{epochs_scale}")
                }
            }
        }
    }

    /// Build the scenario's job list (deterministic in `seed`).
    pub fn build_jobs(&self, cluster: &ClusterSpec, seed: u64)
                      -> Result<Vec<Job>, String> {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => {
                let trace = generate(&TraceConfig {
                    n_jobs: *n_jobs,
                    seed,
                    all_at_start: *all_at_start,
                    max_gpus: *max_gpus,
                    ..Default::default()
                });
                let mut jobs = materialize(&trace, cluster, seed);
                if *hours_scale != 1.0 {
                    for j in &mut jobs {
                        j.epochs = ((j.epochs as f64 * hours_scale).ceil()
                            as u64)
                            .max(1);
                    }
                }
                Ok(jobs)
            }
            WorkloadSpec::Mix { name, epochs_scale } => {
                physical_jobs(name, cluster, *epochs_scale)
                    .ok_or_else(|| format!("unknown workload mix '{name}'"))
            }
        }
    }

    /// Emit as JSON (tagged by `kind`).
    pub fn to_json(&self) -> Json {
        match self {
            WorkloadSpec::Trace {
                n_jobs,
                max_gpus,
                all_at_start,
                hours_scale,
            } => Json::obj()
                .set("kind", "trace")
                .set("n_jobs", *n_jobs)
                .set("max_gpus", *max_gpus)
                .set("all_at_start", *all_at_start)
                .set("hours_scale", *hours_scale),
            WorkloadSpec::Mix { name, epochs_scale } => Json::obj()
                .set("kind", "mix")
                .set("name", name.as_str())
                .set("epochs_scale", *epochs_scale),
        }
    }

    /// Parse from JSON; workload names are validated eagerly.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v.get("kind").as_str() {
            Some("trace") => Ok(WorkloadSpec::Trace {
                n_jobs: v
                    .get("n_jobs")
                    .as_usize()
                    .ok_or("trace workload: 'n_jobs' must be a number")?,
                max_gpus: v.get("max_gpus").as_usize().unwrap_or(8),
                all_at_start: v.get("all_at_start").as_bool().unwrap_or(true),
                hours_scale: v.get("hours_scale").as_f64().unwrap_or(1.0),
            }),
            Some("mix") => {
                let name = v
                    .get("name")
                    .as_str()
                    .ok_or("mix workload: 'name' must be a string")?
                    .to_string();
                // Fail at parse time, not scenarios deep into a sweep.
                if crate::trace::workload::mix(&name).is_none() {
                    return Err(format!("unknown workload mix '{name}'"));
                }
                Ok(WorkloadSpec::Mix {
                    name,
                    epochs_scale: v.get("epochs_scale").as_f64().unwrap_or(1.0),
                })
            }
            _ => Err("workload: 'kind' must be \"trace\" or \"mix\"".into()),
        }
    }
}

// ------------------------------------------------------------- EventsRef

/// What cluster events a scenario runs under: nothing (a static cluster),
/// an explicit [`EventTimeline`], or a seeded [`ChurnConfig`] generator
/// (expanded against the scenario's resolved cluster at run time, so the
/// same spec entry yields the *identical* trace for every scheduler).
#[derive(Clone, Debug)]
pub enum EventsRef {
    /// Static cluster (the default; scenario ids stay unchanged).
    None,
    /// Explicit event list.
    Inline(EventTimeline),
    /// Deterministic seeded churn generator.
    Churn(ChurnConfig),
}

impl EventsRef {
    /// Stable label used in scenario ids and artifact records. Churn
    /// labels encode *every* generator field, so two churn entries in one
    /// sweep never collide to the same scenario id / report group unless
    /// they really are the same trace.
    pub fn label(&self) -> String {
        match self {
            EventsRef::None => "none".into(),
            EventsRef::Inline(t) => {
                if t.name.is_empty() {
                    format!("ev{}", t.events.len())
                } else {
                    t.name.clone()
                }
            }
            EventsRef::Churn(c) => format!(
                "churn-s{}-i{}-d{}-{}-l{}-h{}",
                c.seed,
                c.mean_interval_secs,
                c.min_down_secs,
                c.max_down_secs,
                c.leave_fraction,
                c.horizon_secs
            ),
        }
    }

    /// Materialise the timeline for one resolved cluster.
    pub fn build(&self, cluster: &ClusterSpec)
                 -> Result<EventTimeline, String> {
        match self {
            EventsRef::None => Ok(EventTimeline::empty()),
            EventsRef::Inline(t) => Ok(t.clone()),
            EventsRef::Churn(c) => Ok(generate_churn(cluster, c)),
        }
    }

    /// Emit as JSON (`"none"`, a tagged timeline, or a tagged generator).
    pub fn to_json(&self) -> Json {
        match self {
            EventsRef::None => Json::Str("none".into()),
            EventsRef::Inline(t) => t.to_json().set("kind", "timeline"),
            EventsRef::Churn(c) => c.to_json().set("kind", "churn"),
        }
    }

    /// Parse from JSON; `null`/missing means a static cluster.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        match v {
            Json::Null => Ok(EventsRef::None),
            Json::Str(s) if s == "none" => Ok(EventsRef::None),
            Json::Obj(_) => match v.get("kind").as_str() {
                Some("timeline") => {
                    Ok(EventsRef::Inline(EventTimeline::from_json(v)?))
                }
                Some("churn") => {
                    Ok(EventsRef::Churn(ChurnConfig::from_json(v)?))
                }
                other => Err(format!(
                    "events: 'kind' must be \"timeline\" or \"churn\", \
                     got {other:?}"
                )),
            },
            _ => Err("events: expected \"none\" or an object".into()),
        }
    }
}

// ----------------------------------------------------------- SimConfig JSON

/// Emit a [`SimConfig`] (used by sweep specs and artifact manifests).
pub fn sim_to_json(cfg: &SimConfig) -> Json {
    Json::obj()
        .set("slot_secs", cfg.slot_secs)
        .set("restart_overhead", cfg.restart_overhead)
        .set("max_rounds", cfg.max_rounds)
        .set("horizon", cfg.horizon)
}

/// Parse a [`SimConfig`], taking missing fields from `base`.
pub fn sim_from_json(v: &Json, base: SimConfig) -> SimConfig {
    SimConfig {
        slot_secs: v.get("slot_secs").as_f64().unwrap_or(base.slot_secs),
        restart_overhead: v
            .get("restart_overhead")
            .as_f64()
            .unwrap_or(base.restart_overhead),
        max_rounds: v.get("max_rounds").as_u64().unwrap_or(base.max_rounds),
        horizon: v.get("horizon").as_f64().unwrap_or(base.horizon),
    }
}

// -------------------------------------------------------------- ScenarioSpec

/// One fully-specified simulation scenario. `sim.slot_secs` is
/// authoritative (the sweep's slot axis writes into it).
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Scheduler name (see [`crate::sched::by_name`]; `hadare` and
    /// `hadare-shared` route through the forking engine — the latter with
    /// partial-node per-pool gangs).
    pub scheduler: String,
    /// The cluster to simulate on.
    pub cluster: ClusterRef,
    /// The jobs to run.
    pub workload: WorkloadSpec,
    /// Workload seed (trace generation / materialisation).
    pub seed: u64,
    /// Engine parameters (`slot_secs` set by the sweep's slot axis).
    pub sim: SimConfig,
    /// Cluster events the scenario runs under.
    pub events: EventsRef,
}

impl ScenarioSpec {
    /// Stable, human-readable unique id within a sweep. Static-cluster
    /// scenarios keep the historical five-part form; an events axis
    /// appends its label.
    pub fn id(&self) -> String {
        let base = format!(
            "{}/{}/{}/slot{}/seed{}",
            self.scheduler,
            self.cluster.label(),
            self.workload.label(),
            self.sim.slot_secs,
            self.seed
        );
        match &self.events {
            EventsRef::None => base,
            e => format!("{base}/{}", e.label()),
        }
    }

    /// Emit as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scheduler", self.scheduler.as_str())
            .set("cluster", self.cluster.to_json())
            .set("workload", self.workload.to_json())
            .set("seed", self.seed)
            .set("sim", sim_to_json(&self.sim))
            .set("events", self.events.to_json())
    }

    /// Parse from JSON (missing `events` means a static cluster).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let scheduler = v
            .get("scheduler")
            .as_str()
            .ok_or("scenario: 'scheduler' must be a string")?
            .to_string();
        if !crate::sched::is_known(&scheduler) {
            return Err(format!("unknown scheduler '{scheduler}'"));
        }
        Ok(ScenarioSpec {
            scheduler,
            cluster: ClusterRef::from_json(v.get("cluster"))?,
            workload: WorkloadSpec::from_json(v.get("workload"))?,
            seed: v.get("seed").as_u64().unwrap_or(42),
            sim: sim_from_json(v.get("sim"), SimConfig::default()),
            events: EventsRef::from_json(v.get("events"))?,
        })
    }
}

// ----------------------------------------------------------------- SweepSpec

/// A declarative experiment grid: the cartesian product of every axis.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Sweep label (artifact manifests, reports).
    pub name: String,
    /// Scheduler-name axis.
    pub schedulers: Vec<String>,
    /// Cluster axis.
    pub clusters: Vec<ClusterRef>,
    /// Workload axis.
    pub workloads: Vec<WorkloadSpec>,
    /// Slot lengths `L` (seconds); each writes into `base.slot_secs`.
    pub slots_secs: Vec<f64>,
    /// Workload-seed axis.
    pub seeds: Vec<u64>,
    /// Cluster-events axis (`[EventsRef::None]` = the static grid).
    pub events: Vec<EventsRef>,
    /// Base simulation config (slot overridden per scenario).
    pub base: SimConfig,
    /// Write one per-round telemetry JSONL stream per scenario next to
    /// the sweep artifacts (see `docs/observability.md`).
    pub telemetry: bool,
}

impl SweepSpec {
    /// Number of scenarios `expand` will produce.
    pub fn n_scenarios(&self) -> usize {
        self.schedulers.len()
            * self.clusters.len()
            * self.workloads.len()
            * self.events.len()
            * self.slots_secs.len()
            * self.seeds.len()
    }

    /// Cartesian expansion in a stable order: cluster, workload, events,
    /// slot, seed, scheduler (innermost) — the nesting the hand-rolled
    /// figure loops used, so refactored figures keep their row order.
    pub fn expand(&self) -> Vec<ScenarioSpec> {
        let mut out = Vec::with_capacity(self.n_scenarios());
        for cluster in &self.clusters {
            for workload in &self.workloads {
                for events in &self.events {
                    for &slot in &self.slots_secs {
                        for &seed in &self.seeds {
                            for sched in &self.schedulers {
                                let mut sim = self.base;
                                sim.slot_secs = slot;
                                out.push(ScenarioSpec {
                                    scheduler: sched.clone(),
                                    cluster: cluster.clone(),
                                    workload: workload.clone(),
                                    seed,
                                    sim,
                                    events: events.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Built-in demonstration grid: the four generic schedulers over a
    /// scaled-down Philly trace on `sim60`, two slot lengths x two seeds —
    /// a 16-scenario sweep that finishes in seconds (`hadar sweep` with no
    /// `--spec`, and the `sweep_throughput` bench).
    pub fn demo() -> SweepSpec {
        SweepSpec {
            name: "demo16".into(),
            schedulers: crate::sched::SCHEDULER_NAMES
                .iter()
                .map(|s| s.to_string())
                .collect(),
            clusters: vec![ClusterRef::Preset("sim60".into())],
            workloads: vec![WorkloadSpec::Trace {
                n_jobs: 60,
                max_gpus: 8,
                all_at_start: true,
                hours_scale: 0.2,
            }],
            slots_secs: vec![180.0, 360.0],
            seeds: vec![7, 11],
            events: vec![EventsRef::None],
            base: SimConfig {
                slot_secs: 360.0,
                restart_overhead: 10.0,
                max_rounds: 50_000,
                horizon: 30.0 * 24.0 * 3600.0,
            },
            telemetry: false,
        }
    }

    /// Emit the grid as JSON (the `hadar sweep --spec` file format).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set(
                "schedulers",
                Json::Arr(
                    self.schedulers
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set(
                "clusters",
                Json::Arr(self.clusters.iter().map(|c| c.to_json()).collect()),
            )
            .set(
                "workloads",
                Json::Arr(
                    self.workloads.iter().map(|w| w.to_json()).collect(),
                ),
            )
            .set("slots_secs", self.slots_secs.clone())
            .set(
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::from(s)).collect()),
            )
            .set(
                "events",
                Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
            )
            .set("sim", sim_to_json(&self.base))
            .set("telemetry", self.telemetry)
    }

    /// Parse a grid from JSON; `slots_secs`, `seeds`, and `events` are
    /// optional axes (defaulting to one static-cluster entry).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let base = sim_from_json(v.get("sim"), SimConfig::default());
        let schedulers: Vec<String> = v
            .get("schedulers")
            .as_arr()
            .ok_or("sweep: 'schedulers' must be an array")?
            .iter()
            .map(|s| {
                let name = s
                    .as_str()
                    .ok_or("sweep: scheduler names must be strings")?;
                if !crate::sched::is_known(name) {
                    return Err(format!(
                        "unknown scheduler '{name}' (known: yarn-cs, \
                         tiresias, gavel, hadar, hadare, hadare-shared)"
                    ));
                }
                Ok(name.to_string())
            })
            .collect::<Result<_, _>>()?;
        let clusters: Vec<ClusterRef> = v
            .get("clusters")
            .as_arr()
            .ok_or("sweep: 'clusters' must be an array")?
            .iter()
            .map(ClusterRef::from_json)
            .collect::<Result<_, _>>()?;
        let workloads: Vec<WorkloadSpec> = v
            .get("workloads")
            .as_arr()
            .ok_or("sweep: 'workloads' must be an array")?
            .iter()
            .map(WorkloadSpec::from_json)
            .collect::<Result<_, _>>()?;
        let slots_secs: Vec<f64> = match v.get("slots_secs").as_arr() {
            Some(a) => a
                .iter()
                .map(|s| {
                    s.as_f64().ok_or_else(|| {
                        "sweep: 'slots_secs' must be numbers".to_string()
                    })
                })
                .collect::<Result<_, _>>()?,
            None => vec![base.slot_secs],
        };
        let seeds: Vec<u64> = match v.get("seeds").as_arr() {
            Some(a) => a
                .iter()
                .map(|s| {
                    s.as_u64().ok_or_else(|| {
                        "sweep: 'seeds' must be integers".to_string()
                    })
                })
                .collect::<Result<_, _>>()?,
            None => vec![42],
        };
        let events: Vec<EventsRef> = match v.get("events").as_arr() {
            Some(a) => a
                .iter()
                .map(EventsRef::from_json)
                .collect::<Result<_, _>>()?,
            None => vec![EventsRef::None],
        };
        if schedulers.is_empty()
            || clusters.is_empty()
            || workloads.is_empty()
            || slots_secs.is_empty()
            || seeds.is_empty()
            || events.is_empty()
        {
            return Err("sweep: 'schedulers', 'clusters', 'workloads', \
                        'slots_secs', 'seeds', and 'events' must be \
                        non-empty"
                .into());
        }
        Ok(SweepSpec {
            name: v.get("name").as_str().unwrap_or("sweep").to_string(),
            schedulers,
            clusters,
            workloads,
            slots_secs,
            seeds,
            events,
            base,
            telemetry: v.get("telemetry").as_bool().unwrap_or(false),
        })
    }

    /// Parse a grid from JSON text.
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(preset("sim60").unwrap().total_gpus(), 60);
        assert_eq!(preset("aws5").unwrap().total_gpus(), 5);
        assert_eq!(preset("scaled:2x4").unwrap().total_gpus(), 2 * 4 * 3);
        assert_eq!(preset("big8").unwrap().total_gpus(), 32);
        assert_eq!(preset("big:3x2").unwrap().total_gpus(), 3 * 2 * 2);
        assert!(preset("nope").is_err());
        assert!(preset("scaled:0x4").is_err());
        assert!(preset("scaled:abc").is_err());
        assert!(preset("big:0x4").is_err());
        assert!(preset("big:abc").is_err());
    }

    #[test]
    fn demo_grid_is_16_scenarios_with_unique_ids() {
        let spec = SweepSpec::demo();
        assert_eq!(spec.n_scenarios(), 16);
        let scenarios = spec.expand();
        assert_eq!(scenarios.len(), 16);
        let mut ids: Vec<String> = scenarios.iter().map(|s| s.id()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "scenario ids must be unique");
    }

    #[test]
    fn expansion_order_is_stable_and_scheduler_innermost() {
        let spec = SweepSpec::demo();
        let a = spec.expand();
        let b = spec.expand();
        assert!(a.iter().zip(&b).all(|(x, y)| x.id() == y.id()));
        // Scheduler varies fastest.
        assert_eq!(a[0].scheduler, "yarn-cs");
        assert_eq!(a[1].scheduler, "tiresias");
        assert_eq!(a[2].scheduler, "gavel");
        assert_eq!(a[3].scheduler, "hadar");
        // Then seed.
        assert_eq!(a[0].seed, 7);
        assert_eq!(a[4].seed, 11);
        // Then slot.
        assert_eq!(a[0].sim.slot_secs, 180.0);
        assert_eq!(a[8].sim.slot_secs, 360.0);
    }

    #[test]
    fn sweep_json_roundtrip() {
        let spec = SweepSpec::demo();
        let text = spec.to_json().pretty();
        let back = SweepSpec::parse(&text).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.n_scenarios(), spec.n_scenarios());
        let ids_a: Vec<String> =
            spec.expand().iter().map(|s| s.id()).collect();
        let ids_b: Vec<String> =
            back.expand().iter().map(|s| s.id()).collect();
        assert_eq!(ids_a, ids_b);
        assert_eq!(back.base.max_rounds, spec.base.max_rounds);
        assert_eq!(back.base.horizon, spec.base.horizon);
    }

    #[test]
    fn scenario_json_roundtrip_with_inline_cluster() {
        let s = ScenarioSpec {
            scheduler: "hadar".into(),
            cluster: ClusterRef::Inline(ClusterSpec::testbed5()),
            workload: WorkloadSpec::Mix {
                name: "M-5".into(),
                epochs_scale: 1.0,
            },
            seed: 9,
            sim: SimConfig::default(),
            events: EventsRef::None,
        };
        let back = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.id(), s.id());
        assert_eq!(back.cluster.resolve().unwrap().total_gpus(), 5);
    }

    #[test]
    fn events_axis_multiplies_grid_and_labels_ids() {
        let mut spec = SweepSpec::demo();
        spec.events = vec![
            EventsRef::None,
            EventsRef::Churn(ChurnConfig::default()),
        ];
        assert_eq!(spec.n_scenarios(), 32);
        let scenarios = spec.expand();
        assert_eq!(scenarios.len(), 32);
        let mut ids: Vec<String> =
            scenarios.iter().map(|s| s.id()).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "ids stay unique across the events axis");
        // Static scenarios keep the historical id shape; churn scenarios
        // append the generator label.
        assert!(scenarios
            .iter()
            .any(|s| matches!(s.events, EventsRef::None)
                 && s.id().ends_with(&format!("seed{}", s.seed))));
        assert!(scenarios
            .iter()
            .any(|s| s.id().contains("churn-s7")));
    }

    #[test]
    fn events_axis_roundtrips_through_json() {
        let mut timeline = EventTimeline {
            name: "drill".into(),
            events: Vec::new(),
        };
        timeline.push(
            3600.0,
            crate::cluster::events::EventKind::Maintenance {
                node: 0,
                duration: 1800.0,
            },
        );
        let mut spec = SweepSpec::demo();
        spec.events = vec![
            EventsRef::None,
            EventsRef::Inline(timeline),
            EventsRef::Churn(ChurnConfig {
                seed: 3,
                ..Default::default()
            }),
        ];
        let back = SweepSpec::parse(&spec.to_json().pretty()).unwrap();
        assert_eq!(back.n_scenarios(), spec.n_scenarios());
        let labels_a: Vec<String> =
            spec.events.iter().map(|e| e.label()).collect();
        let labels_b: Vec<String> =
            back.events.iter().map(|e| e.label()).collect();
        assert_eq!(labels_a, labels_b);
        let ids_a: Vec<String> =
            spec.expand().iter().map(|s| s.id()).collect();
        let ids_b: Vec<String> =
            back.expand().iter().map(|s| s.id()).collect();
        assert_eq!(ids_a, ids_b);
    }

    #[test]
    fn churn_labels_distinguish_every_generator_field() {
        let base = ChurnConfig::default();
        let variants = [
            base,
            ChurnConfig { seed: base.seed + 1, ..base },
            ChurnConfig { mean_interval_secs: 1.0 + base.mean_interval_secs,
                          ..base },
            ChurnConfig { min_down_secs: 1.0 + base.min_down_secs, ..base },
            ChurnConfig { max_down_secs: 1.0 + base.max_down_secs, ..base },
            ChurnConfig { leave_fraction: 0.5, ..base },
            ChurnConfig { horizon_secs: 1.0 + base.horizon_secs, ..base },
        ];
        let labels: Vec<String> = variants
            .iter()
            .map(|c| EventsRef::Churn(*c).label())
            .collect();
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(labels[i], labels[j],
                           "configs {i}/{j} collide: {}", labels[i]);
            }
        }
    }

    #[test]
    fn bad_events_entries_are_rejected() {
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],
                "events":[{"kind":"explode"}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],
                "events":[{"kind":"churn","mean_interval_secs":-5}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],"events":[]}"#
        )
        .is_err());
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(SweepSpec::parse("{}").is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["nope"],
                "workloads":[{"kind":"mix","name":"M-1"}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"bogus"}]}"#
        )
        .is_err());
        // Typos in scheduler / mix names fail at parse time, not after
        // half the sweep has run.
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadarr"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-99"}]}"#
        )
        .is_err());
        // Explicitly empty axes must not silently expand to 0 scenarios.
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],"seeds":[]}"#
        )
        .is_err());
        assert!(SweepSpec::parse(
            r#"{"schedulers":["hadar"],"clusters":["aws5"],
                "workloads":[{"kind":"mix","name":"M-1"}],"slots_secs":[]}"#
        )
        .is_err());
    }

    #[test]
    fn trace_labels_distinguish_every_field() {
        let base = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let more_gpus = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let poisson = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: false,
            hours_scale: 1.0,
        };
        let scaled = WorkloadSpec::Trace {
            n_jobs: 100,
            max_gpus: 4,
            all_at_start: true,
            hours_scale: 0.5,
        };
        let labels = [
            base.label(),
            more_gpus.label(),
            poisson.label(),
            scaled.label(),
        ];
        for i in 0..labels.len() {
            for j in (i + 1)..labels.len() {
                assert_ne!(labels[i], labels[j]);
            }
        }
    }

    #[test]
    fn trace_workload_builds_scaled_jobs() {
        let cluster = preset("sim60").unwrap();
        let full = WorkloadSpec::Trace {
            n_jobs: 20,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 1.0,
        };
        let scaled = WorkloadSpec::Trace {
            n_jobs: 20,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: 0.2,
        };
        let a = full.build_jobs(&cluster, 42).unwrap();
        let b = scaled.build_jobs(&cluster, 42).unwrap();
        assert_eq!(a.len(), 20);
        assert_eq!(b.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(y.epochs,
                       ((x.epochs as f64 * 0.2).ceil() as u64).max(1));
        }
    }

    #[test]
    fn mix_labels_stay_bare_at_paper_scale() {
        let paper = WorkloadSpec::Mix {
            name: "M-5".into(),
            epochs_scale: 1.0,
        };
        let scaled = WorkloadSpec::Mix {
            name: "M-5".into(),
            epochs_scale: 0.5,
        };
        // Figures key their cells on the bare mix name.
        assert_eq!(paper.label(), "M-5");
        assert_ne!(paper.label(), scaled.label());
    }

    #[test]
    fn mix_workload_rejects_unknown_mix() {
        let cluster = preset("aws5").unwrap();
        let w = WorkloadSpec::Mix {
            name: "M-99".into(),
            epochs_scale: 1.0,
        };
        assert!(w.build_jobs(&cluster, 0).is_err());
    }
}
