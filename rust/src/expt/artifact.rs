//! JSONL sweep artifacts: per-scenario summary records, the run manifest,
//! and the loader used to re-aggregate a finished sweep without re-running
//! it.
//!
//! Two serialisations exist per record:
//!
//! * [`to_jsonl`] — the full record, including the scheduling wall-time
//!   measurements (`sched_wall_secs`, `sched_wall_per_round`). Wall time
//!   is inherently non-deterministic, so these lines vary run to run.
//! * [`canonical_jsonl`] — the same records with the timing fields
//!   dropped. Everything left is a pure function of the spec, so two runs
//!   of the same sweep — at any worker count — emit byte-identical
//!   canonical lines. The determinism tests and any diff-based tooling
//!   should use this form.

use crate::expt::runner::ScenarioResult;
use crate::util::json::{self, Json};
use crate::util::stats;
use std::io;
use std::path::Path;

/// One scenario's summary: identity + the paper's reporting metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioRecord {
    /// Scenario id ([`crate::expt::spec::ScenarioSpec::id`]).
    pub id: String,
    /// Scheduler name.
    pub scheduler: String,
    /// Cluster label.
    pub cluster: String,
    /// Workload label.
    pub workload: String,
    /// Slot length `L` (seconds).
    pub slot_secs: f64,
    /// Workload seed.
    pub seed: u64,
    /// Cluster-events label (`"none"` for static clusters).
    pub events: String,
    /// Total time duration (makespan), seconds.
    pub ttd: f64,
    /// Whole-makespan busy fraction over nominal capacity (Fig. 3's GRU).
    pub gru: f64,
    /// Busy time over allocated slots (§VI CRU).
    pub cru: f64,
    /// Availability-normalised utilisation (== `gru` on static clusters).
    pub anu: f64,
    /// Mean job completion time (seconds).
    pub jct_mean: f64,
    /// Median JCT.
    pub jct_p50: f64,
    /// 90th-percentile JCT.
    pub jct_p90: f64,
    /// 99th-percentile JCT.
    pub jct_p99: f64,
    /// Fastest JCT.
    pub jct_min: f64,
    /// Slowest JCT.
    pub jct_max: f64,
    /// Jobs that finished.
    pub completed: usize,
    /// Rounds executed.
    pub rounds: u64,
    /// Jobs force-preempted by node drains / capacity shrinks.
    pub preemptions: u64,
    /// Fraction of rounds whose plan changed.
    pub change_fraction: f64,
    /// Wall-clock seconds inside `Scheduler::schedule` (non-deterministic).
    pub sched_wall_secs: f64,
    /// Mean wall-clock per round (non-deterministic).
    pub sched_wall_per_round: f64,
    /// Solver DP-memo hits over the run (0 for schedulers without a
    /// solver counter surface — see
    /// [`crate::sched::Scheduler::solver_stats`]).
    pub memo_hits: u64,
    /// Solver DP-memo misses over the run (0 likewise).
    pub memo_misses: u64,
    /// Rounds the solver answered with the full DP.
    pub dp_rounds: u64,
    /// Rounds the solver fell back to its greedy path.
    pub greedy_rounds: u64,
    /// Solver `FIND_ALLOC` scoring passes over the run (0 likewise).
    pub find_alloc_calls: u64,
    /// Candidate allocations the solver payoff-scored over the run.
    pub candidates_scored: u64,
    /// Speculative scores invalidated by an earlier commit and redone
    /// serially (Hadar's speculative greedy; 0 for other schedulers).
    pub rescore_conflicts: u64,
}

impl ScenarioRecord {
    /// Summarise one finished scenario.
    pub fn from_run(run: &ScenarioResult) -> Self {
        let res = &run.result;
        let jcts: Vec<f64> = res.jct.values().copied().collect();
        let (jct_min, jct_max) = if jcts.is_empty() {
            (0.0, 0.0)
        } else {
            (stats::min(&jcts), stats::max(&jcts))
        };
        let solver = res.solver.unwrap_or_default();
        ScenarioRecord {
            id: run.spec.id(),
            scheduler: run.spec.scheduler.clone(),
            cluster: run.spec.cluster.label(),
            workload: run.spec.workload.label(),
            slot_secs: run.spec.sim.slot_secs,
            seed: run.spec.seed,
            events: run.spec.events.label(),
            ttd: res.ttd,
            gru: res.gru,
            cru: res.cru,
            anu: res.anu,
            jct_mean: stats::mean(&jcts),
            jct_p50: stats::percentile(&jcts, 50.0),
            jct_p90: stats::percentile(&jcts, 90.0),
            jct_p99: stats::percentile(&jcts, 99.0),
            jct_min,
            jct_max,
            completed: res.jct.len(),
            rounds: res.rounds,
            preemptions: res.preemptions,
            change_fraction: res.change_fraction,
            sched_wall_secs: res.sched_wall_secs,
            sched_wall_per_round: res.sched_wall_per_round,
            memo_hits: solver.memo_hits,
            memo_misses: solver.memo_misses,
            dp_rounds: solver.dp_rounds,
            greedy_rounds: solver.greedy_rounds,
            find_alloc_calls: solver.find_alloc_calls,
            candidates_scored: solver.candidates_scored,
            rescore_conflicts: solver.rescore_conflicts,
        }
    }

    /// Emit as JSON; `include_timing` controls the non-deterministic
    /// wall-time fields (see the module docs).
    pub fn to_json(&self, include_timing: bool) -> Json {
        let mut v = Json::obj()
            .set("id", self.id.as_str())
            .set("scheduler", self.scheduler.as_str())
            .set("cluster", self.cluster.as_str())
            .set("workload", self.workload.as_str())
            .set("slot_secs", self.slot_secs)
            .set("seed", self.seed)
            .set("events", self.events.as_str())
            .set("ttd", self.ttd)
            .set("gru", self.gru)
            .set("cru", self.cru)
            .set("anu", self.anu)
            .set("jct_mean", self.jct_mean)
            .set("jct_p50", self.jct_p50)
            .set("jct_p90", self.jct_p90)
            .set("jct_p99", self.jct_p99)
            .set("jct_min", self.jct_min)
            .set("jct_max", self.jct_max)
            .set("completed", self.completed)
            .set("rounds", self.rounds)
            .set("preemptions", self.preemptions)
            .set("change_fraction", self.change_fraction)
            .set("memo_hits", self.memo_hits)
            .set("memo_misses", self.memo_misses)
            .set("dp_rounds", self.dp_rounds)
            .set("greedy_rounds", self.greedy_rounds)
            .set("find_alloc_calls", self.find_alloc_calls)
            .set("candidates_scored", self.candidates_scored)
            .set("rescore_conflicts", self.rescore_conflicts);
        if include_timing {
            v.insert("sched_wall_secs", self.sched_wall_secs);
            v.insert("sched_wall_per_round", self.sched_wall_per_round);
        }
        v
    }

    /// Parse a record; `events`, `anu`, and `preemptions` default for
    /// JSONL written before the dynamic-cluster metrics existed (static
    /// clusters, where `anu == gru`).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let f = |key: &str| -> Result<f64, String> {
            v.get(key)
                .as_f64()
                .ok_or_else(|| format!("record: '{key}' must be a number"))
        };
        let gru = f("gru")?;
        Ok(ScenarioRecord {
            id: v
                .get("id")
                .as_str()
                .ok_or("record: 'id' must be a string")?
                .to_string(),
            scheduler: v
                .get("scheduler")
                .as_str()
                .ok_or("record: 'scheduler' must be a string")?
                .to_string(),
            cluster: v.get("cluster").as_str().unwrap_or("?").to_string(),
            workload: v.get("workload").as_str().unwrap_or("?").to_string(),
            slot_secs: f("slot_secs")?,
            seed: v.get("seed").as_u64().unwrap_or(0),
            events: v.get("events").as_str().unwrap_or("none").to_string(),
            ttd: f("ttd")?,
            gru,
            cru: f("cru")?,
            anu: v.get("anu").as_f64().unwrap_or(gru),
            jct_mean: f("jct_mean")?,
            jct_p50: f("jct_p50")?,
            jct_p90: f("jct_p90")?,
            jct_p99: f("jct_p99")?,
            jct_min: f("jct_min")?,
            jct_max: f("jct_max")?,
            completed: v.get("completed").as_usize().unwrap_or(0),
            rounds: v.get("rounds").as_u64().unwrap_or(0),
            preemptions: v.get("preemptions").as_u64().unwrap_or(0),
            change_fraction: v.get("change_fraction").as_f64().unwrap_or(0.0),
            sched_wall_secs: v.get("sched_wall_secs").as_f64().unwrap_or(0.0),
            sched_wall_per_round: v
                .get("sched_wall_per_round")
                .as_f64()
                .unwrap_or(0.0),
            memo_hits: v.get("memo_hits").as_u64().unwrap_or(0),
            memo_misses: v.get("memo_misses").as_u64().unwrap_or(0),
            dp_rounds: v.get("dp_rounds").as_u64().unwrap_or(0),
            greedy_rounds: v.get("greedy_rounds").as_u64().unwrap_or(0),
            find_alloc_calls: v.get("find_alloc_calls").as_u64().unwrap_or(0),
            candidates_scored: v
                .get("candidates_scored")
                .as_u64()
                .unwrap_or(0),
            rescore_conflicts: v
                .get("rescore_conflicts")
                .as_u64()
                .unwrap_or(0),
        })
    }
}

/// Full JSONL (with timing), one compact record per line.
pub fn to_jsonl(records: &[ScenarioRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json(true).to_string());
        out.push('\n');
    }
    out
}

/// Deterministic JSONL: timing fields dropped, byte-identical across
/// worker counts and repeated runs of the same spec.
pub fn canonical_jsonl(records: &[ScenarioRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json(false).to_string());
        out.push('\n');
    }
    out
}

/// Parse JSONL produced by [`to_jsonl`] / [`canonical_jsonl`] (timing
/// fields are optional and default to zero).
pub fn parse_jsonl(text: &str) -> Result<Vec<ScenarioRecord>, String> {
    let mut records = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        records
            .push(ScenarioRecord::from_json(&v)
                .map_err(|e| format!("line {}: {e}", lineno + 1))?);
    }
    Ok(records)
}

/// Write the full JSONL summaries to `path`.
pub fn write_jsonl(path: &Path, records: &[ScenarioRecord]) -> io::Result<()> {
    std::fs::write(path, to_jsonl(records))
}

/// Load summaries back for re-aggregation (`hadar sweep --from <file>`).
pub fn load_jsonl(path: &Path) -> Result<Vec<ScenarioRecord>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    parse_jsonl(&text)
}

/// Run-level metadata written next to the summaries.
#[derive(Clone, Debug, PartialEq)]
pub struct RunManifest {
    /// Sweep name.
    pub sweep: String,
    /// Scenarios executed.
    pub scenarios: usize,
    /// Worker threads used.
    pub workers: usize,
    /// End-to-end sweep wall time (seconds).
    pub wall_secs: f64,
    /// Sum of per-scenario scheduler wall time (seconds).
    pub sched_wall_secs_total: f64,
}

impl RunManifest {
    /// Emit as JSON.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("sweep", self.sweep.as_str())
            .set("scenarios", self.scenarios)
            .set("workers", self.workers)
            .set("wall_secs", self.wall_secs)
            .set("sched_wall_secs_total", self.sched_wall_secs_total)
    }

    /// Parse from JSON.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        Ok(RunManifest {
            sweep: v
                .get("sweep")
                .as_str()
                .ok_or("manifest: 'sweep' must be a string")?
                .to_string(),
            scenarios: v.get("scenarios").as_usize().unwrap_or(0),
            workers: v.get("workers").as_usize().unwrap_or(0),
            wall_secs: v.get("wall_secs").as_f64().unwrap_or(0.0),
            sched_wall_secs_total: v
                .get("sched_wall_secs_total")
                .as_f64()
                .unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(scheduler: &str, ttd: f64) -> ScenarioRecord {
        ScenarioRecord {
            id: format!("{scheduler}/sim60/trace8@0.1/slot360/seed7"),
            scheduler: scheduler.into(),
            cluster: "sim60".into(),
            workload: "trace8@0.1".into(),
            slot_secs: 360.0,
            seed: 7,
            events: "none".into(),
            ttd,
            gru: 0.8,
            cru: 0.9,
            anu: 0.8,
            jct_mean: 100.0,
            jct_p50: 90.0,
            jct_p90: 150.0,
            jct_p99: 180.0,
            jct_min: 10.0,
            jct_max: 200.0,
            completed: 8,
            rounds: 12,
            preemptions: 0,
            change_fraction: 0.5,
            sched_wall_secs: 0.123,
            sched_wall_per_round: 0.01,
            memo_hits: 30,
            memo_misses: 6,
            dp_rounds: 10,
            greedy_rounds: 2,
            find_alloc_calls: 44,
            candidates_scored: 120,
            rescore_conflicts: 3,
        }
    }

    #[test]
    fn legacy_records_without_event_fields_still_parse() {
        // JSONL written before the dynamic-cluster metrics: no events /
        // anu / preemptions keys.
        let line = r#"{"id":"hadar/c/w/slot360/seed1","scheduler":"hadar",
            "cluster":"c","workload":"w","slot_secs":360,"seed":1,
            "ttd":100.0,"gru":0.7,"cru":0.8,"jct_mean":50.0,
            "jct_p50":50.0,"jct_p90":80.0,"jct_p99":90.0,"jct_min":10.0,
            "jct_max":95.0,"completed":4,"rounds":9,
            "change_fraction":0.2}"#
            .replace('\n', " ");
        let recs = parse_jsonl(&format!("{line}\n")).unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].events, "none");
        assert_eq!(recs[0].anu, 0.7, "anu defaults to gru");
        assert_eq!(recs[0].preemptions, 0);
    }

    #[test]
    fn record_roundtrips_through_json() {
        let r = record("hadar", 1234.5);
        let back = ScenarioRecord::from_json(&r.to_json(true)).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn canonical_drops_timing_but_parses_back() {
        let r = record("gavel", 999.0);
        let line = canonical_jsonl(&[r.clone()]);
        assert!(!line.contains("sched_wall"));
        let back = parse_jsonl(&line).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].ttd, r.ttd);
        assert_eq!(back[0].sched_wall_secs, 0.0);
    }

    #[test]
    fn jsonl_roundtrips_multiple_records() {
        let records = vec![record("hadar", 10.0), record("gavel", 20.0)];
        let text = to_jsonl(&records);
        assert_eq!(text.lines().count(), 2);
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, records);
    }

    #[test]
    fn parse_rejects_garbage_lines() {
        assert!(parse_jsonl("{not json}\n").is_err());
        assert!(parse_jsonl("{\"id\":\"x\"}\n").is_err());
    }

    #[test]
    fn manifest_roundtrips() {
        let m = RunManifest {
            sweep: "demo16".into(),
            scenarios: 16,
            workers: 8,
            wall_secs: 1.5,
            sched_wall_secs_total: 0.4,
        };
        assert_eq!(RunManifest::from_json(&m.to_json()).unwrap(), m);
    }
}
