//! Multi-threaded sweep execution.
//!
//! Scenarios are independent simulations, so the runner fans them out over
//! a `std::thread` worker pool (sized to the available parallelism unless
//! overridden). Work is handed out through a shared atomic cursor and
//! results come back over a channel tagged with the scenario index, so the
//! returned vector's order — and therefore every artifact and report built
//! from it — is the spec's expansion order regardless of how threads
//! interleave. Each scenario is seeded from its own spec, so a 1-worker
//! and an N-worker run of the same sweep produce identical `SimResult`s.

use crate::expt::spec::{ScenarioSpec, SweepSpec};
use crate::jobs::queue::JobQueue;
use crate::obs;
use crate::obs::export::TelemetrySink;
use crate::sched;
use crate::sched::hadare::GangConfig;
use crate::sim::engine::{self, SimResult};
use crate::sim::hadare_engine;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

/// One scenario's spec together with its full simulation result.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    /// The scenario that ran.
    pub spec: ScenarioSpec,
    /// Its simulation outcome.
    pub result: SimResult,
}

/// Worker count used when the caller passes `0`.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The pool size [`run_scenarios`] actually uses for `requested` workers
/// over `n` scenarios (`0` = all cores) — exposed so callers recording
/// run metadata report the same number.
pub fn effective_workers(requested: usize, n: usize) -> usize {
    let w = if requested == 0 { default_workers() } else { requested };
    w.clamp(1, n.max(1))
}

/// Run a single scenario to completion.
///
/// `hadare` and `hadare-shared` are special-cased onto
/// [`hadare_engine::run_with_gang`] (they schedule forked copies onto
/// gang slots, which the generic engine cannot express) — `hadare-shared`
/// with partial-node per-pool gangs ([`GangConfig::shared`]), so a sweep
/// can compare whole-node vs shared big nodes on the identical trace;
/// every other scheduler goes through [`sched::by_name`] and the generic
/// [`engine::run_with_events`]. The scenario's `events` axis is
/// materialised here — a churn generator expands against the resolved
/// cluster, so every scheduler in a sweep replays the identical trace.
/// Timelines are not recorded — sweeps only keep summary metrics.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<SimResult, String> {
    run_scenario_observed(spec, None)
}

/// [`run_scenario`] with an optional per-round telemetry sink threaded
/// through to the engine ([`engine::run_observed`] /
/// [`hadare_engine::run_with_gang_observed`]). The scenario runs under an
/// `expt.scenario` span and flushes this thread's span totals into the
/// global trace table on completion, so sweep flamegraphs attribute time
/// even when worker threads outlive many scenarios.
pub fn run_scenario_observed(spec: &ScenarioSpec,
                             sink: Option<&mut TelemetrySink>)
                             -> Result<SimResult, String> {
    let out = {
        // Inner scope: the span must drop before the flush below so the
        // scenario's own wall-clock lands in the global table now, not
        // at some later flush on this worker thread.
        let _span = obs::trace::span("expt.scenario");
        let cluster = spec.cluster.resolve()?;
        let jobs = spec.workload.build_jobs(&cluster, spec.seed)?;
        let events = spec.events.build(&cluster)?;
        let shared = spec.scheduler.eq_ignore_ascii_case("hadare-shared");
        if shared || spec.scheduler.eq_ignore_ascii_case("hadare") {
            let gang = if shared {
                GangConfig::shared()
            } else {
                GangConfig::default()
            };
            hadare_engine::run_with_gang_observed(&jobs, &cluster, &events,
                                                  &spec.sim, None, gang,
                                                  sink)
                .map(|r| r.sim)
        } else {
            let mut scheduler = sched::by_name(&spec.scheduler)?;
            let mut queue = JobQueue::new();
            for j in jobs {
                queue.admit(j).map_err(|e| e.to_string())?;
            }
            engine::run_observed(
                &mut queue,
                scheduler.as_mut(),
                &cluster,
                &events,
                &spec.sim,
                false,
                sink,
            )
        }
    };
    obs::trace::flush();
    out
}

/// File-system-safe telemetry stem for a scenario id: ASCII
/// alphanumerics, `-`, `.` and `_` pass through, everything else maps
/// to `_`.
pub fn sanitize_id(id: &str) -> String {
    id.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '.' | '_') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Run one scenario, writing per-round telemetry to
/// `<dir>/<sanitized-id>.telemetry.jsonl` when `telemetry_dir` is set.
/// Telemetry files include wall-clock timing fields (they are run
/// artifacts, not determinism fixtures).
fn run_scenario_to_dir(spec: &ScenarioSpec, telemetry_dir: Option<&Path>)
                       -> Result<SimResult, String> {
    match telemetry_dir {
        None => run_scenario_observed(spec, None),
        Some(dir) => {
            let path = dir
                .join(format!("{}.telemetry.jsonl", sanitize_id(&spec.id())));
            let mut sink = TelemetrySink::to_file(&path, true)
                .map_err(|e| format!("telemetry open {path:?}: {e}"))?;
            let res = run_scenario_observed(spec, Some(&mut sink))?;
            sink.finish()
                .map_err(|e| format!("telemetry close {path:?}: {e}"))?;
            Ok(res)
        }
    }
}

/// Expand `spec` and run every scenario on `workers` threads (`0` = all
/// cores). Results come back in expansion order.
pub fn run_sweep(spec: &SweepSpec, workers: usize)
                 -> Result<Vec<ScenarioResult>, String> {
    run_scenarios(&spec.expand(), workers)
}

/// [`run_sweep`] with an optional telemetry directory: when `Some`, every
/// scenario writes one `<sanitized-id>.telemetry.jsonl` stream into it
/// (the directory must already exist — the CLI creates it before the
/// run).
pub fn run_sweep_observed(spec: &SweepSpec, workers: usize,
                          telemetry_dir: Option<&Path>)
                          -> Result<Vec<ScenarioResult>, String> {
    run_scenarios_observed(&spec.expand(), workers, telemetry_dir)
}

/// Run an explicit scenario list on `workers` threads (`0` = all cores).
/// The output order matches the input order independent of thread
/// interleaving; the first failing scenario aborts the sweep with its id.
pub fn run_scenarios(scenarios: &[ScenarioSpec], workers: usize)
                     -> Result<Vec<ScenarioResult>, String> {
    run_scenarios_observed(scenarios, workers, None)
}

/// [`run_scenarios`] with an optional per-scenario telemetry directory
/// (see [`run_sweep_observed`]). Telemetry streams are written by the
/// worker that runs the scenario, so parallel sweeps produce the same
/// set of files as serial ones.
pub fn run_scenarios_observed(scenarios: &[ScenarioSpec], workers: usize,
                              telemetry_dir: Option<&Path>)
                              -> Result<Vec<ScenarioResult>, String> {
    let n = scenarios.len();
    let workers = effective_workers(workers, n);

    let mut slots: Vec<Option<Result<SimResult, String>>> =
        (0..n).map(|_| None).collect();

    if workers <= 1 {
        for (i, s) in scenarios.iter().enumerate() {
            let out = run_scenario_to_dir(s, telemetry_dir);
            let failed = out.is_err();
            slots[i] = Some(out);
            if failed {
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        // First failure stops workers from claiming further scenarios
        // (already-running ones finish); queued scenarios stay `None`.
        let stop = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<(usize, Result<SimResult, String>)>();
        // lint: allow(raw-thread, reason = "sweep worker pool sized by the --workers CLI arg, not a plan-thread count; scenario order is restored by index on collect")
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let stop = &stop;
                scope.spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= scenarios.len() {
                        break;
                    }
                    let out = run_scenario_to_dir(&scenarios[i],
                                                  telemetry_dir);
                    if out.is_err() {
                        stop.store(true, Ordering::SeqCst);
                    }
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, out) in rx {
                slots[i] = Some(out);
            }
        });
    }

    let mut results = Vec::with_capacity(n);
    for (spec, slot) in scenarios.iter().zip(slots) {
        match slot {
            Some(Ok(res)) => results.push(ScenarioResult {
                spec: spec.clone(),
                result: res,
            }),
            Some(Err(e)) => {
                return Err(format!("scenario {}: {e}", spec.id()))
            }
            // Never claimed: an earlier scenario failed first.
            None => {
                return Err(format!(
                    "scenario {}: not run (an earlier scenario failed)",
                    spec.id()
                ))
            }
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::events::ChurnConfig;
    use crate::expt::spec::{ClusterRef, EventsRef, WorkloadSpec};
    use crate::sim::engine::SimConfig;

    fn tiny_spec(scheduler: &str) -> ScenarioSpec {
        ScenarioSpec {
            scheduler: scheduler.into(),
            cluster: ClusterRef::Preset("motivational".into()),
            workload: WorkloadSpec::Trace {
                n_jobs: 4,
                max_gpus: 2,
                all_at_start: true,
                hours_scale: 0.05,
            },
            seed: 3,
            sim: SimConfig::default(),
            events: EventsRef::None,
        }
    }

    #[test]
    fn scenario_runs_and_completes_jobs() {
        let res = run_scenario(&tiny_spec("hadar")).unwrap();
        assert_eq!(res.jct.len(), 4);
        assert!(res.ttd > 0.0);
        assert!(res.gru > 0.0 && res.gru <= 1.0);
    }

    #[test]
    fn hadare_routes_through_forking_engine() {
        let spec = ScenarioSpec {
            scheduler: "hadare".into(),
            cluster: ClusterRef::Preset("aws5".into()),
            workload: WorkloadSpec::Mix {
                name: "M-1".into(),
                epochs_scale: 0.2,
            },
            seed: 0,
            sim: SimConfig {
                slot_secs: 90.0,
                ..Default::default()
            },
            events: EventsRef::None,
        };
        let res = run_scenario(&spec).unwrap();
        assert_eq!(res.jct.len(), 1);
    }

    #[test]
    fn hadare_shared_routes_with_per_pool_gangs() {
        // `hadare-shared` must reach the forking engine in partial-node
        // mode: on the two-pool big8 preset it books 32 GPUs in round 0
        // (per-pool gangs), where `hadare` books the same via whole-node
        // gangs — and both complete the mix.
        let mk = |scheduler: &str| ScenarioSpec {
            scheduler: scheduler.into(),
            cluster: ClusterRef::Preset("big8".into()),
            workload: WorkloadSpec::Mix {
                name: "M-3".into(),
                epochs_scale: 0.2,
            },
            seed: 0,
            sim: SimConfig {
                slot_secs: 90.0,
                ..Default::default()
            },
            events: EventsRef::None,
        };
        let shared = run_scenario(&mk("hadare-shared")).unwrap();
        let whole = run_scenario(&mk("hadare")).unwrap();
        assert_eq!(shared.scheduler, "hadare-shared");
        assert_eq!(whole.scheduler, "hadare");
        assert_eq!(shared.jct.len(), 3);
        assert_eq!(whole.jct.len(), 3);
        // Round 0 (three active parents): per-pool gangs book all 32
        // GPUs across 8 sub-gang allocations; whole-node gangs book the
        // same GPUs as 4 node-wide allocations. The per-parent GPU sums
        // expose the difference: under sharing no parent holds a whole
        // 8-GPU node to itself unless it spans several nodes in 4-GPU
        // pools.
        let r0 = &shared.timeline[0];
        let booked: usize = r0.jobs.values().map(|rj| rj.gpus).sum();
        assert_eq!(booked, 32, "shared round 0 books every GPU");
        assert!(r0.jobs.values().all(|rj| rj.gpus % 4 == 0));
    }

    #[test]
    fn churn_scenarios_are_deterministic_per_spec() {
        // The churn generator expands inside run_scenario, so repeated
        // runs of the same spec see the identical event trace.
        let mut spec = tiny_spec("hadar");
        spec.events = EventsRef::Churn(ChurnConfig {
            seed: 5,
            mean_interval_secs: 900.0,
            min_down_secs: 300.0,
            max_down_secs: 900.0,
            leave_fraction: 0.0,
            horizon_secs: 4.0 * 3600.0,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a.ttd, b.ttd);
        assert_eq!(a.anu, b.anu);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.events_applied, b.events_applied);
        assert_eq!(a.jct, b.jct);
    }

    #[test]
    fn observed_scenario_streams_one_record_per_round() {
        let mut sink = TelemetrySink::in_memory(false);
        let res =
            run_scenario_observed(&tiny_spec("hadar"), Some(&mut sink))
                .unwrap();
        assert_eq!(sink.records(), res.rounds);
        let text = sink.contents().unwrap().to_string();
        assert_eq!(text.lines().count() as u64, res.rounds);
        for line in text.lines() {
            let v = crate::util::json::parse(line).unwrap();
            assert_eq!(v.get("scheduler").as_str(), Some("hadar"));
        }
    }

    #[test]
    fn sanitize_id_keeps_safe_chars_only() {
        assert_eq!(sanitize_id("hadar-sim60_s3.slot360"),
                   "hadar-sim60_s3.slot360");
        assert_eq!(sanitize_id("a/b:c d"), "a_b_c_d");
    }

    #[test]
    fn unknown_scheduler_is_a_clear_error() {
        let err = run_scenario(&tiny_spec("bogus")).unwrap_err();
        assert!(err.contains("unknown scheduler"), "{err}");
    }

    #[test]
    fn parallel_matches_serial_and_preserves_order() {
        let scenarios: Vec<ScenarioSpec> = ["yarn-cs", "gavel", "hadar"]
            .iter()
            .flat_map(|s| {
                let mut a = tiny_spec(s);
                let mut b = tiny_spec(s);
                a.seed = 3;
                b.seed = 5;
                [a, b]
            })
            .collect();
        let serial = run_scenarios(&scenarios, 1).unwrap();
        let parallel = run_scenarios(&scenarios, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.spec.id(), b.spec.id());
            assert_eq!(a.result.ttd, b.result.ttd);
            assert_eq!(a.result.gru, b.result.gru);
            assert_eq!(a.result.cru, b.result.cru);
            assert_eq!(a.result.jct, b.result.jct);
        }
    }
}
