//! Multi-threaded sweep execution.
//!
//! Scenarios are independent simulations, so the runner fans them out over
//! a `std::thread` worker pool (sized to the available parallelism unless
//! overridden). Work is handed out through a shared atomic cursor and
//! results come back over a channel tagged with the scenario index, so the
//! returned vector's order — and therefore every artifact and report built
//! from it — is the spec's expansion order regardless of how threads
//! interleave. Each scenario is seeded from its own spec, so a 1-worker
//! and an N-worker run of the same sweep produce identical `SimResult`s.

use crate::expt::spec::{ScenarioSpec, SweepSpec};
use crate::jobs::queue::JobQueue;
use crate::sched;
use crate::sched::hadare::GangConfig;
use crate::sim::engine::{self, SimResult};
use crate::sim::hadare_engine;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

/// One scenario's spec together with its full simulation result.
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    /// The scenario that ran.
    pub spec: ScenarioSpec,
    /// Its simulation outcome.
    pub result: SimResult,
}

/// Worker count used when the caller passes `0`.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The pool size [`run_scenarios`] actually uses for `requested` workers
/// over `n` scenarios (`0` = all cores) — exposed so callers recording
/// run metadata report the same number.
pub fn effective_workers(requested: usize, n: usize) -> usize {
    let w = if requested == 0 { default_workers() } else { requested };
    w.clamp(1, n.max(1))
}

/// Run a single scenario to completion.
///
/// `hadare` and `hadare-shared` are special-cased onto
/// [`hadare_engine::run_with_gang`] (they schedule forked copies onto
/// gang slots, which the generic engine cannot express) — `hadare-shared`
/// with partial-node per-pool gangs ([`GangConfig::shared`]), so a sweep
/// can compare whole-node vs shared big nodes on the identical trace;
/// every other scheduler goes through [`sched::by_name`] and the generic
/// [`engine::run_with_events`]. The scenario's `events` axis is
/// materialised here — a churn generator expands against the resolved
/// cluster, so every scheduler in a sweep replays the identical trace.
/// Timelines are not recorded — sweeps only keep summary metrics.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<SimResult, String> {
    let cluster = spec.cluster.resolve()?;
    let jobs = spec.workload.build_jobs(&cluster, spec.seed)?;
    let events = spec.events.build(&cluster)?;
    let shared = spec.scheduler.eq_ignore_ascii_case("hadare-shared");
    if shared || spec.scheduler.eq_ignore_ascii_case("hadare") {
        let gang = if shared {
            GangConfig::shared()
        } else {
            GangConfig::default()
        };
        Ok(hadare_engine::run_with_gang(&jobs, &cluster, &events,
                                        &spec.sim, None, gang)?
            .sim)
    } else {
        let mut scheduler = sched::by_name(&spec.scheduler)?;
        let mut queue = JobQueue::new();
        for j in jobs {
            queue.admit(j);
        }
        engine::run_with_events(
            &mut queue,
            scheduler.as_mut(),
            &cluster,
            &events,
            &spec.sim,
            false,
        )
    }
}

/// Expand `spec` and run every scenario on `workers` threads (`0` = all
/// cores). Results come back in expansion order.
pub fn run_sweep(spec: &SweepSpec, workers: usize)
                 -> Result<Vec<ScenarioResult>, String> {
    run_scenarios(&spec.expand(), workers)
}

/// Run an explicit scenario list on `workers` threads (`0` = all cores).
/// The output order matches the input order independent of thread
/// interleaving; the first failing scenario aborts the sweep with its id.
pub fn run_scenarios(scenarios: &[ScenarioSpec], workers: usize)
                     -> Result<Vec<ScenarioResult>, String> {
    let n = scenarios.len();
    let workers = effective_workers(workers, n);

    let mut slots: Vec<Option<Result<SimResult, String>>> =
        (0..n).map(|_| None).collect();

    if workers <= 1 {
        for (i, s) in scenarios.iter().enumerate() {
            let out = run_scenario(s);
            let failed = out.is_err();
            slots[i] = Some(out);
            if failed {
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        // First failure stops workers from claiming further scenarios
        // (already-running ones finish); queued scenarios stay `None`.
        let stop = AtomicBool::new(false);
        let (tx, rx) = mpsc::channel::<(usize, Result<SimResult, String>)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let stop = &stop;
                scope.spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= scenarios.len() {
                        break;
                    }
                    let out = run_scenario(&scenarios[i]);
                    if out.is_err() {
                        stop.store(true, Ordering::SeqCst);
                    }
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, out) in rx {
                slots[i] = Some(out);
            }
        });
    }

    let mut results = Vec::with_capacity(n);
    for (spec, slot) in scenarios.iter().zip(slots) {
        match slot {
            Some(Ok(res)) => results.push(ScenarioResult {
                spec: spec.clone(),
                result: res,
            }),
            Some(Err(e)) => {
                return Err(format!("scenario {}: {e}", spec.id()))
            }
            // Never claimed: an earlier scenario failed first.
            None => {
                return Err(format!(
                    "scenario {}: not run (an earlier scenario failed)",
                    spec.id()
                ))
            }
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::events::ChurnConfig;
    use crate::expt::spec::{ClusterRef, EventsRef, WorkloadSpec};
    use crate::sim::engine::SimConfig;

    fn tiny_spec(scheduler: &str) -> ScenarioSpec {
        ScenarioSpec {
            scheduler: scheduler.into(),
            cluster: ClusterRef::Preset("motivational".into()),
            workload: WorkloadSpec::Trace {
                n_jobs: 4,
                max_gpus: 2,
                all_at_start: true,
                hours_scale: 0.05,
            },
            seed: 3,
            sim: SimConfig::default(),
            events: EventsRef::None,
        }
    }

    #[test]
    fn scenario_runs_and_completes_jobs() {
        let res = run_scenario(&tiny_spec("hadar")).unwrap();
        assert_eq!(res.jct.len(), 4);
        assert!(res.ttd > 0.0);
        assert!(res.gru > 0.0 && res.gru <= 1.0);
    }

    #[test]
    fn hadare_routes_through_forking_engine() {
        let spec = ScenarioSpec {
            scheduler: "hadare".into(),
            cluster: ClusterRef::Preset("aws5".into()),
            workload: WorkloadSpec::Mix {
                name: "M-1".into(),
                epochs_scale: 0.2,
            },
            seed: 0,
            sim: SimConfig {
                slot_secs: 90.0,
                ..Default::default()
            },
            events: EventsRef::None,
        };
        let res = run_scenario(&spec).unwrap();
        assert_eq!(res.jct.len(), 1);
    }

    #[test]
    fn hadare_shared_routes_with_per_pool_gangs() {
        // `hadare-shared` must reach the forking engine in partial-node
        // mode: on the two-pool big8 preset it books 32 GPUs in round 0
        // (per-pool gangs), where `hadare` books the same via whole-node
        // gangs — and both complete the mix.
        let mk = |scheduler: &str| ScenarioSpec {
            scheduler: scheduler.into(),
            cluster: ClusterRef::Preset("big8".into()),
            workload: WorkloadSpec::Mix {
                name: "M-3".into(),
                epochs_scale: 0.2,
            },
            seed: 0,
            sim: SimConfig {
                slot_secs: 90.0,
                ..Default::default()
            },
            events: EventsRef::None,
        };
        let shared = run_scenario(&mk("hadare-shared")).unwrap();
        let whole = run_scenario(&mk("hadare")).unwrap();
        assert_eq!(shared.scheduler, "hadare-shared");
        assert_eq!(whole.scheduler, "hadare");
        assert_eq!(shared.jct.len(), 3);
        assert_eq!(whole.jct.len(), 3);
        // Round 0 (three active parents): per-pool gangs book all 32
        // GPUs across 8 sub-gang allocations; whole-node gangs book the
        // same GPUs as 4 node-wide allocations. The per-parent GPU sums
        // expose the difference: under sharing no parent holds a whole
        // 8-GPU node to itself unless it spans several nodes in 4-GPU
        // pools.
        let r0 = &shared.timeline[0];
        let booked: usize = r0.jobs.values().map(|rj| rj.gpus).sum();
        assert_eq!(booked, 32, "shared round 0 books every GPU");
        assert!(r0.jobs.values().all(|rj| rj.gpus % 4 == 0));
    }

    #[test]
    fn churn_scenarios_are_deterministic_per_spec() {
        // The churn generator expands inside run_scenario, so repeated
        // runs of the same spec see the identical event trace.
        let mut spec = tiny_spec("hadar");
        spec.events = EventsRef::Churn(ChurnConfig {
            seed: 5,
            mean_interval_secs: 900.0,
            min_down_secs: 300.0,
            max_down_secs: 900.0,
            leave_fraction: 0.0,
            horizon_secs: 4.0 * 3600.0,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a.ttd, b.ttd);
        assert_eq!(a.anu, b.anu);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.events_applied, b.events_applied);
        assert_eq!(a.jct, b.jct);
    }

    #[test]
    fn unknown_scheduler_is_a_clear_error() {
        let err = run_scenario(&tiny_spec("bogus")).unwrap_err();
        assert!(err.contains("unknown scheduler"), "{err}");
    }

    #[test]
    fn parallel_matches_serial_and_preserves_order() {
        let scenarios: Vec<ScenarioSpec> = ["yarn-cs", "gavel", "hadar"]
            .iter()
            .flat_map(|s| {
                let mut a = tiny_spec(s);
                let mut b = tiny_spec(s);
                a.seed = 3;
                b.seed = 5;
                [a, b]
            })
            .collect();
        let serial = run_scenarios(&scenarios, 1).unwrap();
        let parallel = run_scenarios(&scenarios, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.spec.id(), b.spec.id());
            assert_eq!(a.result.ttd, b.result.ttd);
            assert_eq!(a.result.gru, b.result.gru);
            assert_eq!(a.result.cru, b.result.cru);
            assert_eq!(a.result.jct, b.result.jct);
        }
    }
}
