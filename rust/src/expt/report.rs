//! Cross-scenario comparison reports over sweep artifacts.
//!
//! Scenarios are grouped by everything except the scheduler (cluster,
//! workload, events, slot, seed); within each group every scheduler is
//! compared to a chosen baseline: TTD speedup (`baseline_ttd / ttd`, >1 is
//! faster) and utilisation deltas in percentage points. Runs under an
//! event timeline additionally report the availability-normalised
//! utilisation (ANU) and drain-preemption counts — the churn-comparison
//! view: the same event trace replayed under every scheduler in the
//! group. A per-scheduler summary table aggregates the mean speedup and
//! deltas across groups.

use crate::expt::artifact::ScenarioRecord;
use crate::util::stats;
use crate::util::table::{human_time, Table};
use std::collections::BTreeMap;

/// Group key: scenario identity minus the scheduler. The events label is
/// part of the identity — schedulers are only compared under the same
/// churn trace.
fn group_key(r: &ScenarioRecord) -> String {
    let base = format!(
        "{}/{}/slot{}/seed{}",
        r.cluster, r.workload, r.slot_secs, r.seed
    );
    if r.events == "none" {
        base
    } else {
        format!("{base}/{}", r.events)
    }
}

/// Render the per-scenario comparison plus a per-scheduler summary.
/// Groups with no `baseline` record show `-` in the speedup column.
pub fn render(records: &[ScenarioRecord], baseline: &str) -> String {
    let mut base_ttd: BTreeMap<String, f64> = BTreeMap::new();
    let mut base_gru: BTreeMap<String, f64> = BTreeMap::new();
    let mut base_cru: BTreeMap<String, f64> = BTreeMap::new();
    for r in records {
        if r.scheduler == baseline {
            let k = group_key(r);
            base_ttd.insert(k.clone(), r.ttd);
            base_gru.insert(k.clone(), r.gru);
            base_cru.insert(k, r.cru);
        }
    }

    let speedup_hdr = format!("TTD vs {baseline}");
    let mut t = Table::new(&[
        "scenario",
        "scheduler",
        "TTD",
        speedup_hdr.as_str(),
        "GRU",
        "dGRU",
        "CRU",
        "dCRU",
        "ANU",
        "preempt",
        "memo%",
        "rescore%",
        "sched ms/round",
    ]);
    // Per-scheduler accumulators for the summary table.
    let mut speedups: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut dgrus: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    let mut dcrus: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for r in records {
        let k = group_key(r);
        let speedup = base_ttd.get(&k).map(|&b| b / r.ttd.max(1e-12));
        let dgru = base_gru.get(&k).map(|&b| (r.gru - b) * 100.0);
        let dcru = base_cru.get(&k).map(|&b| (r.cru - b) * 100.0);
        if let Some(s) = speedup {
            speedups.entry(r.scheduler.clone()).or_default().push(s);
        }
        if let Some(d) = dgru {
            dgrus.entry(r.scheduler.clone()).or_default().push(d);
        }
        if let Some(d) = dcru {
            dcrus.entry(r.scheduler.clone()).or_default().push(d);
        }
        t.row(&[
            k,
            r.scheduler.clone(),
            human_time(r.ttd),
            speedup
                .map(|s| format!("{s:.2}x"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}%", r.gru * 100.0),
            dgru.map(|d| format!("{d:+.1}pp"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}%", r.cru * 100.0),
            dcru.map(|d| format!("{d:+.1}pp"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}%", r.anu * 100.0),
            format!("{}", r.preemptions),
            // DP-memo hit rate for schedulers that expose solver
            // counters; `-` for baselines without a solver.
            {
                let lookups = r.memo_hits + r.memo_misses;
                if lookups == 0 {
                    "-".into()
                } else {
                    format!("{:.1}%",
                            r.memo_hits as f64 * 100.0 / lookups as f64)
                }
            },
            // Fraction of FIND_ALLOC passes forced by speculative-commit
            // conflicts — the cost of Hadar's sharded greedy. `-` for
            // schedulers that never score candidates.
            {
                if r.find_alloc_calls == 0 {
                    "-".into()
                } else {
                    format!("{:.1}%",
                            r.rescore_conflicts as f64 * 100.0
                                / r.find_alloc_calls as f64)
                }
            },
            format!("{:.3}", r.sched_wall_per_round * 1e3),
        ]);
    }

    let mut out = String::new();
    out.push_str(&format!(
        "sweep comparison — {} scenarios, baseline: {baseline}\n",
        records.len()
    ));
    out.push_str(&t.render());

    let mut s = Table::new(&[
        "scheduler",
        "groups",
        format!("mean TTD speedup vs {baseline}").as_str(),
        "mean dGRU",
        "mean dCRU",
    ]);
    for (sched, sp) in &speedups {
        let dg = dgrus.get(sched).map(|v| stats::mean(v)).unwrap_or(0.0);
        let dc = dcrus.get(sched).map(|v| stats::mean(v)).unwrap_or(0.0);
        s.row(&[
            sched.clone(),
            format!("{}", sp.len()),
            format!("{:.2}x", stats::mean(sp)),
            format!("{dg:+.1}pp"),
            format!("{dc:+.1}pp"),
        ]);
    }
    out.push_str("\nper-scheduler summary (mean across groups)\n");
    out.push_str(&s.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(scheduler: &str, seed: u64, ttd: f64, gru: f64)
              -> ScenarioRecord {
        ScenarioRecord {
            id: format!("{scheduler}/c/w/slot360/seed{seed}"),
            scheduler: scheduler.into(),
            cluster: "c".into(),
            workload: "w".into(),
            slot_secs: 360.0,
            seed,
            events: "none".into(),
            ttd,
            gru,
            cru: gru,
            anu: gru,
            jct_mean: ttd / 2.0,
            jct_p50: ttd / 2.0,
            jct_p90: ttd,
            jct_p99: ttd,
            jct_min: 1.0,
            jct_max: ttd,
            completed: 4,
            rounds: 10,
            preemptions: 0,
            change_fraction: 0.1,
            sched_wall_secs: 0.0,
            sched_wall_per_round: 0.0,
            memo_hits: 0,
            memo_misses: 0,
            dp_rounds: 0,
            greedy_rounds: 0,
            find_alloc_calls: 0,
            candidates_scored: 0,
            rescore_conflicts: 0,
        }
    }

    #[test]
    fn memo_column_shows_hit_rate_or_dash() {
        let mut with = record("hadar", 7, 100.0, 0.6);
        with.memo_hits = 3;
        with.memo_misses = 1;
        with.find_alloc_calls = 40;
        with.rescore_conflicts = 10;
        let without = record("gavel", 7, 200.0, 0.5);
        let out = render(&[without, with], "gavel");
        assert!(out.contains("75.0%"), "{out}");
        assert!(out.contains("25.0%"), "rescore%: {out}");
        // The counter-less baseline renders a dash in its memo column
        // (its data row is the one with the 1.00x self-speedup).
        let gavel_line = out
            .lines()
            .find(|l| l.contains("gavel") && l.contains("1.00x"))
            .expect("gavel row");
        assert!(gavel_line.contains(" - "), "{gavel_line}");
    }

    #[test]
    fn baseline_rows_are_unity_and_others_scaled() {
        let records = vec![
            record("gavel", 7, 200.0, 0.5),
            record("hadar", 7, 100.0, 0.6),
        ];
        let out = render(&records, "gavel");
        assert!(out.contains("1.00x"), "{out}");
        assert!(out.contains("2.00x"), "{out}");
        assert!(out.contains("+10.0pp"), "{out}");
        assert!(out.contains("per-scheduler summary"), "{out}");
    }

    #[test]
    fn missing_baseline_shows_dash() {
        let records = vec![record("hadar", 7, 100.0, 0.6)];
        let out = render(&records, "gavel");
        assert!(out.contains(" - "), "{out}");
    }

    #[test]
    fn events_label_separates_comparison_groups() {
        // A churn run must not be compared against a static-cluster
        // baseline: different event traces are different experiments.
        let base = record("gavel", 1, 100.0, 0.5);
        let mut churned = record("hadar", 1, 50.0, 0.5);
        churned.events = "churn-s7-i7200".into();
        let out = render(&[base, churned], "gavel");
        // The hadar row has no baseline in its (churn) group.
        let hadar_line = out
            .lines()
            .find(|l| l.contains("hadar"))
            .expect("hadar row");
        assert!(hadar_line.contains("churn-s7"), "{hadar_line}");
        assert!(hadar_line.contains(" - "), "{hadar_line}");
        // Same trace on both sides compares normally.
        let mut base2 = record("gavel", 1, 100.0, 0.5);
        base2.events = "churn-s7-i7200".into();
        let mut churned2 = record("hadar", 1, 50.0, 0.5);
        churned2.events = "churn-s7-i7200".into();
        let out = render(&[base2, churned2], "gavel");
        assert!(out.contains("2.00x"), "{out}");
    }

    #[test]
    fn summary_averages_across_seeds() {
        let records = vec![
            record("gavel", 1, 100.0, 0.5),
            record("hadar", 1, 50.0, 0.5),
            record("gavel", 2, 100.0, 0.5),
            record("hadar", 2, 25.0, 0.5),
        ];
        let out = render(&records, "gavel");
        // Mean of 2.0x and 4.0x speedups.
        assert!(out.contains("3.00x"), "{out}");
    }
}
