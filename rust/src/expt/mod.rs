//! `expt` — declarative, multi-threaded experiment sweeps (the repo's
//! experiment engine).
//!
//! The paper's evaluation is a grid: five schedulers x trace sizes x slot
//! lengths x cluster specs x workload mixes (Figs. 3-12). Instead of one
//! bespoke serial loop per figure, a sweep is *data*:
//!
//! * [`spec`] — [`spec::SweepSpec`] declares the grid (scheduler names x
//!   cluster presets x workloads x cluster-event timelines x slot lengths
//!   x seeds) and expands it into [`spec::ScenarioSpec`]s via a
//!   deterministic cartesian product. Specs load from / save to JSON
//!   through [`crate::util::json`]. The events axis
//!   ([`spec::EventsRef`]) replays node churn — explicit timelines or
//!   seeded generators — identically under every scheduler.
//! * [`runner`] — executes scenarios on a `std::thread` worker pool (one
//!   `sim::engine::run` / `sim::hadare_engine::run_with_gang` per
//!   scenario; `hadare` plans whole-node gangs, `hadare-shared`
//!   partial-node per-pool gangs), with per-scenario seeds and result
//!   ordering that is independent of thread interleaving.
//! * [`artifact`] — per-scenario JSONL summaries (TTD, JCT percentiles,
//!   GRU/CRU, scheduling wall time) plus a run manifest, and a loader to
//!   re-aggregate a finished sweep without re-running it.
//! * [`report`] — cross-scenario comparison tables (speedup vs a baseline
//!   scheduler, utilisation deltas) rendered through [`crate::util::table`].
//!
//! `figures::trace_eval`, `figures::slots`, and `figures::physical` all
//! express their grids as sweeps and run through [`runner`], so the
//! multi-scenario figures scale with the available cores. The `hadar
//! sweep` CLI subcommand exposes the same machinery on arbitrary spec
//! files (see `docs/expt.md`).

pub mod artifact;
pub mod report;
pub mod runner;
pub mod spec;

pub use artifact::{RunManifest, ScenarioRecord};
pub use runner::{run_scenario, run_sweep, ScenarioResult};
pub use spec::{ClusterRef, EventsRef, ScenarioSpec, SweepSpec, WorkloadSpec};
