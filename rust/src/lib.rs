//! # Hadar / HadarE — heterogeneity-aware DL-cluster scheduling
//!
//! Reproduction of *Resource Heterogeneity-Aware and Utilization-Enhanced
//! Scheduling for Deep Learning Clusters* (Sultana et al., IEEE TC 2025;
//! Hadar first appeared at IPDPS'24).
//!
//! Layer-3 of the three-layer Rust + JAX + Pallas stack:
//!
//! * [`sched`] — the paper's contribution: the Hadar primal-dual/DP
//!   scheduler (Algorithms 1-2), the Gavel/Tiresias/YARN-CS baselines, and
//!   the HadarE forking scheduler.
//! * [`sim`] — discrete-time trace-driven simulator (paper §IV), with
//!   dynamic-cluster support: both engines replay a
//!   [`cluster::events::EventTimeline`] (node joins, drains, maintenance
//!   windows, capacity changes), preempting jobs on drained nodes and
//!   reporting availability-normalised utilisation.
//! * [`exec`] — physical-cluster *emulation*: virtual-clock heterogeneous
//!   nodes running **real** training steps through the PJRT runtime
//!   (paper §VI), including HadarE's aggregate + consolidate loop.
//! * [`runtime`] — loads the AOT-compiled HLO artifacts produced by
//!   `python/compile/aot.py` and executes them via the `xla` crate's PJRT
//!   CPU client. Python never runs on this path.
//! * [`cluster`], [`jobs`], [`trace`] — the modelled world: GPU types,
//!   nodes, jobs, throughput matrices, Philly-like traces, workload
//!   mixes, and the cluster event timeline ([`cluster::events`]) plus its
//!   seeded churn generator.
//! * [`forking`] — HadarE's Job Forker and Job Tracker (paper §V).
//! * [`expt`] — declarative experiment sweeps: a scenario grid spec, a
//!   multi-threaded runner, JSONL artifacts, and comparison reports (the
//!   `hadar sweep` subcommand; the multi-scenario figures run through it).
//! * [`figures`] — one driver per paper table/figure (see DESIGN.md's
//!   experiment index), shared by examples and benches.
//! * [`obs`] — observability: scoped span tracing with folded-stack
//!   export, a counters/gauges/histograms registry, and per-round JSONL
//!   telemetry (off by default; see `docs/observability.md`).
//! * [`analysis`] — the `hadar lint` static-analysis pass: a
//!   comment/string-stripping lexer, the module graph with plan-path vs
//!   harness classification, and an eight-rule determinism engine with
//!   suppression pragmas (see `docs/static-analysis.md`; CI gates on a
//!   clean tree).
//! * [`util`] — self-contained substrates (JSON, RNG, CLI, stats, tables,
//!   property-test + bench harnesses).
//!
//! Prose documentation lives in `docs/`: `docs/architecture.md` (layer
//! map), `docs/schedulers.md` (implementation ↔ paper equations),
//! `docs/simulation.md` (round loop, overhead accounting, event
//! timelines), and `docs/expt.md` (the sweep engine).

#![warn(missing_docs)]

pub mod analysis;
pub mod cluster;
pub mod exec;
pub mod expt;
pub mod figures;
pub mod forking;
pub mod jobs;
pub mod obs;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod util;
