//! The AOT artifact manifest — the contract between `python/compile/aot.py`
//! and the Rust runtime. Parsed from `artifacts/manifest.json`.

use crate::util::json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// How a parameter is initialised (mirrors `model.init_params`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    /// All ones (layer-norm gains).
    Ones,
    /// All zeros (biases, momenta).
    Zeros,
    /// Gaussian with the given stddev.
    Normal(f64),
}

/// One flat parameter slot.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// Parameter name (e.g. `"layer0.ln1.g"`).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Initialisation rule.
    pub init: Init,
}

impl ParamSpec {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered model variant.
#[derive(Clone, Debug)]
pub struct Variant {
    /// Variant name (`"tiny"`, `"small"`, …).
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Sequence length.
    pub seq: usize,
    /// Batch size.
    pub batch: usize,
    /// Total parameter count (sanity check against `params`).
    pub param_count: usize,
    /// Flat parameter slots, in executable argument order.
    pub params: Vec<ParamSpec>,
    /// Path to the train-step HLO text.
    pub train_hlo: PathBuf,
    /// Path to the eval-step HLO text.
    pub eval_hlo: PathBuf,
}

impl Variant {
    /// Token input shape for train_step: [batch, seq+1].
    pub fn token_len(&self) -> usize {
        self.batch * (self.seq + 1)
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory (HLO paths are relative to it).
    pub dir: PathBuf,
    /// Variants by name.
    pub variants: BTreeMap<String, Variant>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON; `dir` anchors the HLO paths.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        if v.get("format").as_usize() != Some(1) {
            return Err("unsupported manifest format".into());
        }
        let mut variants = BTreeMap::new();
        let vmap = v
            .get("variants")
            .as_obj()
            .ok_or("manifest: missing 'variants'")?;
        for (name, entry) in vmap {
            let cfg = entry.get("config");
            let mut params = Vec::new();
            for p in entry
                .get("params")
                .as_arr()
                .ok_or("variant: missing 'params'")?
            {
                let kind = p.get("kind").as_str().unwrap_or("normal");
                let init = match kind {
                    "ones" => Init::Ones,
                    "zeros" => Init::Zeros,
                    "normal" => Init::Normal(
                        p.get("scale").as_f64().unwrap_or(0.02),
                    ),
                    other => return Err(format!("unknown init '{other}'")),
                };
                params.push(ParamSpec {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or("param missing name")?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or("param missing shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    init,
                });
            }
            let variant = Variant {
                name: name.clone(),
                vocab: cfg.get("vocab").as_usize().unwrap_or(0),
                d_model: cfg.get("d_model").as_usize().unwrap_or(0),
                n_layers: cfg.get("n_layers").as_usize().unwrap_or(0),
                seq: cfg.get("seq").as_usize().unwrap_or(0),
                batch: cfg.get("batch").as_usize().unwrap_or(0),
                param_count: entry.get("param_count").as_usize().unwrap_or(0),
                params,
                train_hlo: dir.join(
                    entry.get("train_hlo").as_str().ok_or("missing train_hlo")?,
                ),
                eval_hlo: dir.join(
                    entry.get("eval_hlo").as_str().ok_or("missing eval_hlo")?,
                ),
            };
            variants.insert(name.clone(), variant);
        }
        if variants.is_empty() {
            return Err("manifest has no variants".into());
        }
        Ok(Manifest { dir, variants })
    }

    /// Look up a variant by name.
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.get(name)
    }

    /// Default artifact directory: `$HADAR_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        // lint: allow(env-read, reason = "artifact-dir config knob, resolved once at load time; never read on the plan path")
        std::env::var("HADAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "variants": {
        "tiny": {
          "config": {"name": "tiny", "vocab": 256, "d_model": 64,
                     "n_layers": 2, "n_heads": 2, "d_ff": 128,
                     "seq": 64, "batch": 8},
          "param_count": 87040,
          "params": [
            {"name": "tok_emb", "shape": [256, 64], "kind": "normal",
             "scale": 0.02},
            {"name": "layer0.ln1.g", "shape": [64], "kind": "ones"},
            {"name": "layer0.b1", "shape": [128], "kind": "zeros"}
          ],
          "train_hlo": "tiny_train.hlo.txt",
          "eval_hlo": "tiny_eval.hlo.txt",
          "train_inputs": {"tokens": [8, 65], "lr": [], "n_params": 26}
        }
      }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.vocab, 256);
        assert_eq!(v.batch, 8);
        assert_eq!(v.params.len(), 3);
        assert_eq!(v.params[0].init, Init::Normal(0.02));
        assert_eq!(v.params[1].init, Init::Ones);
        assert_eq!(v.params[2].init, Init::Zeros);
        assert_eq!(v.params[0].numel(), 256 * 64);
        assert_eq!(v.train_hlo, PathBuf::from("/tmp/a/tiny_train.hlo.txt"));
        assert_eq!(v.token_len(), 8 * 65);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 2, "variants": {}}"#,
                                PathBuf::new())
            .is_err());
        assert!(Manifest::parse(r#"{"format": 1, "variants": {}}"#,
                                PathBuf::new())
            .is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.variant("tiny").is_some());
            let v = m.variant("tiny").unwrap();
            assert!(v.train_hlo.exists());
            assert!(v.eval_hlo.exists());
        }
    }
}
