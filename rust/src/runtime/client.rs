//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! on the `xla` crate's CPU client. This is the only place the training
//! path touches compiled compute — Python never runs here.
//!
//! Interchange is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md: jax >= 0.5 serialized protos are rejected by
//! xla_extension 0.5.1; the text parser reassigns instruction ids).

use crate::runtime::artifacts::{Init, Manifest, Variant};
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};

/// Owns the PJRT client; compile once, execute many.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled train-step: `(tokens, lr, P params, P momenta) ->
/// (loss, P params, P momenta)` as one HLO module (fwd + bwd + SGD fused).
pub struct TrainStep {
    exe: xla::PjRtLoadedExecutable,
    /// Parameter slot count `P`.
    pub n_params: usize,
    /// Batch size of the lowered module.
    pub batch: usize,
    /// Sequence length of the lowered module.
    pub seq: usize,
}

/// A compiled eval-step: `(tokens, P params) -> (loss, accuracy)`.
pub struct EvalStep {
    exe: xla::PjRtLoadedExecutable,
    /// Parameter slot count `P`.
    pub n_params: usize,
}

/// Model state held as host literals between steps.
pub struct ModelState {
    /// Parameter tensors, in manifest order.
    pub params: Vec<xla::Literal>,
    /// SGD momentum tensors, matching `params`.
    pub momenta: Vec<xla::Literal>,
}

impl Runtime {
    /// Create the PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?,
        })
    }

    /// The PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &std::path::Path)
                    -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }

    /// Compile the train-step artifact of one variant.
    pub fn load_train(&self, variant: &Variant) -> Result<TrainStep> {
        Ok(TrainStep {
            exe: self.compile_file(&variant.train_hlo)?,
            n_params: variant.params.len(),
            batch: variant.batch,
            seq: variant.seq,
        })
    }

    /// Compile the eval-step artifact of one variant.
    pub fn load_eval(&self, variant: &Variant) -> Result<EvalStep> {
        Ok(EvalStep {
            exe: self.compile_file(&variant.eval_hlo)?,
            n_params: variant.params.len(),
        })
    }

    /// Initialise a model state from the manifest's init specs with a
    /// deterministic seed (mirrors `model.init_params` semantics; exact
    /// values differ — documented in DESIGN.md).
    pub fn init_state(&self, variant: &Variant, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed ^ 0x11AD_A12E);
        let mut params = Vec::with_capacity(variant.params.len());
        let mut momenta = Vec::with_capacity(variant.params.len());
        for spec in &variant.params {
            let n = spec.numel();
            let values: Vec<f32> = match spec.init {
                Init::Ones => vec![1.0; n],
                Init::Zeros => vec![0.0; n],
                Init::Normal(scale) => (0..n)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect(),
            };
            params.push(literal_f32(&values, &spec.shape));
            momenta.push(literal_f32(&vec![0.0; n], &spec.shape));
        }
        ModelState { params, momenta }
    }
}

/// Build an f32 literal with the given shape.
pub fn literal_f32(values: &[f32], shape: &[usize]) -> xla::Literal {
    let flat = xla::Literal::vec1(values);
    if shape.len() == 1 {
        flat
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        flat.reshape(&dims).expect("reshape literal")
    }
}

/// Build an i32 token literal of shape [batch, seq+1].
pub fn literal_tokens(tokens: &[i32], batch: usize, seq_plus1: usize)
                      -> xla::Literal {
    assert_eq!(tokens.len(), batch * seq_plus1);
    xla::Literal::vec1(tokens)
        .reshape(&[batch as i64, seq_plus1 as i64])
        .expect("reshape tokens")
}

impl TrainStep {
    /// Run one SGD step; returns the loss and advances `state` in place.
    pub fn step(&self, state: &mut ModelState, tokens: &[i32], lr: f32)
                -> Result<f32> {
        let tok = literal_tokens(tokens, self.batch, self.seq + 1);
        let lr_lit = xla::Literal::scalar(lr);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(
            2 + 2 * self.n_params,
        );
        args.push(&tok);
        args.push(&lr_lit);
        args.extend(state.params.iter());
        args.extend(state.momenta.iter());
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("train step execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decompose result: {e:?}"))?;
        if parts.len() != 1 + 2 * self.n_params {
            return Err(anyhow!(
                "train step returned {} outputs, expected {}",
                parts.len(),
                1 + 2 * self.n_params
            ));
        }
        let momenta: Vec<xla::Literal> =
            parts.split_off(1 + self.n_params);
        let params: Vec<xla::Literal> = parts.split_off(1);
        let loss = parts[0]
            .to_vec::<f32>()
            .context("loss literal")?[0];
        state.params = params;
        state.momenta = momenta;
        Ok(loss)
    }
}

impl EvalStep {
    /// Evaluate on one batch: (cross-entropy loss, top-1 accuracy).
    pub fn eval(&self, state: &ModelState, tokens: &[i32], batch: usize,
                seq_plus1: usize) -> Result<(f32, f32)> {
        let tok = literal_tokens(tokens, batch, seq_plus1);
        let mut args: Vec<&xla::Literal> =
            Vec::with_capacity(1 + self.n_params);
        args.push(&tok);
        args.extend(state.params.iter());
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("eval execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch eval: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decompose eval: {e:?}"))?;
        let loss = parts[0].to_vec::<f32>().context("loss")?[0];
        let acc = parts[1].to_vec::<f32>().context("acc")?[0];
        Ok((loss, acc))
    }
}

/// Flatten a state's parameters to one f32 vector (consolidation I/O).
pub fn flatten_params(params: &[xla::Literal]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for p in params {
        out.extend(p.to_vec::<f32>().context("flatten param")?);
    }
    Ok(out)
}

/// Rebuild parameter literals from a flat vector using the variant's specs.
pub fn unflatten_params(flat: &[f32], variant: &Variant)
                        -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(variant.params.len());
    let mut off = 0;
    for spec in &variant.params {
        let n = spec.numel();
        if off + n > flat.len() {
            return Err(anyhow!("flat params too short"));
        }
        out.push(literal_f32(&flat[off..off + n], &spec.shape));
        off += n;
    }
    if off != flat.len() {
        return Err(anyhow!("flat params too long: {} vs {}", flat.len(), off));
    }
    Ok(out)
}

/// Load the default manifest (helper shared by examples/benches/tests).
pub fn load_default_manifest() -> Result<Manifest> {
    Manifest::load(Manifest::default_dir())
        .map_err(|e| anyhow!("load manifest: {e} (run `make artifacts`)"))
}
