//! Trainer: synthetic corpus generation + the training-loop driver the
//! emulated cluster nodes share, including HadarE's parameter
//! consolidation in literal space.

use crate::forking::tracker::consolidate_weights;
use crate::runtime::artifacts::Variant;
use crate::runtime::client::{
    flatten_params, unflatten_params, ModelState, TrainStep,
};
use crate::util::rng::{Rng, ZipfTable};
use anyhow::Result;

/// Deterministic synthetic corpus: a Zipf-weighted order-1 Markov chain
/// over the vocabulary. It has real learnable structure (transition rows
/// are low-entropy) so cross-entropy falls well below log(vocab) and
/// next-token accuracy is meaningful — the substitution for the paper's
/// datasets (DESIGN.md §Substitutions).
pub struct Corpus {
    vocab: usize,
    /// Per-state candidate successors (front-loaded probability).
    successors: Vec<Vec<u32>>,
    zipf: ZipfTable,
}

impl Corpus {
    /// `branch` successors per state; smaller = more learnable.
    pub fn new(vocab: usize, branch: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0E9_05);
        let successors = (0..vocab)
            .map(|_| {
                (0..branch.max(1))
                    .map(|_| rng.below(vocab as u64) as u32)
                    .collect()
            })
            .collect();
        Corpus {
            vocab,
            successors,
            zipf: ZipfTable::new(branch.max(1), 1.5),
        }
    }

    /// Sample a `[batch, seq+1]` token batch (flattened row-major).
    pub fn batch(&self, rng: &mut Rng, batch: usize, seq_plus1: usize)
                 -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * seq_plus1);
        for _ in 0..batch {
            let mut cur = rng.below(self.vocab as u64) as usize;
            out.push(cur as i32);
            for _ in 1..seq_plus1 {
                let succ = &self.successors[cur];
                cur = succ[self.zipf.sample(rng)] as usize;
                out.push(cur as i32);
            }
        }
        out
    }
}

/// One model under training: state + its data stream.
pub struct Trainer {
    /// Parameters + momenta.
    pub state: ModelState,
    /// The job's data distribution.
    pub corpus: Corpus,
    /// Batch-sampling stream.
    pub rng: Rng,
    /// Real steps executed so far.
    pub steps_done: u64,
    /// (cumulative step, loss) curve.
    pub losses: Vec<(u64, f32)>,
    /// SGD learning rate.
    pub lr: f32,
}

impl Trainer {
    /// `corpus_seed` defines the data distribution (shared across copies
    /// and with the evaluator); the sampling stream is derived from it.
    pub fn new(state: ModelState, vocab: usize, corpus_seed: u64, lr: f32)
               -> Self {
        Trainer {
            state,
            corpus: Corpus::new(vocab, 4, corpus_seed),
            rng: Rng::new(corpus_seed ^ 0x7EA1),
            steps_done: 0,
            losses: Vec::new(),
            lr,
        }
    }

    /// Run `n` real train steps through the compiled executable.
    pub fn run_steps(&mut self, exe: &TrainStep, n: u64) -> Result<f32> {
        let mut last = f32::NAN;
        for _ in 0..n {
            let tokens =
                self.corpus.batch(&mut self.rng, exe.batch, exe.seq + 1);
            last = exe.step(&mut self.state, &tokens, self.lr)?;
            self.steps_done += 1;
            self.losses.push((self.steps_done, last));
        }
        Ok(last)
    }
}

/// HadarE §V-B consolidation over literal-space parameter vectors:
/// flatten each copy's parameters, weight-average, unflatten.
pub fn consolidate_states(states: &[&ModelState], weights: &[f64],
                          variant: &Variant) -> Result<Vec<xla::Literal>> {
    let flats: Vec<Vec<f32>> = states
        .iter()
        .map(|s| flatten_params(&s.params))
        .collect::<Result<_>>()?;
    let avg = consolidate_weights(&flats, weights);
    unflatten_params(&avg, variant)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_in_range() {
        let c = Corpus::new(64, 4, 9);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = c.batch(&mut r1, 2, 10);
        let b = c.batch(&mut r2, 2, 10);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < 64));
    }

    #[test]
    fn corpus_has_learnable_structure() {
        // Transitions should be concentrated: the same current token leads
        // to few successors.
        let c = Corpus::new(32, 2, 1);
        let mut rng = Rng::new(2);
        let toks = c.batch(&mut rng, 8, 65);
        let mut pairs = std::collections::BTreeMap::new();
        for row in toks.chunks(65) {
            for w in row.windows(2) {
                pairs
                    .entry(w[0])
                    .or_insert_with(std::collections::BTreeSet::new)
                    .insert(w[1]);
            }
        }
        // Each state has at most `branch` = 2 successors.
        assert!(pairs.values().all(|s| s.len() <= 2));
    }
}
