//! PJRT runtime: artifact manifest, compiled executables, and the trainer
//! substrate (synthetic corpora, consolidation).

pub mod artifacts;
pub mod client;
pub mod trainer;

pub use artifacts::{Init, Manifest, ParamSpec, Variant};
pub use client::{
    flatten_params, literal_f32, literal_tokens, load_default_manifest,
    unflatten_params, EvalStep, ModelState, Runtime, TrainStep,
};
pub use trainer::{consolidate_states, Corpus, Trainer};
