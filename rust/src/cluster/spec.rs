//! Cluster specifications: the three evaluation clusters from the paper,
//! plus JSON load/save for custom clusters.

use crate::cluster::gpu::{GpuType, PcieGen};
use crate::cluster::node::Node;
use crate::util::json::{self, Json};

/// A full cluster: the set of nodes plus derived views.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl ClusterSpec {
    pub fn new(name: &str, nodes: Vec<Node>) -> Self {
        ClusterSpec {
            name: name.to_string(),
            nodes,
        }
    }

    /// §IV simulated cluster: 15 nodes, 60 GPUs — 20 each of V100, P100,
    /// K80 (following Gavel's simulation setup). 5 nodes per type, 4 GPUs
    /// per node.
    pub fn sim60() -> Self {
        let mut nodes = Vec::new();
        let types = [GpuType::V100, GpuType::P100, GpuType::K80];
        for (ti, &t) in types.iter().enumerate() {
            for i in 0..5 {
                let id = ti * 5 + i;
                nodes.push(Node::new(
                    id,
                    &format!("{}-{}", t.name().to_lowercase(), i),
                    &[(t, 4)],
                    PcieGen::Gen3,
                ));
            }
        }
        ClusterSpec::new("sim60", nodes)
    }

    /// §VI AWS cluster: 1x p3.2xlarge (V100), 2x p2.xlarge (K80),
    /// 2x g4dn.xlarge (T4); one GPU used per node.
    pub fn aws5() -> Self {
        ClusterSpec::new(
            "aws5",
            vec![
                Node::new(0, "p3.2xlarge", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "p2.xlarge-a", &[(GpuType::K80, 1)], PcieGen::Gen3),
                Node::new(2, "p2.xlarge-b", &[(GpuType::K80, 1)], PcieGen::Gen3),
                Node::new(3, "g4dn.xlarge-a", &[(GpuType::T4, 1)], PcieGen::Gen3),
                Node::new(4, "g4dn.xlarge-b", &[(GpuType::T4, 1)], PcieGen::Gen3),
            ],
        )
    }

    /// §VI lab testbed: Titan RTX, T4, T400, RTX 3090, RTX A2000; the paper
    /// notes three of five nodes have older PCIe-3.0 motherboards.
    pub fn testbed5() -> Self {
        ClusterSpec::new(
            "testbed5",
            vec![
                Node::new(0, "titan", &[(GpuType::TitanRtx, 1)], PcieGen::Gen3),
                Node::new(1, "t4", &[(GpuType::T4, 1)], PcieGen::Gen3),
                Node::new(2, "t400", &[(GpuType::T400, 1)], PcieGen::Gen3),
                Node::new(3, "dell-3090", &[(GpuType::Rtx3090, 1)], PcieGen::Gen4),
                Node::new(4, "a2000", &[(GpuType::RtxA2000, 1)], PcieGen::Gen4),
            ],
        )
    }

    /// Fig. 1 motivational cluster: 2x V100, 3x P100, 1x K80, modelled as
    /// three nodes (one per type) matching the paper's per-type totals.
    pub fn motivational() -> Self {
        ClusterSpec::new(
            "motivational",
            vec![
                Node::new(0, "v100-node", &[(GpuType::V100, 2)], PcieGen::Gen3),
                Node::new(1, "p100-node", &[(GpuType::P100, 3)], PcieGen::Gen3),
                Node::new(2, "k80-node", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        )
    }

    /// Scaled cluster for the Fig. 5 scalability sweep: grows with the job
    /// count, keeping the 1:1:1 V100/P100/K80 mix of `sim60`.
    pub fn scaled(nodes_per_type: usize, gpus_per_node: usize) -> Self {
        let mut nodes = Vec::new();
        let types = [GpuType::V100, GpuType::P100, GpuType::K80];
        let mut id = 0;
        for &t in &types {
            for i in 0..nodes_per_type {
                nodes.push(Node::new(
                    id,
                    &format!("{}-{}", t.name().to_lowercase(), i),
                    &[(t, gpus_per_node)],
                    PcieGen::Gen3,
                ));
                id += 1;
            }
        }
        ClusterSpec::new("scaled", nodes)
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.total_gpus()).sum()
    }

    /// GPU types present, in stable order.
    pub fn gpu_types(&self) -> Vec<GpuType> {
        let mut types: Vec<GpuType> = GpuType::ALL
            .iter()
            .copied()
            .filter(|&t| self.nodes.iter().any(|n| n.capacity(t) > 0))
            .collect();
        types.sort();
        types
    }

    /// Total capacity of one GPU type across the cluster.
    pub fn capacity_of(&self, r: GpuType) -> usize {
        self.nodes.iter().map(|n| n.capacity(r)).sum()
    }

    // ------------------------------------------------------------- JSON I/O

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let mut gpus = Json::obj();
                for (g, c) in &n.gpus {
                    gpus.insert(g.name(), *c);
                }
                Json::obj()
                    .set("id", n.id)
                    .set("name", n.name.as_str())
                    .set("gpus", gpus)
                    .set(
                        "pcie",
                        match n.pcie {
                            PcieGen::Gen3 => "gen3",
                            PcieGen::Gen4 => "gen4",
                        },
                    )
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("nodes", Json::Arr(nodes))
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = v.get("name").as_str().unwrap_or("custom").to_string();
        let mut nodes = Vec::new();
        for (i, nv) in v
            .get("nodes")
            .as_arr()
            .ok_or("cluster: 'nodes' must be an array")?
            .iter()
            .enumerate()
        {
            let gpus_obj = nv
                .get("gpus")
                .as_obj()
                .ok_or("node: 'gpus' must be an object")?;
            let mut gpus = Vec::new();
            for (gname, count) in gpus_obj {
                let g = GpuType::from_name(gname)
                    .ok_or_else(|| format!("unknown gpu type '{gname}'"))?;
                gpus.push((g, count.as_usize().ok_or("gpu count must be int")?));
            }
            let pcie = match nv.get("pcie").as_str() {
                Some("gen4") => PcieGen::Gen4,
                _ => PcieGen::Gen3,
            };
            nodes.push(Node::new(
                nv.get("id").as_usize().unwrap_or(i),
                nv.get("name").as_str().unwrap_or(&format!("node{i}")),
                &gpus,
                pcie,
            ));
        }
        if nodes.is_empty() {
            return Err("cluster has no nodes".into());
        }
        Ok(ClusterSpec { name, nodes })
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim60_matches_paper() {
        let c = ClusterSpec::sim60();
        assert_eq!(c.nodes.len(), 15);
        assert_eq!(c.total_gpus(), 60);
        assert_eq!(c.capacity_of(GpuType::V100), 20);
        assert_eq!(c.capacity_of(GpuType::P100), 20);
        assert_eq!(c.capacity_of(GpuType::K80), 20);
    }

    #[test]
    fn aws5_and_testbed5_are_five_single_gpu_nodes() {
        for c in [ClusterSpec::aws5(), ClusterSpec::testbed5()] {
            assert_eq!(c.nodes.len(), 5);
            assert_eq!(c.total_gpus(), 5);
            assert!(c.nodes.iter().all(|n| n.total_gpus() == 1));
        }
        assert_eq!(ClusterSpec::testbed5().gpu_types().len(), 5);
    }

    #[test]
    fn motivational_matches_fig1() {
        let c = ClusterSpec::motivational();
        assert_eq!(c.capacity_of(GpuType::V100), 2);
        assert_eq!(c.capacity_of(GpuType::P100), 3);
        assert_eq!(c.capacity_of(GpuType::K80), 1);
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::testbed5();
        let txt = c.to_json().pretty();
        let c2 = ClusterSpec::parse(&txt).unwrap();
        assert_eq!(c2.nodes.len(), c.nodes.len());
        assert_eq!(c2.total_gpus(), c.total_gpus());
        assert_eq!(c2.gpu_types(), c.gpu_types());
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(ClusterSpec::parse("{}").is_err());
        assert!(ClusterSpec::parse(
            r#"{"nodes": [{"gpus": {"NotAGpu": 1}}]}"#
        )
        .is_err());
    }
}
