//! Cluster specifications: the three evaluation clusters from the paper,
//! plus JSON load/save for custom clusters.

use crate::cluster::gpu::{GpuType, PcieGen};
use crate::cluster::node::Node;
use crate::util::json::{self, Json};

/// A full cluster: the set of nodes plus derived views.
///
/// Under a [`crate::cluster::events::ClusterTimeline`] this is a *snapshot*:
/// nodes join and leave between rounds, so node ids need not stay
/// contiguous — always address nodes by id, not by index.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Cluster label (preset name or the JSON file's `name`).
    pub name: String,
    /// The machines currently in the cluster.
    pub nodes: Vec<Node>,
}

impl ClusterSpec {
    /// Build a cluster from a node list.
    pub fn new(name: &str, nodes: Vec<Node>) -> Self {
        ClusterSpec {
            name: name.to_string(),
            nodes,
        }
    }

    /// §IV simulated cluster: 15 nodes, 60 GPUs — 20 each of V100, P100,
    /// K80 (following Gavel's simulation setup). 5 nodes per type, 4 GPUs
    /// per node.
    pub fn sim60() -> Self {
        let mut nodes = Vec::new();
        let types = [GpuType::V100, GpuType::P100, GpuType::K80];
        for (ti, &t) in types.iter().enumerate() {
            for i in 0..5 {
                let id = ti * 5 + i;
                nodes.push(Node::new(
                    id,
                    &format!("{}-{}", t.name().to_lowercase(), i),
                    &[(t, 4)],
                    PcieGen::Gen3,
                ));
            }
        }
        ClusterSpec::new("sim60", nodes)
    }

    /// §VI AWS cluster: 1x p3.2xlarge (V100), 2x p2.xlarge (K80),
    /// 2x g4dn.xlarge (T4); one GPU used per node.
    pub fn aws5() -> Self {
        ClusterSpec::new(
            "aws5",
            vec![
                Node::new(0, "p3.2xlarge", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "p2.xlarge-a", &[(GpuType::K80, 1)], PcieGen::Gen3),
                Node::new(2, "p2.xlarge-b", &[(GpuType::K80, 1)], PcieGen::Gen3),
                Node::new(3, "g4dn.xlarge-a", &[(GpuType::T4, 1)], PcieGen::Gen3),
                Node::new(4, "g4dn.xlarge-b", &[(GpuType::T4, 1)], PcieGen::Gen3),
            ],
        )
    }

    /// §VI lab testbed: Titan RTX, T4, T400, RTX 3090, RTX A2000; the paper
    /// notes three of five nodes have older PCIe-3.0 motherboards.
    pub fn testbed5() -> Self {
        ClusterSpec::new(
            "testbed5",
            vec![
                Node::new(0, "titan", &[(GpuType::TitanRtx, 1)], PcieGen::Gen3),
                Node::new(1, "t4", &[(GpuType::T4, 1)], PcieGen::Gen3),
                Node::new(2, "t400", &[(GpuType::T400, 1)], PcieGen::Gen3),
                Node::new(3, "dell-3090", &[(GpuType::Rtx3090, 1)], PcieGen::Gen4),
                Node::new(4, "a2000", &[(GpuType::RtxA2000, 1)], PcieGen::Gen4),
            ],
        )
    }

    /// Fig. 1 motivational cluster: 2x V100, 3x P100, 1x K80, modelled as
    /// three nodes (one per type) matching the paper's per-type totals.
    pub fn motivational() -> Self {
        ClusterSpec::new(
            "motivational",
            vec![
                Node::new(0, "v100-node", &[(GpuType::V100, 2)], PcieGen::Gen3),
                Node::new(1, "p100-node", &[(GpuType::P100, 3)], PcieGen::Gen3),
                Node::new(2, "k80-node", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        )
    }

    /// Scaled cluster for the Fig. 5 scalability sweep: grows with the job
    /// count, keeping the 1:1:1 V100/P100/K80 mix of `sim60`.
    pub fn scaled(nodes_per_type: usize, gpus_per_node: usize) -> Self {
        let mut nodes = Vec::new();
        let types = [GpuType::V100, GpuType::P100, GpuType::K80];
        let mut id = 0;
        for &t in &types {
            for i in 0..nodes_per_type {
                nodes.push(Node::new(
                    id,
                    &format!("{}-{}", t.name().to_lowercase(), i),
                    &[(t, gpus_per_node)],
                    PcieGen::Gen3,
                ));
                id += 1;
            }
        }
        ClusterSpec::new("scaled", nodes)
    }

    /// Two-pool **big-node** preset for partial-node HadarE (per-pool
    /// gangs): 4 nodes, each carrying 8 GPUs as two 4-GPU pools
    /// (V100 + P100) — 32 GPUs total. With whole-node gangs one parent
    /// monopolises all 8 GPUs of a node (and runs at the bottleneck of
    /// the slower pool); with `share_nodes` two parents can hold one
    /// pool each, which is the scenario the `big8` tests and the
    /// `expt`/CI sweep smoke drive. See [`ClusterSpec::big`] for the
    /// scaled family.
    pub fn big8() -> Self {
        let mut c = ClusterSpec::big(4, 4);
        c.name = "big8".into();
        c
    }

    /// Scaled two-pool big-node family: `nodes` nodes, each with a
    /// `gpus_per_pool`-GPU V100 pool and a `gpus_per_pool`-GPU P100 pool
    /// (`2 * nodes * gpus_per_pool` GPUs total). Preset syntax in sweep
    /// specs: `big:<nodes>x<gpus_per_pool>`; `sched::bench`'s
    /// `fork_shared_*` rows plan on `big:20x4`.
    pub fn big(nodes: usize, gpus_per_pool: usize) -> Self {
        let spec_nodes = (0..nodes)
            .map(|id| {
                Node::new(
                    id,
                    &format!("big-{id}"),
                    &[
                        (GpuType::V100, gpus_per_pool),
                        (GpuType::P100, gpus_per_pool),
                    ],
                    PcieGen::Gen3,
                )
            })
            .collect();
        ClusterSpec::new(&format!("big{nodes}x{gpus_per_pool}"), spec_nodes)
    }

    /// ~256-node synthetic cluster for the scheduler microbenches
    /// (`benches/l3_sched_micro.rs`, `hadar bench`): 64 nodes each of
    /// V100/P100/K80/T4, 4 GPUs per node — 256 nodes, 1024 GPUs. Big
    /// enough that per-call slot-list rebuilds and per-branch state clones
    /// dominate the solve, which is exactly what the zero-clone hot path
    /// is measured against (see `docs/performance.md`).
    pub fn synthetic256() -> Self {
        let mut nodes = Vec::new();
        let types = [GpuType::V100, GpuType::P100, GpuType::K80, GpuType::T4];
        let mut id = 0;
        for &t in &types {
            for i in 0..64 {
                nodes.push(Node::new(
                    id,
                    &format!("{}-{}", t.name().to_lowercase(), i),
                    &[(t, 4)],
                    PcieGen::Gen3,
                ));
                id += 1;
            }
        }
        ClusterSpec::new("synthetic256", nodes)
    }

    /// Total GPUs across all nodes and types.
    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.total_gpus()).sum()
    }

    /// The node with this id, if present.
    pub fn node(&self, id: usize) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id == id)
    }

    /// Add a node (cluster-event `join`). The caller guarantees the id is
    /// not already present ([`crate::cluster::events::EventTimeline::resolve`]
    /// validates this for event streams).
    pub fn add_node(&mut self, node: Node) {
        self.nodes.push(node);
    }

    /// Remove a node by id (cluster-event `leave`/drain), returning its
    /// spec so maintenance windows can restore it.
    pub fn remove_node(&mut self, id: usize) -> Option<Node> {
        let idx = self.nodes.iter().position(|n| n.id == id)?;
        Some(self.nodes.remove(idx))
    }

    /// Set one `(node, type)` pool to `count` GPUs (cluster-event
    /// `set_capacity`; 0 removes the pool). Returns the pool's previous
    /// capacity, or `None` if the node is absent.
    pub fn set_capacity(&mut self, id: usize, gpu: GpuType, count: usize)
                        -> Option<usize> {
        let n = self.nodes.iter_mut().find(|n| n.id == id)?;
        let old = n.gpus.get(&gpu).copied().unwrap_or(0);
        if count == 0 {
            n.gpus.remove(&gpu);
        } else {
            n.gpus.insert(gpu, count);
        }
        Some(old)
    }

    /// GPU types present, in stable order.
    pub fn gpu_types(&self) -> Vec<GpuType> {
        let mut types: Vec<GpuType> = GpuType::ALL
            .iter()
            .copied()
            .filter(|&t| self.nodes.iter().any(|n| n.capacity(t) > 0))
            .collect();
        types.sort();
        types
    }

    /// Total capacity of one GPU type across the cluster.
    pub fn capacity_of(&self, r: GpuType) -> usize {
        self.nodes.iter().map(|n| n.capacity(r)).sum()
    }

    // ------------------------------------------------------------- JSON I/O

    /// Emit as a JSON object (the inline-cluster format of sweep specs).
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> =
            self.nodes.iter().map(|n| n.to_json()).collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("nodes", Json::Arr(nodes))
    }

    /// Parse a cluster object; node `id`/`name` default to the list index.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = v.get("name").as_str().unwrap_or("custom").to_string();
        let mut nodes = Vec::new();
        for (i, nv) in v
            .get("nodes")
            .as_arr()
            .ok_or("cluster: 'nodes' must be an array")?
            .iter()
            .enumerate()
        {
            nodes.push(Node::from_json(nv, i)?);
        }
        if nodes.is_empty() {
            return Err("cluster has no nodes".into());
        }
        Ok(ClusterSpec { name, nodes })
    }

    /// Parse a cluster from JSON text.
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim60_matches_paper() {
        let c = ClusterSpec::sim60();
        assert_eq!(c.nodes.len(), 15);
        assert_eq!(c.total_gpus(), 60);
        assert_eq!(c.capacity_of(GpuType::V100), 20);
        assert_eq!(c.capacity_of(GpuType::P100), 20);
        assert_eq!(c.capacity_of(GpuType::K80), 20);
    }

    #[test]
    fn aws5_and_testbed5_are_five_single_gpu_nodes() {
        for c in [ClusterSpec::aws5(), ClusterSpec::testbed5()] {
            assert_eq!(c.nodes.len(), 5);
            assert_eq!(c.total_gpus(), 5);
            assert!(c.nodes.iter().all(|n| n.total_gpus() == 1));
        }
        assert_eq!(ClusterSpec::testbed5().gpu_types().len(), 5);
    }

    #[test]
    fn synthetic256_matches_its_name() {
        let c = ClusterSpec::synthetic256();
        assert_eq!(c.nodes.len(), 256);
        assert_eq!(c.total_gpus(), 1024);
        assert_eq!(c.gpu_types().len(), 4);
    }

    #[test]
    fn big8_is_four_two_pool_nodes() {
        let c = ClusterSpec::big8();
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.total_gpus(), 32);
        for n in &c.nodes {
            assert_eq!(n.total_gpus(), 8);
            let gang: Vec<(GpuType, usize)> = n.gang().collect();
            assert_eq!(
                gang,
                vec![(GpuType::V100, 4), (GpuType::P100, 4)],
                "each big node carries two 4-GPU pools"
            );
        }
        let scaled = ClusterSpec::big(20, 4);
        assert_eq!(scaled.nodes.len(), 20);
        assert_eq!(scaled.total_gpus(), 160);
        assert_eq!(scaled.name, "big20x4");
    }

    #[test]
    fn motivational_matches_fig1() {
        let c = ClusterSpec::motivational();
        assert_eq!(c.capacity_of(GpuType::V100), 2);
        assert_eq!(c.capacity_of(GpuType::P100), 3);
        assert_eq!(c.capacity_of(GpuType::K80), 1);
    }

    #[test]
    fn json_roundtrip() {
        let c = ClusterSpec::testbed5();
        let txt = c.to_json().pretty();
        let c2 = ClusterSpec::parse(&txt).unwrap();
        assert_eq!(c2.nodes.len(), c.nodes.len());
        assert_eq!(c2.total_gpus(), c.total_gpus());
        assert_eq!(c2.gpu_types(), c.gpu_types());
    }

    #[test]
    fn event_mutators_add_remove_and_resize() {
        let mut c = ClusterSpec::motivational();
        assert_eq!(c.total_gpus(), 6);
        let gone = c.remove_node(0).unwrap();
        assert_eq!(gone.capacity(GpuType::V100), 2);
        assert_eq!(c.total_gpus(), 4);
        assert!(c.node(0).is_none());
        assert!(c.remove_node(0).is_none());
        c.add_node(gone);
        assert_eq!(c.total_gpus(), 6);
        assert_eq!(c.set_capacity(1, GpuType::P100, 1), Some(3));
        assert_eq!(c.capacity_of(GpuType::P100), 1);
        assert_eq!(c.set_capacity(2, GpuType::K80, 0), Some(1));
        assert_eq!(c.capacity_of(GpuType::K80), 0);
        assert!(!c.gpu_types().contains(&GpuType::K80));
        assert_eq!(c.set_capacity(99, GpuType::K80, 1), None);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(ClusterSpec::parse("{}").is_err());
        assert!(ClusterSpec::parse(
            r#"{"nodes": [{"gpus": {"NotAGpu": 1}}]}"#
        )
        .is_err());
    }
}
