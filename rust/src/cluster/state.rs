//! Per-round allocation state: `γ_h^r(t)` (allocated counts) against
//! capacities `c_h^r`, with the allocate/undo bookkeeping all schedulers
//! share.
//!
//! §Perf note: storage is dense `[node][type]` arrays rather than maps,
//! and the three quantities the Hadar DP hammers are all maintained
//! *incrementally* (see `docs/performance.md` for the hot-path map and
//! the before/after numbers):
//!
//! * [`ClusterState::digest`] — a Zobrist-style rolling digest (XOR of
//!   per-`(node, type, count)` keys) updated O(1) per allocate/undo,
//!   replacing an O(nodes × types) FNV rescan per DP memo probe;
//! * [`ClusterState::free_slots_of_type`] — a per-type bucket index over
//!   free counts, so `FIND_ALLOC` iterates candidate pools in
//!   most-free-first order without rebuilding + sorting a slot list per
//!   call;
//! * [`ClusterState::checkpoint`] / [`ClusterState::rewind`] — O(1)-per-
//!   assignment undo, so the DP explores select branches by mutating one
//!   state instead of cloning the whole struct at every node.

use crate::cluster::gpu::GpuType;
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::JobId;

const NTYPES: usize = GpuType::ALL.len();

#[inline]
fn tix(g: GpuType) -> usize {
    g as usize
}

/// Zobrist key for one `(node, type, allocated-count)` cell, generated
/// procedurally (splitmix64 finaliser over the packed cell id) instead of
/// from a precomputed table — same statistical quality, no per-cluster
/// setup cost. The digest of a state is the XOR of the keys of every
/// pool's current count, so changing one pool's count is two XORs.
#[inline]
fn zkey(node: usize, t: usize, count: usize) -> u64 {
    // count < 2^16 (u16 storage), t < 2^8: the packed id is collision-free.
    let cell = ((node as u64) << 24) | ((t as u64) << 16) | count as u64;
    let mut z = cell.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One allocation entry: `w_{jh}^r` GPUs of type `r` on node `h` for job `j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// The job holding the GPUs.
    pub job: JobId,
    /// Node id `h`.
    pub node: usize,
    /// GPU type `r`.
    pub gpu: GpuType,
    /// Workers `w_{jh}^r`.
    pub count: usize,
}

/// Checkpoint token for [`ClusterState::rewind`]: the assignment-log length
/// at the time of [`ClusterState::checkpoint`]. Opaque on purpose — only
/// meaningful against the state that produced it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StateMark(usize);

/// Mutable view of the cluster within a scheduling round.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// `γ_h^r(t)`, dense [node][type].
    allocated: Vec<[u16; NTYPES]>,
    /// Capacity `c_h^r`, dense [node][type].
    capacity: Vec<[u16; NTYPES]>,
    /// Free GPUs per type across all nodes (incrementally maintained).
    free_by_type: [i64; NTYPES],
    total_free_count: i64,
    total_capacity_count: i64,
    /// Live assignments in allocation order — doubles as the undo log for
    /// [`ClusterState::rewind`].
    assignments: Vec<Assignment>,
    /// Zobrist rolling digest over all pools with capacity (incrementally
    /// maintained; see [`zkey`]).
    zobrist: u64,
    /// Per-type rolling digests: `type_digests[t]` XORs the same keys as
    /// `zobrist` but only over type-`t` pools, so the digest of any *set*
    /// of types is an O(set) XOR of entries
    /// ([`ClusterState::digest_of_types`]) — the signature the Hadar
    /// no-candidate rows are invalidated by.
    type_digests: [u64; NTYPES],
    /// FNV-1a digest of the capacity matrix. Capacities are fixed for the
    /// lifetime of one snapshot, so this is computed once in
    /// [`ClusterState::new`] and never maintained. Needed because the
    /// Zobrist digests cover *allocated counts* only: two clusters with
    /// different capacities but equal allocations share a `zobrist`.
    cap_digest: u64,
    /// Per-type free-slot buckets: `slot_index[t][f]` holds the ids (sorted
    /// ascending) of nodes with exactly `f` free type-`t` GPUs, for
    /// `f >= 1`. Bucket 0 stays empty — fully-allocated pools leave the
    /// index entirely.
    slot_index: Vec<Vec<Vec<u32>>>,
}

impl ClusterState {
    /// Fresh all-free state for one cluster snapshot. Rebuilt every round
    /// by the schedulers, so dynamic clusters (node churn) need no special
    /// handling here — missing node ids simply have zero capacity.
    pub fn new(spec: &ClusterSpec) -> Self {
        let n = spec
            .nodes
            .iter()
            .map(|nd| nd.id + 1)
            .max()
            .unwrap_or(0);
        let mut capacity = vec![[0u16; NTYPES]; n];
        let mut free_by_type = [0i64; NTYPES];
        let mut total = 0i64;
        for node in &spec.nodes {
            for (&g, &c) in &node.gpus {
                capacity[node.id][tix(g)] = c as u16;
                free_by_type[tix(g)] += c as i64;
                total += c as i64;
            }
        }
        // Seed the rolling digests and the free-slot buckets from the
        // all-free position (O(nodes × types), once per round).
        let mut zobrist = 0u64;
        let mut type_digests = [0u64; NTYPES];
        let mut cap_digest = 0xCBF2_9CE4_8422_2325u64; // FNV-1a offset
        let mut slot_index: Vec<Vec<Vec<u32>>> = Vec::with_capacity(NTYPES);
        for t in 0..NTYPES {
            let max_cap = capacity
                .iter()
                .map(|row| row[t] as usize)
                .max()
                .unwrap_or(0);
            let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); max_cap + 1];
            for (h, row) in capacity.iter().enumerate() {
                let c = row[t] as usize;
                if c > 0 {
                    zobrist ^= zkey(h, t, 0);
                    type_digests[t] ^= zkey(h, t, 0);
                    let cell = ((h as u64) << 24)
                        | ((t as u64) << 16)
                        | c as u64;
                    cap_digest = (cap_digest ^ cell)
                        .wrapping_mul(0x0000_0100_0000_01B3);
                    buckets[c].push(h as u32);
                }
            }
            // Nodes were visited in id order, so each bucket is sorted.
            slot_index.push(buckets);
        }
        ClusterState {
            allocated: vec![[0u16; NTYPES]; n],
            capacity,
            free_by_type,
            total_free_count: total,
            total_capacity_count: total,
            assignments: Vec::new(),
            zobrist,
            type_digests,
            cap_digest,
            slot_index,
        }
    }

    /// One past the largest node id (iteration bound; ids may be sparse).
    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.capacity.len()
    }

    /// Capacity `c_h^r` (0 for unknown nodes/types).
    #[inline]
    pub fn capacity(&self, node: usize, gpu: GpuType) -> usize {
        self.capacity
            .get(node)
            .map(|row| row[tix(gpu)] as usize)
            .unwrap_or(0)
    }

    /// `γ_h^r(t)`.
    #[inline]
    pub fn allocated(&self, node: usize, gpu: GpuType) -> usize {
        self.allocated
            .get(node)
            .map(|row| row[tix(gpu)] as usize)
            .unwrap_or(0)
    }

    /// Free GPUs in one `(node, type)` pool.
    #[inline]
    pub fn free(&self, node: usize, gpu: GpuType) -> usize {
        self.capacity(node, gpu) - self.allocated(node, gpu)
    }

    /// Total free GPUs of one type across all nodes — O(1).
    #[inline]
    pub fn free_of_type(&self, gpu: GpuType) -> usize {
        self.free_by_type[tix(gpu)] as usize
    }

    /// Free GPUs across the whole cluster — O(1).
    #[inline]
    pub fn total_free(&self) -> usize {
        self.total_free_count as usize
    }

    /// Total GPUs in this snapshot — O(1).
    #[inline]
    pub fn total_capacity(&self) -> usize {
        self.total_capacity_count as usize
    }

    /// Allocated GPUs across the whole cluster — O(1).
    #[inline]
    pub fn total_allocated(&self) -> usize {
        (self.total_capacity_count - self.total_free_count) as usize
    }

    /// All (node, type, free) triples with free > 0, node-major.
    pub fn free_slots(&self) -> Vec<(usize, GpuType, usize)> {
        let mut out = Vec::new();
        for (h, (cap, alloc)) in
            self.capacity.iter().zip(self.allocated.iter()).enumerate()
        {
            for (t, (&c, &a)) in cap.iter().zip(alloc.iter()).enumerate() {
                if c > a {
                    out.push((h, GpuType::ALL[t], (c - a) as usize));
                }
            }
        }
        out
    }

    /// `(node, free)` pairs with free type-`gpu` GPUs, most-free first and
    /// node-id ascending within equal free counts — the order `FIND_ALLOC`
    /// fills spread allocations in. Served from the incrementally
    /// maintained bucket index: no per-call rebuild, no sort.
    pub fn free_slots_of_type(
        &self,
        gpu: GpuType,
    ) -> impl Iterator<Item = (usize, usize)> + '_ {
        if crate::obs::enabled() {
            crate::obs::metrics::core().state_slot_scans.add(1);
        }
        self.slot_index[tix(gpu)]
            .iter()
            .enumerate()
            .rev()
            .flat_map(|(f, bucket)| {
                bucket.iter().map(move |&h| (h as usize, f))
            })
    }

    /// Whether every GPU in the cluster is allocated — O(1).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.total_free_count == 0
    }

    /// Shift one pool's allocated count by `delta` (positive = allocate),
    /// maintaining the per-type totals, the Zobrist digest, and the
    /// free-slot buckets. Callers guarantee the result stays within
    /// `[0, capacity]`.
    fn shift_pool(&mut self, node: usize, t: usize, delta: i64) {
        let cap = self.capacity[node][t] as usize;
        let old = self.allocated[node][t] as usize;
        let new = (old as i64 + delta) as usize;
        debug_assert!(new <= cap, "pool over/underflow");
        self.allocated[node][t] = new as u16;
        self.free_by_type[t] -= delta;
        self.total_free_count -= delta;
        let dk = zkey(node, t, old) ^ zkey(node, t, new);
        self.zobrist ^= dk;
        self.type_digests[t] ^= dk;
        let (old_free, new_free) = (cap - old, cap - new);
        if old_free > 0 {
            let bucket = &mut self.slot_index[t][old_free];
            let i = bucket
                .binary_search(&(node as u32))
                .expect("indexed node present in its free bucket");
            bucket.remove(i);
        }
        if new_free > 0 {
            let bucket = &mut self.slot_index[t][new_free];
            let i = bucket
                .binary_search(&(node as u32))
                .expect_err("node cannot already sit in the target bucket");
            bucket.insert(i, node as u32);
        }
    }

    /// Record an allocation. Panics if capacity is exceeded (scheduler bug —
    /// constraint (1d) must hold by construction).
    pub fn allocate(&mut self, a: Assignment) {
        assert!(a.count > 0, "zero-count assignment");
        let free = self.free(a.node, a.gpu);
        assert!(
            a.count <= free,
            "capacity violation: node {} type {:?}: want {} free {}",
            a.node,
            a.gpu,
            a.count,
            free
        );
        self.shift_pool(a.node, tix(a.gpu), a.count as i64);
        self.assignments.push(a);
    }

    /// Snapshot the current position of the assignment log. Pair with
    /// [`ClusterState::rewind`] to undo everything allocated since — the
    /// zero-clone select-branch pattern of the Hadar DP.
    #[inline]
    pub fn checkpoint(&self) -> StateMark {
        if crate::obs::enabled() {
            crate::obs::metrics::core().state_checkpoints.add(1);
        }
        StateMark(self.assignments.len())
    }

    /// Undo every allocation made after `mark`, restoring counts, totals,
    /// digest, and free-slot buckets exactly (see the round-trip property
    /// test in `rust/tests/prop_invariants.rs`). O(assignments undone).
    pub fn rewind(&mut self, mark: StateMark) {
        debug_assert!(mark.0 <= self.assignments.len(), "stale mark");
        if crate::obs::enabled() {
            let m = crate::obs::metrics::core();
            m.state_rewinds.add(1);
            m.state_rewound_assignments
                .add(self.assignments.len().saturating_sub(mark.0) as u64);
        }
        while self.assignments.len() > mark.0 {
            let a = self.assignments.pop().expect("log longer than mark");
            self.shift_pool(a.node, tix(a.gpu), -(a.count as i64));
        }
    }

    /// Release every assignment of one job; returns how many GPUs freed.
    pub fn release_job(&mut self, job: JobId) -> usize {
        let mut freed = 0;
        let mut kept = Vec::with_capacity(self.assignments.len());
        for a in std::mem::take(&mut self.assignments) {
            if a.job == job {
                self.shift_pool(a.node, tix(a.gpu), -(a.count as i64));
                freed += a.count;
            } else {
                kept.push(a);
            }
        }
        self.assignments = kept;
        freed
    }

    /// All live assignments, in allocation order.
    pub fn assignments(&self) -> &[Assignment] {
        &self.assignments
    }

    /// One job's live assignments.
    pub fn assignments_of(&self, job: JobId) -> Vec<Assignment> {
        self.assignments
            .iter()
            .copied()
            .filter(|a| a.job == job)
            .collect()
    }

    /// GPU types a job currently uses (for the bottleneck rule Eq. (1b)).
    pub fn gpu_types_of(&self, job: JobId) -> Vec<GpuType> {
        let mut types: Vec<GpuType> = self
            .assignments
            .iter()
            .filter(|a| a.job == job)
            .map(|a| a.gpu)
            .collect();
        types.sort();
        types.dedup();
        types
    }

    /// Distinct nodes a job currently uses (consolidation check).
    pub fn nodes_of(&self, job: JobId) -> Vec<usize> {
        let mut nodes: Vec<usize> = self
            .assignments
            .iter()
            .filter(|a| a.job == job)
            .map(|a| a.node)
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Digest of the free state (DP memo key) — O(1). The Zobrist rolling
    /// digest is updated on every allocate/rewind/release, so equal digests
    /// mean equal `γ` matrices (modulo the usual 64-bit collision odds,
    /// same as any hashed memo key).
    #[inline]
    pub fn digest(&self) -> u64 {
        self.zobrist
    }

    /// Digest of the capacity matrix — O(1), fixed for this snapshot.
    /// Distinguishes clusters the allocation digests cannot: the Zobrist
    /// keys cover allocated counts, not capacities, so round signatures
    /// that must change under node churn fold this in too.
    #[inline]
    pub fn capacity_digest(&self) -> u64 {
        self.cap_digest
    }

    /// Rolling digest restricted to a set of GPU types — O(types).
    /// Equal values mean every type-`g` pool (for `g` in `types`) holds
    /// the allocation counts it held when the other digest was taken,
    /// which is exactly the read set of one `FIND_ALLOC` scoring call.
    /// `types` must hold distinct entries (duplicates XOR-cancel).
    #[inline]
    pub fn digest_of_types(&self, types: &[GpuType]) -> u64 {
        types
            .iter()
            .fold(0u64, |d, &g| d ^ self.type_digests[tix(g)])
    }

    /// Candidate nodes for a *packed* (single-node) allocation of `want`
    /// GPUs drawn from `types`, ascending by node id — the order the
    /// historical full scan visited them in, so payoff ties break
    /// identically. Served from the free-slot buckets:
    ///
    /// * one type: exactly the nodes with `>= want` free type GPUs
    ///   (buckets `want..`);
    /// * several types: every node with at least one free GPU of any of
    ///   the types — a superset of the feasible set (per-node sums are
    ///   not indexed), but omitted nodes provably cannot contribute.
    ///
    /// Fully-busy nodes never appear, which is what makes the packed
    /// scan O(candidates) instead of O(nodes).
    pub fn packed_candidates(
        &self,
        types: &[GpuType],
        want: usize,
    ) -> Vec<u32> {
        if crate::obs::enabled() {
            crate::obs::metrics::core().state_slot_scans.add(1);
        }
        let mut out: Vec<u32> = Vec::new();
        if let [g] = types {
            let buckets = &self.slot_index[tix(*g)];
            for bucket in &buckets[want.min(buckets.len())..] {
                out.extend_from_slice(bucket);
            }
            out.sort_unstable();
        } else {
            for &g in types {
                for bucket in &self.slot_index[tix(g)][1..] {
                    out.extend_from_slice(bucket);
                }
            }
            out.sort_unstable();
            out.dedup();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;

    fn state() -> ClusterState {
        ClusterState::new(&ClusterSpec::motivational())
    }

    #[test]
    fn initial_state_is_empty() {
        let s = state();
        assert_eq!(s.total_free(), 6);
        assert_eq!(s.total_allocated(), 0);
        assert!(!s.is_full());
    }

    #[test]
    fn allocate_and_release() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 1 });
        assert_eq!(s.free(0, GpuType::V100), 0);
        assert_eq!(s.total_allocated(), 3);
        assert_eq!(s.free_of_type(GpuType::P100), 2);
        assert_eq!(s.gpu_types_of(JobId(1)), vec![GpuType::V100, GpuType::P100]);
        assert_eq!(s.nodes_of(JobId(1)), vec![0, 1]);
        assert_eq!(s.release_job(JobId(1)), 3);
        assert_eq!(s.total_allocated(), 0);
        assert_eq!(s.free_of_type(GpuType::P100), 3);
    }

    #[test]
    #[should_panic(expected = "capacity violation")]
    fn over_allocation_panics() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 3 });
    }

    #[test]
    fn free_slots_reflect_allocations() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(2), node: 2, gpu: GpuType::K80, count: 1 });
        let slots = s.free_slots();
        assert!(!slots.iter().any(|&(h, g, _)| h == 2 && g == GpuType::K80));
        assert_eq!(s.free_of_type(GpuType::P100), 3);
    }

    #[test]
    fn is_full_when_everything_allocated() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 3 });
        s.allocate(Assignment { job: JobId(1), node: 2, gpu: GpuType::K80, count: 1 });
        assert!(s.is_full());
    }

    #[test]
    fn digest_changes_with_allocations() {
        let mut s = state();
        let d0 = s.digest();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 1 });
        assert_ne!(d0, s.digest());
        s.release_job(JobId(1));
        assert_eq!(d0, s.digest());
    }

    #[test]
    fn checkpoint_rewind_round_trips() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 1 });
        let d1 = s.digest();
        let free1 = s.free(1, GpuType::P100);
        let mark = s.checkpoint();
        s.allocate(Assignment { job: JobId(2), node: 0, gpu: GpuType::V100, count: 2 });
        s.allocate(Assignment { job: JobId(2), node: 1, gpu: GpuType::P100, count: 2 });
        assert_ne!(s.digest(), d1);
        s.rewind(mark);
        assert_eq!(s.digest(), d1);
        assert_eq!(s.free(1, GpuType::P100), free1);
        assert_eq!(s.free(0, GpuType::V100), 2);
        assert_eq!(s.assignments().len(), 1);
        assert_eq!(s.total_allocated(), 1);
    }

    #[test]
    fn slot_index_orders_most_free_first_with_node_tiebreak() {
        // motivational: node 0 = 2x V100, node 1 = 3x P100, node 2 = 1x K80.
        let mut s = state();
        assert_eq!(
            s.free_slots_of_type(GpuType::P100).collect::<Vec<_>>(),
            vec![(1, 3)]
        );
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 1 });
        assert_eq!(
            s.free_slots_of_type(GpuType::P100).collect::<Vec<_>>(),
            vec![(1, 2)]
        );
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 2 });
        assert!(s.free_slots_of_type(GpuType::P100).next().is_none());
        s.release_job(JobId(1));
        assert_eq!(
            s.free_slots_of_type(GpuType::P100).collect::<Vec<_>>(),
            vec![(1, 3)]
        );
    }

    #[test]
    fn capacity_digest_fixed_under_allocations() {
        let mut s = state();
        let d = s.capacity_digest();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        assert_eq!(d, s.capacity_digest(), "allocations never move it");
        let other = ClusterState::new(&ClusterSpec::sim60());
        assert_ne!(d, other.capacity_digest(), "different capacity matrix");
    }

    #[test]
    fn type_digests_track_only_touched_types() {
        let mut s = state();
        let v0 = s.digest_of_types(&[GpuType::V100]);
        let p0 = s.digest_of_types(&[GpuType::P100]);
        let both0 = s.digest_of_types(&[GpuType::V100, GpuType::P100]);
        assert_eq!(both0, v0 ^ p0, "set digest is the XOR of its types");
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 1 });
        assert_ne!(v0, s.digest_of_types(&[GpuType::V100]));
        assert_eq!(p0, s.digest_of_types(&[GpuType::P100]),
                   "untouched type keeps its digest");
        s.release_job(JobId(1));
        assert_eq!(v0, s.digest_of_types(&[GpuType::V100]));
    }

    #[test]
    fn packed_candidates_single_type_matches_brute_force() {
        let mut s = ClusterState::new(&ClusterSpec::sim60());
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::V100, count: 3 });
        s.allocate(Assignment { job: JobId(1), node: 3, gpu: GpuType::V100, count: 4 });
        for want in 1..=5usize {
            let got = s.packed_candidates(&[GpuType::V100], want);
            let want_nodes: Vec<u32> = (0..s.n_nodes())
                .filter(|&h| s.free(h, GpuType::V100) >= want)
                .map(|h| h as u32)
                .collect();
            assert_eq!(got, want_nodes, "want={want}");
        }
        // Beyond the largest bucket: empty, no slice panic.
        assert!(s.packed_candidates(&[GpuType::V100], 99).is_empty());
    }

    #[test]
    fn packed_candidates_multi_type_union_is_sorted_dedup() {
        let mut s = state();
        // motivational: node 0 = 2x V100, node 1 = 3x P100, node 2 = 1x K80.
        let got = s.packed_candidates(&[GpuType::V100, GpuType::P100], 2);
        assert_eq!(got, vec![0, 1]);
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        let got = s.packed_candidates(&[GpuType::V100, GpuType::P100], 2);
        assert_eq!(got, vec![1], "fully-busy node 0 leaves the index");
    }

    #[test]
    fn slot_index_matches_rebuild_on_wider_cluster() {
        // sim60: 5 nodes per type, 4 GPUs each — exercise ties + ordering.
        let mut s = ClusterState::new(&ClusterSpec::sim60());
        s.allocate(Assignment { job: JobId(7), node: 1, gpu: GpuType::V100, count: 3 });
        s.allocate(Assignment { job: JobId(7), node: 3, gpu: GpuType::V100, count: 1 });
        let got: Vec<(usize, usize)> =
            s.free_slots_of_type(GpuType::V100).collect();
        // Rebuild the old way: stable sort by free desc over node order.
        let mut want: Vec<(usize, usize)> = (0..s.n_nodes())
            .map(|h| (h, s.free(h, GpuType::V100)))
            .filter(|&(_, f)| f > 0)
            .collect();
        want.sort_by(|a, b| b.1.cmp(&a.1));
        assert_eq!(got, want);
    }
}
