//! Per-round allocation state: `γ_h^r(t)` (allocated counts) against
//! capacities `c_h^r`, with the allocate/release bookkeeping all schedulers
//! share.
//!
//! §Perf note: storage is dense `[node][type]` arrays rather than maps —
//! `find_alloc` scans every (node, type) pool for every queued job, so pool
//! lookup is the hottest load in the Fig. 5 scalability path (see
//! EXPERIMENTS.md §Perf for the before/after).

use crate::cluster::gpu::GpuType;
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::JobId;

const NTYPES: usize = GpuType::ALL.len();

#[inline]
fn tix(g: GpuType) -> usize {
    g as usize
}

/// One allocation entry: `w_{jh}^r` GPUs of type `r` on node `h` for job `j`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// The job holding the GPUs.
    pub job: JobId,
    /// Node id `h`.
    pub node: usize,
    /// GPU type `r`.
    pub gpu: GpuType,
    /// Workers `w_{jh}^r`.
    pub count: usize,
}

/// Mutable view of the cluster within a scheduling round.
#[derive(Clone, Debug)]
pub struct ClusterState {
    /// `γ_h^r(t)`, dense [node][type].
    allocated: Vec<[u16; NTYPES]>,
    /// Capacity `c_h^r`, dense [node][type].
    capacity: Vec<[u16; NTYPES]>,
    /// Free GPUs per type across all nodes (incrementally maintained).
    free_by_type: [i64; NTYPES],
    total_free_count: i64,
    total_capacity_count: i64,
    /// Live assignments for introspection/release.
    assignments: Vec<Assignment>,
}

impl ClusterState {
    /// Fresh all-free state for one cluster snapshot. Rebuilt every round
    /// by the schedulers, so dynamic clusters (node churn) need no special
    /// handling here — missing node ids simply have zero capacity.
    pub fn new(spec: &ClusterSpec) -> Self {
        let n = spec
            .nodes
            .iter()
            .map(|nd| nd.id + 1)
            .max()
            .unwrap_or(0);
        let mut capacity = vec![[0u16; NTYPES]; n];
        let mut free_by_type = [0i64; NTYPES];
        let mut total = 0i64;
        for node in &spec.nodes {
            for (&g, &c) in &node.gpus {
                capacity[node.id][tix(g)] = c as u16;
                free_by_type[tix(g)] += c as i64;
                total += c as i64;
            }
        }
        ClusterState {
            allocated: vec![[0u16; NTYPES]; n],
            capacity,
            free_by_type,
            total_free_count: total,
            total_capacity_count: total,
            assignments: Vec::new(),
        }
    }

    /// One past the largest node id (iteration bound; ids may be sparse).
    #[inline]
    pub fn n_nodes(&self) -> usize {
        self.capacity.len()
    }

    /// Capacity `c_h^r` (0 for unknown nodes/types).
    #[inline]
    pub fn capacity(&self, node: usize, gpu: GpuType) -> usize {
        self.capacity
            .get(node)
            .map(|row| row[tix(gpu)] as usize)
            .unwrap_or(0)
    }

    /// `γ_h^r(t)`.
    #[inline]
    pub fn allocated(&self, node: usize, gpu: GpuType) -> usize {
        self.allocated
            .get(node)
            .map(|row| row[tix(gpu)] as usize)
            .unwrap_or(0)
    }

    /// Free GPUs in one `(node, type)` pool.
    #[inline]
    pub fn free(&self, node: usize, gpu: GpuType) -> usize {
        self.capacity(node, gpu) - self.allocated(node, gpu)
    }

    /// Total free GPUs of one type across all nodes — O(1).
    #[inline]
    pub fn free_of_type(&self, gpu: GpuType) -> usize {
        self.free_by_type[tix(gpu)] as usize
    }

    /// Free GPUs across the whole cluster — O(1).
    #[inline]
    pub fn total_free(&self) -> usize {
        self.total_free_count as usize
    }

    /// Total GPUs in this snapshot — O(1).
    #[inline]
    pub fn total_capacity(&self) -> usize {
        self.total_capacity_count as usize
    }

    /// Allocated GPUs across the whole cluster — O(1).
    #[inline]
    pub fn total_allocated(&self) -> usize {
        (self.total_capacity_count - self.total_free_count) as usize
    }

    /// All (node, type, free) triples with free > 0.
    pub fn free_slots(&self) -> Vec<(usize, GpuType, usize)> {
        let mut out = Vec::new();
        for (h, (cap, alloc)) in
            self.capacity.iter().zip(self.allocated.iter()).enumerate()
        {
            for (t, (&c, &a)) in cap.iter().zip(alloc.iter()).enumerate() {
                if c > a {
                    out.push((h, GpuType::ALL[t], (c - a) as usize));
                }
            }
        }
        out
    }

    /// Whether every GPU in the cluster is allocated — O(1).
    #[inline]
    pub fn is_full(&self) -> bool {
        self.total_free_count == 0
    }

    /// Record an allocation. Panics if capacity is exceeded (scheduler bug —
    /// constraint (1d) must hold by construction).
    pub fn allocate(&mut self, a: Assignment) {
        assert!(a.count > 0, "zero-count assignment");
        let free = self.free(a.node, a.gpu);
        assert!(
            a.count <= free,
            "capacity violation: node {} type {:?}: want {} free {}",
            a.node,
            a.gpu,
            a.count,
            free
        );
        self.allocated[a.node][tix(a.gpu)] += a.count as u16;
        self.free_by_type[tix(a.gpu)] -= a.count as i64;
        self.total_free_count -= a.count as i64;
        self.assignments.push(a);
    }

    /// Release every assignment of one job; returns how many GPUs freed.
    pub fn release_job(&mut self, job: JobId) -> usize {
        let mut freed = 0;
        let allocated = &mut self.allocated;
        let free_by_type = &mut self.free_by_type;
        let total_free = &mut self.total_free_count;
        self.assignments.retain(|a| {
            if a.job == job {
                allocated[a.node][tix(a.gpu)] -= a.count as u16;
                free_by_type[tix(a.gpu)] += a.count as i64;
                *total_free += a.count as i64;
                freed += a.count;
                false
            } else {
                true
            }
        });
        freed
    }

    /// All live assignments, in allocation order.
    pub fn assignments(&self) -> &[Assignment] {
        &self.assignments
    }

    /// One job's live assignments.
    pub fn assignments_of(&self, job: JobId) -> Vec<Assignment> {
        self.assignments
            .iter()
            .copied()
            .filter(|a| a.job == job)
            .collect()
    }

    /// GPU types a job currently uses (for the bottleneck rule Eq. (1b)).
    pub fn gpu_types_of(&self, job: JobId) -> Vec<GpuType> {
        let mut types: Vec<GpuType> = self
            .assignments
            .iter()
            .filter(|a| a.job == job)
            .map(|a| a.gpu)
            .collect();
        types.sort();
        types.dedup();
        types
    }

    /// Distinct nodes a job currently uses (consolidation check).
    pub fn nodes_of(&self, job: JobId) -> Vec<usize> {
        let mut nodes: Vec<usize> = self
            .assignments
            .iter()
            .filter(|a| a.job == job)
            .map(|a| a.node)
            .collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Fast digest of the free state (DP memo key). FNV-1a over the dense
    /// allocation rows.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for row in &self.allocated {
            for &a in row {
                h ^= a as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;

    fn state() -> ClusterState {
        ClusterState::new(&ClusterSpec::motivational())
    }

    #[test]
    fn initial_state_is_empty() {
        let s = state();
        assert_eq!(s.total_free(), 6);
        assert_eq!(s.total_allocated(), 0);
        assert!(!s.is_full());
    }

    #[test]
    fn allocate_and_release() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 1 });
        assert_eq!(s.free(0, GpuType::V100), 0);
        assert_eq!(s.total_allocated(), 3);
        assert_eq!(s.free_of_type(GpuType::P100), 2);
        assert_eq!(s.gpu_types_of(JobId(1)), vec![GpuType::V100, GpuType::P100]);
        assert_eq!(s.nodes_of(JobId(1)), vec![0, 1]);
        assert_eq!(s.release_job(JobId(1)), 3);
        assert_eq!(s.total_allocated(), 0);
        assert_eq!(s.free_of_type(GpuType::P100), 3);
    }

    #[test]
    #[should_panic(expected = "capacity violation")]
    fn over_allocation_panics() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 3 });
    }

    #[test]
    fn free_slots_reflect_allocations() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(2), node: 2, gpu: GpuType::K80, count: 1 });
        let slots = s.free_slots();
        assert!(!slots.iter().any(|&(h, g, _)| h == 2 && g == GpuType::K80));
        assert_eq!(s.free_of_type(GpuType::P100), 3);
    }

    #[test]
    fn is_full_when_everything_allocated() {
        let mut s = state();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 2 });
        s.allocate(Assignment { job: JobId(1), node: 1, gpu: GpuType::P100, count: 3 });
        s.allocate(Assignment { job: JobId(1), node: 2, gpu: GpuType::K80, count: 1 });
        assert!(s.is_full());
    }

    #[test]
    fn digest_changes_with_allocations() {
        let mut s = state();
        let d0 = s.digest();
        s.allocate(Assignment { job: JobId(1), node: 0, gpu: GpuType::V100, count: 1 });
        assert_ne!(d0, s.digest());
        s.release_job(JobId(1));
        assert_eq!(d0, s.digest());
    }
}
