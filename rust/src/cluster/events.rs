//! Cluster event timeline — dynamic clusters for the simulators.
//!
//! Real GPU datacenters are not static: nodes drain for maintenance, fail
//! and leave, or join as capacity grows (the dominant operational reality
//! in the Helios characterisation; an open challenge in the Gao et al.
//! scheduling survey). This module adds that dimension to the otherwise
//! static [`ClusterSpec`]:
//!
//! * [`ClusterEvent`] / [`EventKind`] — one timed change: a node **join**,
//!   a permanent **leave**, a **maintenance** window (drain + automatic
//!   rejoin), or a per-pool **capacity change**.
//! * [`EventTimeline`] — an ordered event list, JSON-loadable (the file
//!   format behind `hadar simulate --events <file>` and the sweep specs'
//!   `events` axis; schema in `docs/simulation.md`).
//! * [`ChurnConfig`] / [`generate_churn`] — a seeded, deterministic churn
//!   generator, so sweeps can compare schedulers under *identical* random
//!   event traces.
//! * [`ClusterTimeline`] — the event-aware cluster view the engines drive:
//!   it owns the *current* [`ClusterSpec`] and applies due events at round
//!   boundaries, reporting which nodes were drained/shrunk so the engine
//!   can preempt (and charge the checkpoint-restart overhead to) exactly
//!   the jobs placed there.
//!
//! Timing semantics: engines apply events at the first round boundary at
//! or after `at` (the simulator is round-based; nothing changes mid-slot).
//! Availability accounting (`SimResult::anu`) uses the application time.

use crate::cluster::gpu::GpuType;
use crate::cluster::node::Node;
use crate::cluster::spec::ClusterSpec;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// What happens to the cluster at one instant.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A new node joins the cluster. Its id must not collide with a node
    /// currently present.
    Join(Node),
    /// A node leaves permanently (decommission or unrecovered failure).
    Leave {
        /// Id of the departing node.
        node: usize,
    },
    /// Scheduled maintenance: the node drains at the event time and
    /// rejoins `duration` seconds later with its spec intact.
    Maintenance {
        /// Id of the node being drained.
        node: usize,
        /// Downtime in seconds (must be > 0).
        duration: f64,
    },
    /// Set the capacity of one `(node, GPU type)` pool to `count`
    /// (0 removes the pool — e.g. a failed device or a partial upgrade).
    SetCapacity {
        /// Id of the affected node.
        node: usize,
        /// GPU type whose pool changes.
        gpu: GpuType,
        /// New capacity `c_h^r` (absolute, not a delta).
        count: usize,
    },
}

/// One timed cluster event.
#[derive(Clone, Debug)]
pub struct ClusterEvent {
    /// Simulation time in seconds at which the event takes effect.
    pub at: f64,
    /// The change itself.
    pub kind: EventKind,
}

/// An ordered stream of cluster events (the empty timeline reproduces the
/// static-cluster behaviour exactly).
#[derive(Clone, Debug, Default)]
pub struct EventTimeline {
    /// Label used in scenario ids and reports.
    pub name: String,
    /// The events; [`EventTimeline::resolve`] sorts by time, so callers
    /// may append in any order.
    pub events: Vec<ClusterEvent>,
}

/// A maintenance-free event ready for the engines ([`EventKind`] with
/// `Maintenance` expanded into a `Leave` + a later `Join`).
#[derive(Clone, Debug)]
pub enum ResolvedKind {
    /// A node (re)joins with this spec.
    Join(Node),
    /// A node drains/leaves.
    Leave {
        /// Id of the departing node.
        node: usize,
    },
    /// One `(node, GPU type)` pool is resized to `count`.
    SetCapacity {
        /// Id of the affected node.
        node: usize,
        /// GPU type whose pool changes.
        gpu: GpuType,
        /// New capacity (absolute).
        count: usize,
    },
}

/// One resolved, time-ordered event.
#[derive(Clone, Debug)]
pub struct ResolvedEvent {
    /// Simulation time in seconds.
    pub at: f64,
    /// The change (maintenance already expanded).
    pub kind: ResolvedKind,
}

impl EventTimeline {
    /// The empty timeline (a static cluster).
    pub fn empty() -> Self {
        EventTimeline::default()
    }

    /// Whether the timeline holds no events.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append one event (any time order; `resolve` sorts).
    pub fn push(&mut self, at: f64, kind: EventKind) {
        self.events.push(ClusterEvent { at, kind });
    }

    /// Validate against `initial` and expand into a time-ordered,
    /// maintenance-free list: every referenced node must exist at its
    /// event time, joins must not collide with live ids, and maintenance
    /// windows rejoin with the node's spec as of the drain (including any
    /// earlier capacity changes).
    pub fn resolve(&self, initial: &ClusterSpec)
                   -> Result<Vec<ResolvedEvent>, String> {
        for (i, e) in self.events.iter().enumerate() {
            if !e.at.is_finite() || e.at < 0.0 {
                return Err(format!("event {i}: bad time {}", e.at));
            }
            if let EventKind::Maintenance { duration, .. } = e.kind {
                if !duration.is_finite() || duration <= 0.0 {
                    return Err(format!(
                        "event {i}: maintenance duration must be > 0, got \
                         {duration}"
                    ));
                }
            }
        }
        // Stable time order (original index breaks ties).
        let mut order: Vec<usize> = (0..self.events.len()).collect();
        order.sort_by(|&a, &b| {
            // total_cmp: a NaN timestamp must not panic validation.
            self.events[a]
                .at
                .total_cmp(&self.events[b].at)
                .then(a.cmp(&b))
        });

        // Specs of the nodes currently in the cluster.
        let mut known: BTreeMap<usize, Node> = initial
            .nodes
            .iter()
            .map(|n| (n.id, n.clone()))
            .collect();
        // Maintenance rejoins not yet emitted: (rejoin time, node spec).
        let mut pending: Vec<(f64, Node)> = Vec::new();
        let mut out: Vec<ResolvedEvent> = Vec::new();

        for &i in &order {
            let e = &self.events[i];
            flush_rejoins(e.at, &mut pending, &mut known, &mut out)?;
            match &e.kind {
                EventKind::Join(node) => {
                    if known.contains_key(&node.id) {
                        return Err(format!(
                            "join at t={}: node id {} already present",
                            e.at, node.id
                        ));
                    }
                    known.insert(node.id, node.clone());
                    out.push(ResolvedEvent {
                        at: e.at,
                        kind: ResolvedKind::Join(node.clone()),
                    });
                }
                EventKind::Leave { node } => {
                    known.remove(node).ok_or_else(|| {
                        format!(
                            "leave at t={}: node {} not in cluster",
                            e.at, node
                        )
                    })?;
                    out.push(ResolvedEvent {
                        at: e.at,
                        kind: ResolvedKind::Leave { node: *node },
                    });
                }
                EventKind::Maintenance { node, duration } => {
                    let spec = known.remove(node).ok_or_else(|| {
                        format!(
                            "maintenance at t={}: node {} not in cluster",
                            e.at, node
                        )
                    })?;
                    out.push(ResolvedEvent {
                        at: e.at,
                        kind: ResolvedKind::Leave { node: *node },
                    });
                    pending.push((e.at + duration, spec));
                }
                EventKind::SetCapacity { node, gpu, count } => {
                    let spec = known.get_mut(node).ok_or_else(|| {
                        format!(
                            "set_capacity at t={}: node {} not in cluster",
                            e.at, node
                        )
                    })?;
                    if *count == 0 {
                        spec.gpus.remove(gpu);
                    } else {
                        spec.gpus.insert(*gpu, *count);
                    }
                    out.push(ResolvedEvent {
                        at: e.at,
                        kind: ResolvedKind::SetCapacity {
                            node: *node,
                            gpu: *gpu,
                            count: *count,
                        },
                    });
                }
            }
        }
        flush_rejoins(f64::INFINITY, &mut pending, &mut known, &mut out)?;
        Ok(out)
    }

    // ------------------------------------------------------------- JSON I/O

    /// Emit the timeline as JSON (see `docs/simulation.md` for the schema).
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let base = Json::obj().set("at", e.at);
                match &e.kind {
                    EventKind::Join(node) => base
                        .set("kind", "join")
                        .set("node", node.to_json()),
                    EventKind::Leave { node } => {
                        base.set("kind", "leave").set("node", *node)
                    }
                    EventKind::Maintenance { node, duration } => base
                        .set("kind", "maintenance")
                        .set("node", *node)
                        .set("duration", *duration),
                    EventKind::SetCapacity { node, gpu, count } => base
                        .set("kind", "set_capacity")
                        .set("node", *node)
                        .set("gpu", gpu.name())
                        .set("count", *count),
                }
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("events", Json::Arr(events))
    }

    /// Parse a timeline from its JSON object form.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = v.get("name").as_str().unwrap_or("events").to_string();
        let mut events = Vec::new();
        for (i, ev) in v
            .get("events")
            .as_arr()
            .ok_or("events: 'events' must be an array")?
            .iter()
            .enumerate()
        {
            let at = ev
                .get("at")
                .as_f64()
                .ok_or_else(|| format!("event {i}: 'at' must be a number"))?;
            let kind = match ev.get("kind").as_str() {
                Some("join") => {
                    let nv = ev.get("node");
                    if nv.get("id").as_usize().is_none() {
                        return Err(format!(
                            "event {i}: join 'node' needs an explicit 'id'"
                        ));
                    }
                    EventKind::Join(Node::from_json(nv, 0)?)
                }
                Some("leave") => EventKind::Leave {
                    node: ev.get("node").as_usize().ok_or_else(|| {
                        format!("event {i}: 'node' must be an id")
                    })?,
                },
                Some("maintenance") => EventKind::Maintenance {
                    node: ev.get("node").as_usize().ok_or_else(|| {
                        format!("event {i}: 'node' must be an id")
                    })?,
                    duration: ev.get("duration").as_f64().ok_or_else(
                        || format!("event {i}: 'duration' must be a number"),
                    )?,
                },
                Some("set_capacity") => EventKind::SetCapacity {
                    node: ev.get("node").as_usize().ok_or_else(|| {
                        format!("event {i}: 'node' must be an id")
                    })?,
                    gpu: ev
                        .get("gpu")
                        .as_str()
                        .and_then(GpuType::from_name)
                        .ok_or_else(|| {
                            format!("event {i}: unknown 'gpu' type")
                        })?,
                    count: ev.get("count").as_usize().ok_or_else(|| {
                        format!("event {i}: 'count' must be an integer")
                    })?,
                },
                other => {
                    return Err(format!(
                        "event {i}: 'kind' must be join/leave/maintenance/\
                         set_capacity, got {other:?}"
                    ))
                }
            };
            events.push(ClusterEvent { at, kind });
        }
        Ok(EventTimeline { name, events })
    }

    /// Parse a timeline from JSON text (the `--events <file>` format).
    pub fn parse(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

/// Emit every pending maintenance rejoin due by `upto`, in time order
/// (helper of [`EventTimeline::resolve`]).
fn flush_rejoins(upto: f64, pending: &mut Vec<(f64, Node)>,
                 known: &mut BTreeMap<usize, Node>,
                 out: &mut Vec<ResolvedEvent>) -> Result<(), String> {
    pending.sort_by(|a, b| a.0.total_cmp(&b.0));
    while !pending.is_empty() && pending[0].0 <= upto {
        let (rt, node) = pending.remove(0);
        if known.contains_key(&node.id) {
            return Err(format!(
                "maintenance rejoin at t={rt}: node id {} already present",
                node.id
            ));
        }
        known.insert(node.id, node.clone());
        out.push(ResolvedEvent {
            at: rt,
            kind: ResolvedKind::Join(node),
        });
    }
    Ok(())
}

// ------------------------------------------------------------ churn generator

/// Seeded random-churn parameters: disruptions arrive as a Poisson process
/// and hit a uniformly-chosen live node; most are maintenance windows,
/// a fraction are permanent leaves. The generator never drains the last
/// live node, and the same `(cluster, config)` always yields the same
/// timeline — sweeps compare schedulers under identical churn.
#[derive(Clone, Copy, Debug)]
pub struct ChurnConfig {
    /// Generator seed (also part of the scenario label).
    pub seed: u64,
    /// Mean seconds between disruption events (exponential).
    pub mean_interval_secs: f64,
    /// Shortest maintenance downtime (uniform draw lower bound).
    pub min_down_secs: f64,
    /// Longest maintenance downtime (uniform draw upper bound).
    pub max_down_secs: f64,
    /// Fraction of disruptions that are permanent leaves (0.0..=1.0).
    pub leave_fraction: f64,
    /// Stop generating events after this many seconds.
    pub horizon_secs: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            seed: 7,
            mean_interval_secs: 2.0 * 3600.0,
            min_down_secs: 600.0,
            max_down_secs: 3600.0,
            leave_fraction: 0.1,
            horizon_secs: 24.0 * 3600.0,
        }
    }
}

impl ChurnConfig {
    /// Emit as JSON (the sweep specs' `{"kind": "churn", ...}` form, sans
    /// the `kind` tag).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seed", self.seed)
            .set("mean_interval_secs", self.mean_interval_secs)
            .set("min_down_secs", self.min_down_secs)
            .set("max_down_secs", self.max_down_secs)
            .set("leave_fraction", self.leave_fraction)
            .set("horizon_secs", self.horizon_secs)
    }

    /// Parse from JSON, defaulting missing fields; validates ranges.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let d = ChurnConfig::default();
        let cfg = ChurnConfig {
            seed: v.get("seed").as_u64().unwrap_or(d.seed),
            mean_interval_secs: v
                .get("mean_interval_secs")
                .as_f64()
                .unwrap_or(d.mean_interval_secs),
            min_down_secs: v
                .get("min_down_secs")
                .as_f64()
                .unwrap_or(d.min_down_secs),
            max_down_secs: v
                .get("max_down_secs")
                .as_f64()
                .unwrap_or(d.max_down_secs),
            leave_fraction: v
                .get("leave_fraction")
                .as_f64()
                .unwrap_or(d.leave_fraction),
            horizon_secs: v
                .get("horizon_secs")
                .as_f64()
                .unwrap_or(d.horizon_secs),
        };
        if cfg.mean_interval_secs <= 0.0 || !cfg.mean_interval_secs.is_finite()
        {
            return Err("churn: 'mean_interval_secs' must be > 0".into());
        }
        if cfg.min_down_secs <= 0.0 || cfg.max_down_secs < cfg.min_down_secs {
            return Err(
                "churn: need 0 < min_down_secs <= max_down_secs".into()
            );
        }
        if !(0.0..=1.0).contains(&cfg.leave_fraction) {
            return Err("churn: 'leave_fraction' must be in [0, 1]".into());
        }
        if cfg.horizon_secs <= 0.0 || !cfg.horizon_secs.is_finite() {
            return Err("churn: 'horizon_secs' must be > 0".into());
        }
        Ok(cfg)
    }
}

/// Generate a deterministic churn timeline for `cluster` (see
/// [`ChurnConfig`]). The result always resolves against `cluster`.
pub fn generate_churn(cluster: &ClusterSpec, cfg: &ChurnConfig)
                      -> EventTimeline {
    let mut rng = Rng::new(cfg.seed ^ 0xC1_0D_5E_ED);
    let mut live: Vec<usize> = cluster.nodes.iter().map(|n| n.id).collect();
    // (rejoin time, node id) for in-flight maintenance windows.
    let mut pending: Vec<(f64, usize)> = Vec::new();
    let mut timeline = EventTimeline {
        name: format!("churn-s{}", cfg.seed),
        events: Vec::new(),
    };
    let mut t = 0.0;
    loop {
        t += rng.exponential(1.0 / cfg.mean_interval_secs);
        if !(t < cfg.horizon_secs) {
            break;
        }
        // Nodes whose maintenance finished by now are live again.
        pending.sort_by(|a, b| a.0.total_cmp(&b.0));
        while !pending.is_empty() && pending[0].0 <= t {
            let (_, id) = pending.remove(0);
            live.push(id);
        }
        if live.len() <= 1 {
            continue; // never drain the last live node
        }
        let idx = rng.below(live.len() as u64) as usize;
        let node = live.swap_remove(idx);
        if rng.f64() < cfg.leave_fraction {
            timeline.push(t, EventKind::Leave { node });
        } else {
            let duration =
                rng.range_f(cfg.min_down_secs, cfg.max_down_secs);
            timeline.push(t, EventKind::Maintenance { node, duration });
            pending.push((t + duration, node));
        }
    }
    timeline
}

// ------------------------------------------------------- event-aware view

/// Outcome of [`ClusterTimeline::advance_to`].
#[derive(Clone, Debug, Default)]
pub struct AdvanceOutcome {
    /// Nodes that drained or shrank — jobs placed there must be preempted.
    /// Joins and capacity *increases* never appear here.
    pub affected: BTreeSet<usize>,
    /// Whether total capacity changed (availability accounting boundary).
    pub capacity_changed: bool,
}

/// The engines' event-aware cluster view: the *current* [`ClusterSpec`]
/// plus the resolved events not yet applied. Schedulers are handed
/// [`ClusterTimeline::cluster`] each round, so they always see the live
/// cluster rather than the simulation's starting spec.
#[derive(Clone, Debug)]
pub struct ClusterTimeline {
    current: ClusterSpec,
    events: Vec<ResolvedEvent>,
    next: usize,
    applied: u64,
}

impl ClusterTimeline {
    /// Build the view; fails if the timeline does not resolve against
    /// `initial` (unknown node ids, colliding joins, bad durations).
    pub fn new(initial: &ClusterSpec, timeline: &EventTimeline)
               -> Result<Self, String> {
        Ok(ClusterTimeline {
            current: initial.clone(),
            events: timeline.resolve(initial)?,
            next: 0,
            applied: 0,
        })
    }

    /// The cluster as of the last `advance_to` call.
    pub fn cluster(&self) -> &ClusterSpec {
        &self.current
    }

    /// Events applied so far.
    pub fn events_applied(&self) -> u64 {
        self.applied
    }

    /// Apply every event with `at <= now` (round-boundary semantics) and
    /// report which nodes lost capacity.
    pub fn advance_to(&mut self, now: f64) -> AdvanceOutcome {
        let mut out = AdvanceOutcome::default();
        while self.next < self.events.len()
            && self.events[self.next].at <= now
        {
            let ev = self.events[self.next].clone();
            match ev.kind {
                ResolvedKind::Join(node) => {
                    self.current.add_node(node);
                    out.capacity_changed = true;
                }
                ResolvedKind::Leave { node } => {
                    if self.current.remove_node(node).is_some() {
                        out.affected.insert(node);
                        out.capacity_changed = true;
                    }
                }
                ResolvedKind::SetCapacity { node, gpu, count } => {
                    if let Some(old) =
                        self.current.set_capacity(node, gpu, count)
                    {
                        if count < old {
                            out.affected.insert(node);
                        }
                        if count != old {
                            out.capacity_changed = true;
                        }
                    }
                }
            }
            self.applied += 1;
            self.next += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::PcieGen;

    fn duo() -> ClusterSpec {
        ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 2)], PcieGen::Gen3),
                Node::new(1, "p", &[(GpuType::P100, 2)], PcieGen::Gen3),
            ],
        )
    }

    #[test]
    fn empty_timeline_resolves_to_nothing() {
        let t = EventTimeline::empty();
        assert!(t.is_empty());
        assert!(t.resolve(&duo()).unwrap().is_empty());
    }

    #[test]
    fn json_roundtrip_covers_all_kinds() {
        let mut t = EventTimeline {
            name: "mix".into(),
            events: Vec::new(),
        };
        t.push(
            100.0,
            EventKind::Join(Node::new(5, "new", &[(GpuType::T4, 1)],
                                      PcieGen::Gen4)),
        );
        t.push(200.0, EventKind::Leave { node: 0 });
        t.push(
            300.0,
            EventKind::Maintenance { node: 1, duration: 60.0 },
        );
        t.push(
            400.0,
            EventKind::SetCapacity {
                node: 5,
                gpu: GpuType::T4,
                count: 2,
            },
        );
        let back = EventTimeline::parse(&t.to_json().pretty()).unwrap();
        assert_eq!(back.name, "mix");
        assert_eq!(back.events.len(), 4);
        assert!(matches!(back.events[0].kind, EventKind::Join(ref n)
                         if n.id == 5 && n.pcie == PcieGen::Gen4));
        assert!(matches!(back.events[2].kind,
                         EventKind::Maintenance { node: 1, duration }
                         if duration == 60.0));
        // Resolves against the duo cluster (join 5, leave 0, maint 1, …).
        assert!(back.resolve(&duo()).is_ok());
    }

    #[test]
    fn parse_rejects_malformed_events() {
        assert!(EventTimeline::parse("{}").is_err());
        assert!(EventTimeline::parse(
            r#"{"events": [{"at": 1, "kind": "explode"}]}"#
        )
        .is_err());
        assert!(EventTimeline::parse(
            r#"{"events": [{"kind": "leave", "node": 0}]}"#
        )
        .is_err());
        // Join without an explicit node id.
        assert!(EventTimeline::parse(
            r#"{"events": [{"at": 1, "kind": "join",
                            "node": {"gpus": {"T4": 1}}}]}"#
        )
        .is_err());
    }

    #[test]
    fn maintenance_expands_to_leave_then_join_in_time_order() {
        let mut t = EventTimeline::empty();
        t.push(100.0, EventKind::Maintenance { node: 0, duration: 50.0 });
        t.push(500.0, EventKind::Leave { node: 1 });
        let r = t.resolve(&duo()).unwrap();
        assert_eq!(r.len(), 3);
        assert!(matches!(r[0].kind, ResolvedKind::Leave { node: 0 }));
        assert_eq!(r[0].at, 100.0);
        assert!(matches!(r[1].kind, ResolvedKind::Join(ref n) if n.id == 0));
        assert_eq!(r[1].at, 150.0);
        assert!(matches!(r[2].kind, ResolvedKind::Leave { node: 1 }));
        // Non-decreasing times.
        assert!(r.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn resolve_rejects_inconsistent_references() {
        // Unknown node.
        let mut t = EventTimeline::empty();
        t.push(10.0, EventKind::Leave { node: 9 });
        assert!(t.resolve(&duo()).is_err());
        // Double leave.
        let mut t = EventTimeline::empty();
        t.push(10.0, EventKind::Leave { node: 0 });
        t.push(20.0, EventKind::Leave { node: 0 });
        assert!(t.resolve(&duo()).is_err());
        // Join colliding with a live id.
        let mut t = EventTimeline::empty();
        t.push(
            10.0,
            EventKind::Join(Node::new(1, "dup", &[(GpuType::T4, 1)],
                                      PcieGen::Gen3)),
        );
        assert!(t.resolve(&duo()).is_err());
        // Negative time / non-positive duration.
        let mut t = EventTimeline::empty();
        t.push(-1.0, EventKind::Leave { node: 0 });
        assert!(t.resolve(&duo()).is_err());
        let mut t = EventTimeline::empty();
        t.push(1.0, EventKind::Maintenance { node: 0, duration: 0.0 });
        assert!(t.resolve(&duo()).is_err());
    }

    #[test]
    fn capacity_changes_carry_into_maintenance_rejoin() {
        let mut t = EventTimeline::empty();
        t.push(
            10.0,
            EventKind::SetCapacity {
                node: 0,
                gpu: GpuType::V100,
                count: 1,
            },
        );
        t.push(20.0, EventKind::Maintenance { node: 0, duration: 30.0 });
        let r = t.resolve(&duo()).unwrap();
        // set_capacity, leave, rejoin — the rejoin spec has the new count.
        let ResolvedKind::Join(ref n) = r[2].kind else {
            panic!("expected rejoin, got {:?}", r[2]);
        };
        assert_eq!(n.capacity(GpuType::V100), 1);
    }

    #[test]
    fn churn_generator_is_deterministic_and_resolvable() {
        let cluster = ClusterSpec::sim60();
        let cfg = ChurnConfig {
            seed: 11,
            mean_interval_secs: 1800.0,
            horizon_secs: 12.0 * 3600.0,
            ..Default::default()
        };
        let a = generate_churn(&cluster, &cfg);
        let b = generate_churn(&cluster, &cfg);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(!a.is_empty(), "12h at 30min mean interval yields events");
        assert!(a.resolve(&cluster).is_ok());
        let c = generate_churn(
            &cluster,
            &ChurnConfig { seed: 12, ..cfg },
        );
        assert_ne!(a.to_json().to_string(), c.to_json().to_string());
        // All events inside the horizon.
        assert!(a.events.iter().all(|e| e.at < cfg.horizon_secs));
    }

    #[test]
    fn churn_never_drains_the_last_node() {
        let single = ClusterSpec::new(
            "one",
            vec![Node::new(0, "n", &[(GpuType::V100, 1)], PcieGen::Gen3)],
        );
        let t = generate_churn(
            &single,
            &ChurnConfig {
                seed: 3,
                mean_interval_secs: 60.0,
                horizon_secs: 3600.0,
                leave_fraction: 1.0,
                ..Default::default()
            },
        );
        assert!(t.is_empty(), "a 1-node cluster is never drained");
    }

    #[test]
    fn cluster_timeline_applies_events_and_reports_affected_nodes() {
        let mut t = EventTimeline::empty();
        t.push(100.0, EventKind::Leave { node: 0 });
        t.push(
            200.0,
            EventKind::Join(Node::new(7, "new", &[(GpuType::T4, 4)],
                                      PcieGen::Gen3)),
        );
        t.push(
            300.0,
            EventKind::SetCapacity {
                node: 1,
                gpu: GpuType::P100,
                count: 1,
            },
        );
        let mut view = ClusterTimeline::new(&duo(), &t).unwrap();
        assert_eq!(view.cluster().total_gpus(), 4);

        let o = view.advance_to(50.0);
        assert!(o.affected.is_empty() && !o.capacity_changed);

        let o = view.advance_to(100.0);
        assert!(o.affected.contains(&0));
        assert!(o.capacity_changed);
        assert_eq!(view.cluster().total_gpus(), 2);

        // Join grows capacity but never preempts.
        let o = view.advance_to(200.0);
        assert!(o.affected.is_empty());
        assert!(o.capacity_changed);
        assert_eq!(view.cluster().total_gpus(), 6);

        // Capacity shrink marks the node affected.
        let o = view.advance_to(1e9);
        assert!(o.affected.contains(&1));
        assert_eq!(view.cluster().total_gpus(), 5);
        assert_eq!(view.events_applied(), 3);
    }
}
