//! Cluster nodes (machines/servers): per-type GPU capacities `c_h^r`.

use crate::cluster::gpu::{GpuType, PcieGen};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Largest node id accepted from untrusted input (cluster files, `join`
/// cluster events). The per-round allocation state is dense in the
/// largest live id, so an absurd id would cost memory proportional to it
/// every scheduling round — reject it at parse time instead.
pub const MAX_NODE_ID: usize = 65_535;

/// One machine `h` with capacity `c_h^r` for each GPU type `r`.
#[derive(Clone, Debug)]
pub struct Node {
    /// Stable node id `h`; ids need not be contiguous (nodes can leave).
    pub id: usize,
    /// Human-readable machine name (e.g. `"p3.2xlarge"`).
    pub name: String,
    /// `c_h^r`: capacity per GPU type (most real nodes carry one type).
    pub gpus: BTreeMap<GpuType, usize>,
    /// PCIe generation of the host (Eq. 10's `pcie_scaling` term).
    pub pcie: PcieGen,
}

impl Node {
    /// Build a node from `(type, count)` capacity pairs.
    pub fn new(id: usize, name: &str, gpus: &[(GpuType, usize)],
               pcie: PcieGen) -> Self {
        Node {
            id,
            name: name.to_string(),
            gpus: gpus.iter().copied().collect(),
            pcie,
        }
    }

    /// Capacity `c_h^r` for one GPU type (0 if the type is absent).
    pub fn capacity(&self, r: GpuType) -> usize {
        self.gpus.get(&r).copied().unwrap_or(0)
    }

    /// Total GPUs across all types on this node.
    pub fn total_gpus(&self) -> usize {
        self.gpus.values().sum()
    }

    /// The dominant (highest-capacity) GPU type on this node.
    pub fn primary_gpu(&self) -> Option<GpuType> {
        self.gpus
            .iter()
            .max_by_key(|(_, &c)| c)
            .map(|(&g, _)| g)
    }

    /// The node's whole-GPU gang as `(type, count)` pools with capacity
    /// `> 0`, in type order — exactly what a HadarE whole-node copy
    /// occupies (see [`crate::sched::hadare`]). Empty pools (capacity 0
    /// left behind by a `set_capacity` event) are skipped.
    pub fn gang(&self) -> impl Iterator<Item = (GpuType, usize)> + '_ {
        self.gpus
            .iter()
            .filter(|(_, &c)| c > 0)
            .map(|(&g, &c)| (g, c))
    }

    /// Emit as a JSON object (the `nodes` entries of a cluster file and
    /// the `node` payload of a `join` cluster event share this format).
    pub fn to_json(&self) -> Json {
        let mut gpus = Json::obj();
        for (g, c) in &self.gpus {
            gpus.insert(g.name(), *c);
        }
        Json::obj()
            .set("id", self.id)
            .set("name", self.name.as_str())
            .set("gpus", gpus)
            .set(
                "pcie",
                match self.pcie {
                    PcieGen::Gen3 => "gen3",
                    PcieGen::Gen4 => "gen4",
                },
            )
    }

    /// Parse a node object; `fallback_id`/`fallback name` cover cluster
    /// files that omit them (event files must spell the id out — see
    /// [`crate::cluster::events`]).
    pub fn from_json(v: &Json, fallback_id: usize) -> Result<Self, String> {
        let gpus_obj = v
            .get("gpus")
            .as_obj()
            .ok_or("node: 'gpus' must be an object")?;
        let mut gpus = Vec::new();
        for (gname, count) in gpus_obj {
            let g = GpuType::from_name(gname)
                .ok_or_else(|| format!("unknown gpu type '{gname}'"))?;
            gpus.push((g, count.as_usize().ok_or("gpu count must be int")?));
        }
        let pcie = match v.get("pcie").as_str() {
            Some("gen4") => PcieGen::Gen4,
            _ => PcieGen::Gen3,
        };
        let id = v.get("id").as_usize().unwrap_or(fallback_id);
        if id > MAX_NODE_ID {
            return Err(format!(
                "node id {id} exceeds the maximum {MAX_NODE_ID}"
            ));
        }
        Ok(Node::new(
            id,
            v.get("name").as_str().unwrap_or(&format!("node{id}")),
            &gpus,
            pcie,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities() {
        let n = Node::new(0, "n0", &[(GpuType::V100, 4), (GpuType::K80, 2)],
                          PcieGen::Gen3);
        assert_eq!(n.capacity(GpuType::V100), 4);
        assert_eq!(n.capacity(GpuType::T4), 0);
        assert_eq!(n.total_gpus(), 6);
        assert_eq!(n.primary_gpu(), Some(GpuType::V100));
        let gang: Vec<(GpuType, usize)> = n.gang().collect();
        assert_eq!(gang, vec![(GpuType::V100, 4), (GpuType::K80, 2)]);
    }

    #[test]
    fn gang_skips_zeroed_pools() {
        let mut n = Node::new(0, "n0", &[(GpuType::V100, 4)], PcieGen::Gen3);
        n.gpus.insert(GpuType::K80, 0); // set_capacity leftovers
        let gang: Vec<(GpuType, usize)> = n.gang().collect();
        assert_eq!(gang, vec![(GpuType::V100, 4)]);
    }

    #[test]
    fn json_roundtrip() {
        let n = Node::new(3, "dell", &[(GpuType::Rtx3090, 1)], PcieGen::Gen4);
        let back = Node::from_json(&n.to_json(), 0).unwrap();
        assert_eq!(back.id, 3);
        assert_eq!(back.name, "dell");
        assert_eq!(back.capacity(GpuType::Rtx3090), 1);
        assert_eq!(back.pcie, PcieGen::Gen4);
    }

    #[test]
    fn from_json_applies_fallbacks_and_rejects_bad_types() {
        let v = crate::util::json::parse(r#"{"gpus": {"T4": 2}}"#).unwrap();
        let n = Node::from_json(&v, 7).unwrap();
        assert_eq!(n.id, 7);
        assert_eq!(n.name, "node7");
        let bad =
            crate::util::json::parse(r#"{"gpus": {"NotAGpu": 1}}"#).unwrap();
        assert!(Node::from_json(&bad, 0).is_err());
    }

    #[test]
    fn from_json_rejects_absurd_node_ids() {
        // The allocation state is dense in the largest id; a huge id from
        // a cluster file or join event must fail at parse time, not OOM
        // the simulator.
        let v = crate::util::json::parse(
            r#"{"id": 1000000000, "gpus": {"T4": 1}}"#,
        )
        .unwrap();
        let err = Node::from_json(&v, 0).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
        let ok = crate::util::json::parse(
            &format!(r#"{{"id": {MAX_NODE_ID}, "gpus": {{"T4": 1}}}}"#),
        )
        .unwrap();
        assert!(Node::from_json(&ok, 0).is_ok());
    }
}
