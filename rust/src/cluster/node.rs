//! Cluster nodes (machines/servers): per-type GPU capacities `c_h^r`.

use crate::cluster::gpu::{GpuType, PcieGen};
use std::collections::BTreeMap;

/// One machine `h` with capacity `c_h^r` for each GPU type `r`.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    /// `c_h^r`: capacity per GPU type (most real nodes carry one type).
    pub gpus: BTreeMap<GpuType, usize>,
    pub pcie: PcieGen,
}

impl Node {
    pub fn new(id: usize, name: &str, gpus: &[(GpuType, usize)],
               pcie: PcieGen) -> Self {
        Node {
            id,
            name: name.to_string(),
            gpus: gpus.iter().copied().collect(),
            pcie,
        }
    }

    pub fn capacity(&self, r: GpuType) -> usize {
        self.gpus.get(&r).copied().unwrap_or(0)
    }

    pub fn total_gpus(&self) -> usize {
        self.gpus.values().sum()
    }

    /// The dominant (highest-capacity) GPU type on this node.
    pub fn primary_gpu(&self) -> Option<GpuType> {
        self.gpus
            .iter()
            .max_by_key(|(_, &c)| c)
            .map(|(&g, _)| g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacities() {
        let n = Node::new(0, "n0", &[(GpuType::V100, 4), (GpuType::K80, 2)],
                          PcieGen::Gen3);
        assert_eq!(n.capacity(GpuType::V100), 4);
        assert_eq!(n.capacity(GpuType::T4), 0);
        assert_eq!(n.total_gpus(), 6);
        assert_eq!(n.primary_gpu(), Some(GpuType::V100));
    }
}
