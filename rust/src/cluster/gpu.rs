//! GPU type catalogue with the attributes the paper's throughput model
//! (Eq. 10) consumes: PMI (Performance-Memory Index), VRAM, and the PCIe
//! generation of the host the card typically sits in.
//!
//! The catalogue covers both evaluation settings of the paper: the
//! simulated 60-GPU cluster (V100/P100/K80, §IV) and the two physical
//! clusters (§VI): AWS (V100/K80/T4) and the lab testbed (Titan RTX, T4,
//! T400, RTX 3090, RTX A2000).

/// A GPU model. `Ord` derives a stable type index used across matrices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GpuType {
    /// NVIDIA V100 (the simulated cluster's fast tier).
    V100,
    /// NVIDIA P100 (mid tier).
    P100,
    /// NVIDIA K80 (slow tier).
    K80,
    /// NVIDIA T4 (AWS g4dn / lab testbed).
    T4,
    /// NVIDIA Titan RTX (lab testbed).
    TitanRtx,
    /// NVIDIA T400 (lab testbed's slowest card).
    T400,
    /// NVIDIA RTX 3090 (lab testbed's fastest card).
    Rtx3090,
    /// NVIDIA RTX A2000 (lab testbed).
    RtxA2000,
}

impl GpuType {
    /// Every catalogued type, in stable index order.
    pub const ALL: [GpuType; 8] = [
        GpuType::V100,
        GpuType::P100,
        GpuType::K80,
        GpuType::T4,
        GpuType::TitanRtx,
        GpuType::T400,
        GpuType::Rtx3090,
        GpuType::RtxA2000,
    ];

    /// Canonical display/JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            GpuType::V100 => "V100",
            GpuType::P100 => "P100",
            GpuType::K80 => "K80",
            GpuType::T4 => "T4",
            GpuType::TitanRtx => "TitanRTX",
            GpuType::T400 => "T400",
            GpuType::Rtx3090 => "RTX3090",
            GpuType::RtxA2000 => "RTXA2000",
        }
    }

    /// Case-insensitive lookup by [`GpuType::name`].
    pub fn from_name(s: &str) -> Option<GpuType> {
        GpuType::ALL.iter().copied().find(|g| {
            g.name().eq_ignore_ascii_case(s)
        })
    }

    /// Peak tensor throughput in TFLOPS (fp16/tensor-core where present,
    /// else fp32) — public spec-sheet numbers.
    pub fn tflops(&self) -> f64 {
        match self {
            GpuType::V100 => 125.0,   // tensor cores
            GpuType::P100 => 21.2,    // fp16
            GpuType::K80 => 8.7,      // fp32 (per board)
            GpuType::T4 => 65.0,      // tensor cores
            GpuType::TitanRtx => 130.5,
            GpuType::T400 => 1.1,
            GpuType::Rtx3090 => 142.0,
            GpuType::RtxA2000 => 63.9,
        }
    }

    /// On-board VRAM in GiB.
    pub fn vram_gib(&self) -> f64 {
        match self {
            GpuType::V100 => 16.0,
            GpuType::P100 => 16.0,
            GpuType::K80 => 12.0,
            GpuType::T4 => 16.0,
            GpuType::TitanRtx => 24.0,
            GpuType::T400 => 4.0,
            GpuType::Rtx3090 => 24.0,
            GpuType::RtxA2000 => 6.0,
        }
    }

    /// Performance-Memory Index from the paper's Eq. (10) rationale:
    /// parallel tensor throughput weighted by sqrt(VRAM).
    pub fn pmi(&self) -> f64 {
        self.tflops() * self.vram_gib().sqrt()
    }
}

/// PCIe generation of a host; Eq. (10)'s `pcie_scaling` term.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PcieGen {
    /// PCIe 3.0 (x16 ≈ 16 GB/s).
    Gen3,
    /// PCIe 4.0 (double Gen3 bandwidth).
    Gen4,
}

impl PcieGen {
    /// Relative host<->device bandwidth scale (Gen3 x16 ≈ 16 GB/s = 1.0).
    pub fn scaling(&self) -> f64 {
        match self {
            PcieGen::Gen3 => 1.0,
            PcieGen::Gen4 => 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for g in GpuType::ALL {
            assert_eq!(GpuType::from_name(g.name()), Some(g));
        }
        assert_eq!(GpuType::from_name("v100"), Some(GpuType::V100));
        assert_eq!(GpuType::from_name("nope"), None);
    }

    #[test]
    fn pmi_ordering_matches_generation_gaps() {
        // The paper's motivating observation: V100 >> K80.
        assert!(GpuType::V100.pmi() / GpuType::K80.pmi() > 5.0);
        // P100 sits between them.
        assert!(GpuType::P100.pmi() > GpuType::K80.pmi());
        assert!(GpuType::P100.pmi() < GpuType::V100.pmi());
        // Testbed extremes: 3090 fastest, T400 slowest.
        assert!(GpuType::Rtx3090.pmi() > GpuType::RtxA2000.pmi());
        assert!(GpuType::T400.pmi() < GpuType::RtxA2000.pmi());
    }

    #[test]
    fn pcie_scaling() {
        assert!(PcieGen::Gen4.scaling() > PcieGen::Gen3.scaling());
    }
}
