//! The modelled cluster: GPU catalogue, nodes, cluster specs, and the
//! per-round allocation state shared by all schedulers.

pub mod gpu;
pub mod node;
pub mod spec;
pub mod state;

pub use gpu::{GpuType, PcieGen};
pub use node::Node;
pub use spec::ClusterSpec;
pub use state::{Assignment, ClusterState};
