//! The modelled cluster: GPU catalogue, nodes, cluster specs, the
//! per-round allocation state shared by all schedulers, and the event
//! timeline that makes clusters dynamic (joins, drains, capacity changes).

pub mod events;
pub mod gpu;
pub mod node;
pub mod spec;
pub mod state;

pub use events::{
    generate_churn, ChurnConfig, ClusterEvent, ClusterTimeline,
    EventKind, EventTimeline,
};
pub use gpu::{GpuType, PcieGen};
pub use node::{Node, MAX_NODE_ID};
pub use spec::ClusterSpec;
pub use state::{Assignment, ClusterState};
