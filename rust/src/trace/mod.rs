//! Traces and workloads: the Philly-shaped synthetic trace generator
//! (+ CSV parser for real traces) and the paper's workload mixes.

pub mod philly;
pub mod workload;

pub use philly::{generate, parse_csv, TraceConfig, TraceJob};
