//! Workload materialisation: trace records -> schedulable `Job`s, and the
//! paper's physical-cluster workload mixes (M-1 … M-12, §VI-B).

use crate::cluster::gpu::{GpuType, PcieGen};
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::Job;
use crate::jobs::model::{DlModel, SizeClass};
use crate::jobs::throughput;
use crate::trace::philly::TraceJob;
use crate::util::rng::Rng;

/// Table II assignment: trace size class -> candidate models (paper §IV-A
/// samples the model matching the job's GPU-time category).
pub fn models_for_class(class: SizeClass) -> &'static [DlModel] {
    match class {
        SizeClass::S => &[DlModel::ResNet18],
        SizeClass::M => &[DlModel::CycleGan],
        SizeClass::L => &[DlModel::Lstm, DlModel::Transformer],
        SizeClass::XL => &[DlModel::ResNet50],
    }
}

/// Iterations per epoch `N_j` for a model (dataset-size proportional —
/// larger datasets mean more chunks per pass).
pub fn iters_per_epoch(model: DlModel) -> u64 {
    (100.0 * model.size_class().dataset_scale()) as u64
}

/// (GPU type, PCIe) pairs present in a cluster, for throughput rows.
pub fn cluster_gpu_pcie(cluster: &ClusterSpec) -> Vec<(GpuType, PcieGen)> {
    let mut pairs: Vec<(GpuType, PcieGen)> = Vec::new();
    for node in &cluster.nodes {
        for (&g, &c) in &node.gpus {
            if c > 0 && !pairs.iter().any(|&(pg, _)| pg == g) {
                pairs.push((g, node.pcie));
            }
        }
    }
    pairs.sort_by_key(|&(g, _)| g);
    pairs
}

/// Materialise trace records into jobs on a given cluster:
/// * model sampled uniformly from the class's Table II candidates;
/// * `E_j * N_j` sized so the job's demand equals its trace GPU-hours at
///   the *geometric-mean* throughput of the simulated trio — the trace's
///   "GPU-hours" are type-agnostic, so anchoring at the mean keeps both
///   tails bounded (a V100 anchor would make any K80 placement a 10x
///   catastrophe and blow YARN-CS's tail far past the paper's 1.67x);
/// * throughput row = anchors + Eq. (10) estimates over the cluster types.
pub fn materialize(trace: &[TraceJob], cluster: &ClusterSpec, seed: u64)
                   -> Vec<Job> {
    let mut rng = Rng::new(seed ^ 0x5EED);
    let pairs = cluster_gpu_pcie(cluster);
    let max_gang = cluster
        .nodes
        .iter()
        .map(|n| n.total_gpus())
        .max()
        .unwrap_or(1)
        .max(1);
    trace
        .iter()
        .map(|t| {
            let model = *rng.choice(models_for_class(t.class));
            let anchors = [
                model.anchor_throughput(GpuType::V100).expect("anchor"),
                model.anchor_throughput(GpuType::P100).expect("anchor"),
                model.anchor_throughput(GpuType::K80).expect("anchor"),
            ];
            let x_ref = anchors.iter().product::<f64>().powf(1.0 / 3.0);
            let total_iters = t.gpu_hours * 3600.0 * x_ref;
            let n = iters_per_epoch(model);
            let epochs = ((total_iters / n as f64).ceil() as u64).max(1);
            let mut job = Job::new(
                t.id,
                model,
                t.submit,
                t.gpus.min(max_gang),
                epochs,
                n,
            );
            job.throughput = throughput::throughput_row(model, &pairs);
            job
        })
        .collect()
}

/// The paper's §VI-B workload mixes. `M-3 = <LT, 2xMM>` etc.
pub fn mix(name: &str) -> Option<Vec<DlModel>> {
    use DlModel::*;
    let models = match name {
        "M-1" => vec![MiMa],
        "M-3" => vec![Transformer, MiMa, MiMa],
        "M-4" => vec![ResNet18, Lstm, Transformer, MiMa],
        "M-5" => vec![ResNet18, Lstm, Transformer, Recoder, MiMa],
        "M-8" => vec![ResNet18, Lstm, Transformer, Recoder,
                      MiMa, MiMa, MiMa, MiMa],
        "M-10" => vec![ResNet18, Lstm, Transformer, Recoder,
                       MiMa, MiMa, MiMa, MiMa, MiMa, MiMa],
        "M-12" => vec![ResNet18, Lstm, Transformer, Recoder,
                       MiMa, MiMa, MiMa, MiMa, MiMa, MiMa, MiMa, MiMa],
        _ => return None,
    };
    Some(models)
}

/// All seven mixes in paper order.
pub const MIX_NAMES: [&str; 7] =
    ["M-1", "M-3", "M-4", "M-5", "M-8", "M-10", "M-12"];

/// Build the physical-cluster jobs for one mix: single-GPU gangs (the
/// paper always uses one GPU per node in §VI), all arriving at t=0.
/// `epochs_scale` scales job lengths (1.0 ≈ paper-magnitude virtual time).
pub fn physical_jobs(mix_name: &str, cluster: &ClusterSpec,
                     epochs_scale: f64) -> Option<Vec<Job>> {
    let models = mix(mix_name)?;
    let pairs = cluster_gpu_pcie(cluster);
    Some(
        models
            .iter()
            .enumerate()
            .map(|(i, &model)| {
                // Base epochs per model sized so M-5 lands near the paper's
                // ~1h TTD scale in virtual seconds.
                let base_epochs = match model.size_class() {
                    SizeClass::S => 30,
                    SizeClass::M => 20,
                    SizeClass::L => 15,
                    SizeClass::XL => 10,
                };
                let epochs =
                    ((base_epochs as f64 * epochs_scale).ceil() as u64).max(1);
                let mut job = Job::new(i as u64, model, 0.0, 1, epochs,
                                       iters_per_epoch(model));
                job.throughput = throughput::throughput_row(model, &pairs);
                job
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::philly::{generate, TraceConfig};

    #[test]
    fn materialize_sizes_jobs_by_gpu_hours() {
        let cluster = ClusterSpec::sim60();
        let trace = generate(&TraceConfig {
            n_jobs: 100,
            ..Default::default()
        });
        let jobs = materialize(&trace, &cluster, 7);
        assert_eq!(jobs.len(), 100);
        for (t, j) in trace.iter().zip(&jobs) {
            let x_ref = [GpuType::V100, GpuType::P100, GpuType::K80]
                .iter()
                .map(|&g| j.model.anchor_throughput(g).unwrap())
                .product::<f64>()
                .powf(1.0 / 3.0);
            let expect = t.gpu_hours * 3600.0 * x_ref;
            let got = j.total_iters();
            // Epochs are ceiled to whole multiples of N_j.
            let slack = iters_per_epoch(j.model) as f64;
            assert!(got >= expect - 1e-9 && got <= expect + slack,
                    "iters {got} vs {expect}");
            assert!(models_for_class(t.class).contains(&j.model));
            // Throughput row covers all cluster types.
            assert_eq!(j.throughput.len(), 3);
        }
    }

    #[test]
    fn materialize_is_deterministic() {
        let cluster = ClusterSpec::sim60();
        let trace = generate(&TraceConfig::default());
        let a = materialize(&trace, &cluster, 1);
        let b = materialize(&trace, &cluster, 1);
        assert!(a.iter().zip(&b).all(|(x, y)| x.model == y.model
            && x.epochs == y.epochs));
    }

    #[test]
    fn mixes_match_paper_composition() {
        assert_eq!(mix("M-1").unwrap().len(), 1);
        assert_eq!(mix("M-3").unwrap().len(), 3);
        assert_eq!(mix("M-4").unwrap().len(), 4);
        assert_eq!(mix("M-5").unwrap().len(), 5);
        assert_eq!(mix("M-8").unwrap().len(), 8);
        assert_eq!(mix("M-10").unwrap().len(), 10);
        assert_eq!(mix("M-12").unwrap().len(), 12);
        assert!(mix("M-99").is_none());
        // M-12 = <IC, LM, LT, RS, 8xMM>
        let m12 = mix("M-12").unwrap();
        assert_eq!(m12.iter().filter(|&&m| m == DlModel::MiMa).count(), 8);
    }

    #[test]
    fn physical_jobs_cover_cluster_types() {
        let cluster = ClusterSpec::testbed5();
        let jobs = physical_jobs("M-5", &cluster, 1.0).unwrap();
        assert_eq!(jobs.len(), 5);
        for j in &jobs {
            assert_eq!(j.gpus_requested, 1);
            assert_eq!(j.throughput.len(), 5);
            assert!(j.throughput.values().all(|&x| x > 0.0));
        }
    }
}
