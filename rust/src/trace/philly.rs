//! Philly-like trace generation + CSV trace parsing.
//!
//! The paper samples 480 jobs from the busiest hours (3-10) of the
//! Microsoft Philly trace [Jeon et al., ATC'19]. That trace is not
//! available in this sandbox, so `TraceGenerator` synthesises a trace with
//! the published shape (DESIGN.md §Substitutions):
//!
//! * **GPU demand** is heavy-tailed and power-of-two biased: most jobs ask
//!   for 1 GPU; 2/4/8-GPU gangs taper geometrically (Philly Fig. 3).
//! * **Durations** are bucketed into the paper's §IV-A GPU-hour classes
//!   (S 0-1, M 1-10, L 10-50, XL 60-100 GPU-hours), sampled log-uniformly
//!   within the class, with class probabilities skewed small (heavy tail).
//! * **Arrivals** are Poisson within the configured window (the paper's
//!   trace-driven runs make all jobs available at t=0; both modes exist).
//!
//! `parse_csv` accepts real traces in a `job_id,submit_sec,gpus,duration_h`
//! format so a user with Philly access can drive the simulator unchanged.

use crate::jobs::model::SizeClass;
use crate::util::rng::Rng;

/// One trace record (before materialisation into a `Job`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceJob {
    /// Trace-local job id.
    pub id: u64,
    /// Submission time in seconds from trace start.
    pub submit: f64,
    /// Requested gang size.
    pub gpus: usize,
    /// Total demand in GPU-hours (drives E_j * N_j via throughput).
    pub gpu_hours: f64,
    /// GPU-hour size class (paper §IV-A buckets).
    pub class: SizeClass,
}

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Number of jobs to generate.
    pub n_jobs: usize,
    /// Generator seed.
    pub seed: u64,
    /// All jobs at t=0 (paper §IV-A) vs Poisson arrivals over the window.
    pub all_at_start: bool,
    /// Arrival window in seconds when `all_at_start` is false.
    pub window_secs: f64,
    /// Cap on the gang size (cluster-dependent).
    pub max_gpus: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_jobs: 480,
            seed: 42,
            all_at_start: true,
            window_secs: 7.0 * 3600.0, // busiest hours 3-10
            max_gpus: 8,
        }
    }
}

/// Philly-shaped class mix: small jobs dominate, XL is rare.
const CLASS_WEIGHTS: [(SizeClass, f64); 4] = [
    (SizeClass::S, 0.45),
    (SizeClass::M, 0.35),
    (SizeClass::L, 0.15),
    (SizeClass::XL, 0.05),
];

/// Power-of-two gang-size weights (1 GPU dominates).
const GPU_WEIGHTS: [(usize, f64); 4] = [(1, 0.70), (2, 0.15), (4, 0.10), (8, 0.05)];

/// Generate a Philly-shaped trace (deterministic in `cfg.seed`).
pub fn generate(cfg: &TraceConfig) -> Vec<TraceJob> {
    let mut rng = Rng::new(cfg.seed);
    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    let class_w: Vec<f64> = CLASS_WEIGHTS.iter().map(|&(_, w)| w).collect();
    let gpu_w: Vec<f64> = GPU_WEIGHTS.iter().map(|&(_, w)| w).collect();
    let mut t = 0.0;
    let rate = cfg.n_jobs as f64 / cfg.window_secs;
    for id in 0..cfg.n_jobs {
        let class = CLASS_WEIGHTS[rng.weighted(&class_w)].0;
        let mut gpus = GPU_WEIGHTS[rng.weighted(&gpu_w)].0;
        gpus = gpus.min(cfg.max_gpus).max(1);
        let (lo, hi) = class.gpu_hour_range();
        // Log-uniform within the class (avoid zero lower bound for S).
        let lo = lo.max(0.05);
        let gpu_hours = (rng.f64() * (hi.ln() - lo.ln()) + lo.ln()).exp();
        let submit = if cfg.all_at_start {
            0.0
        } else {
            t += rng.exponential(rate);
            t
        };
        jobs.push(TraceJob {
            id: id as u64,
            submit,
            gpus,
            gpu_hours,
            class,
        });
    }
    jobs
}

/// Parse `job_id,submit_sec,gpus,duration_gpu_hours` CSV (with optional
/// header). Lines starting with `#` are skipped.
///
/// At most **one** leading header row is tolerated: the first
/// non-comment line may be a four-column row of *labels* — every field
/// non-numeric, like `job_id,submit,gpus,hours`. Anything else that
/// fails to parse — a bad-id data row (even as the first line), a second
/// header, a three-field garbage line — is an error, not a silent drop
/// (a trace loader that eats malformed rows under-reports the workload
/// it claims to replay).
pub fn parse_csv(text: &str) -> Result<Vec<TraceJob>, String> {
    let mut out = Vec::new();
    let mut first_candidate = true;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        // Header = all four fields are labels. A corrupt first *data*
        // row ("xx,0.0,1,0.5") has numeric tail fields and must error
        // below, not vanish as a pseudo-header.
        if first_candidate
            && fields.len() == 4
            && fields.iter().all(|f| f.parse::<f64>().is_err())
        {
            first_candidate = false;
            continue; // the single permitted header row
        }
        first_candidate = false;
        if fields.len() != 4 {
            return Err(format!("line {}: expected 4 fields", lineno + 1));
        }
        let id: u64 = fields[0]
            .parse()
            .map_err(|_| format!("line {}: bad id", lineno + 1))?;
        let submit: f64 = fields[1]
            .parse()
            .map_err(|_| format!("line {}: bad submit", lineno + 1))?;
        let gpus: usize = fields[2]
            .parse()
            .map_err(|_| format!("line {}: bad gpus", lineno + 1))?;
        let gpu_hours: f64 = fields[3]
            .parse()
            .map_err(|_| format!("line {}: bad duration", lineno + 1))?;
        let class = SizeClass::ALL
            .iter()
            .copied()
            .find(|c| {
                let (lo, hi) = c.gpu_hour_range();
                gpu_hours >= lo && gpu_hours < hi
            })
            .unwrap_or(SizeClass::XL);
        out.push(TraceJob {
            id,
            submit,
            gpus,
            gpu_hours,
            class,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count_deterministically() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 480);
        assert_eq!(a, b);
    }

    #[test]
    fn class_mix_is_heavy_tailed() {
        let jobs = generate(&TraceConfig {
            n_jobs: 5000,
            ..Default::default()
        });
        let count = |c: SizeClass| jobs.iter().filter(|j| j.class == c).count();
        assert!(count(SizeClass::S) > count(SizeClass::L));
        assert!(count(SizeClass::M) > count(SizeClass::XL));
        assert!(count(SizeClass::XL) > 0);
    }

    #[test]
    fn gpu_hours_respect_class_ranges() {
        for j in generate(&TraceConfig {
            n_jobs: 1000,
            ..Default::default()
        }) {
            let (lo, hi) = j.class.gpu_hour_range();
            assert!(j.gpu_hours >= lo.max(0.05) * 0.999
                    && j.gpu_hours <= hi * 1.001,
                    "{:?} {}", j.class, j.gpu_hours);
        }
    }

    #[test]
    fn gang_sizes_power_of_two_and_bounded() {
        let jobs = generate(&TraceConfig {
            n_jobs: 2000,
            max_gpus: 4,
            ..Default::default()
        });
        assert!(jobs.iter().all(|j| [1, 2, 4].contains(&j.gpus)));
        let ones = jobs.iter().filter(|j| j.gpus == 1).count();
        assert!(ones > jobs.len() / 2);
    }

    #[test]
    fn poisson_arrivals_are_ordered_and_spread() {
        let jobs = generate(&TraceConfig {
            n_jobs: 200,
            all_at_start: false,
            ..Default::default()
        });
        assert!(jobs.windows(2).all(|w| w[0].submit <= w[1].submit));
        assert!(jobs.last().unwrap().submit > 0.0);
    }

    #[test]
    fn csv_roundtrip() {
        let csv = "\
# comment
job_id,submit,gpus,hours
0,0.0,1,0.5
1,10.0,4,25.0
2,20.0,8,80.0
";
        let jobs = parse_csv(csv).unwrap();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].class, SizeClass::S);
        assert_eq!(jobs[1].class, SizeClass::L);
        assert_eq!(jobs[2].class, SizeClass::XL);
        assert!(parse_csv("1,2,3").is_err());
        assert!(parse_csv("a,b,c,d\n1,x,1,1").is_err());
    }

    #[test]
    fn csv_skips_at_most_one_header_and_rejects_garbage() {
        // Regression: pre-data lines whose id failed to parse were *all*
        // skipped as "headers", silently dropping bad-id data rows and
        // short garbage lines. Exactly one four-field header row may be
        // skipped; everything else errors.
        //
        // A second header-looking line is an error, not a skip.
        let err = parse_csv("job_id,submit,gpus,hours\na,b,c,d\n1,0,1,1")
            .unwrap_err();
        assert!(err.contains("bad id"), "{err}");
        // A bad-id data row after the header is an error (it used to
        // vanish because no data row had been seen yet).
        let err = parse_csv("job_id,submit,gpus,hours\nxx,0.0,1,0.5")
            .unwrap_err();
        assert!(err.contains("bad id"), "{err}");
        // A bad-id row after data is an error too.
        let err = parse_csv("0,0.0,1,0.5\nxx,1.0,1,0.5").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        // A corrupt first *data* row in a headerless file is not a
        // header — its tail fields are numeric, so it errors instead of
        // vanishing.
        let err = parse_csv("xx,0.0,1,0.5\n1,1.0,1,0.5").unwrap_err();
        assert!(err.contains("bad id"), "{err}");
        // A three-field garbage first line is not a header — it used to
        // be dropped silently.
        let err = parse_csv("a,b,c\n0,0.0,1,0.5").unwrap_err();
        assert!(err.contains("expected 4 fields"), "{err}");
        // Comments and blank lines before the header are still fine, and
        // a header-only file parses to an empty trace.
        let jobs =
            parse_csv("# c\n\njob_id,submit,gpus,hours\n3,1.0,2,5.0")
                .unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].id, 3);
        assert!(parse_csv("job_id,submit,gpus,hours").unwrap().is_empty());
    }
}
