//! Telemetry export: per-round JSONL streams and a Prometheus-style
//! text snapshot.
//!
//! The engines emit one [`RoundTelemetry`] record per scheduling round
//! into a [`TelemetrySink`] (`hadar simulate --telemetry <file>`, or one
//! stream per scenario when `SweepSpec.telemetry` is set). Records are
//! deterministic modulo the wall-clock field: with `include_timing`
//! off, the same seed produces a byte-identical stream whether span
//! tracing is enabled or not (asserted by `rust/tests/obs_telemetry.rs`).
//!
//! [`prometheus`] renders a [`crate::obs::metrics::Registry`] snapshot
//! in the Prometheus text exposition format, for
//! `hadar simulate --metrics-dump` and the future `hadar serve` mode.

use crate::obs::metrics::{MetricValue, Registry};
use crate::sched::SolverStats;
use crate::util::json::Json;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

/// One scheduling round's telemetry record (one JSONL line).
///
/// Everything except `sched_wall_secs` is derived from the simulation
/// state, so it is deterministic for a fixed seed; `sched_wall_secs` is
/// wall clock and is dropped when the sink's `include_timing` is off.
/// The schema is documented in `docs/observability.md`.
#[derive(Clone, Debug)]
pub struct RoundTelemetry {
    /// Round number (0-based).
    pub round: u64,
    /// Virtual time at round start (seconds).
    pub now: f64,
    /// Scheduler that produced this round's plan.
    pub scheduler: String,
    /// Arrived, incomplete jobs at round start (queue depth).
    pub active_jobs: usize,
    /// Jobs holding an allocation this round.
    pub scheduled_jobs: usize,
    /// GPUs allocated this round.
    pub gpus_allocated: usize,
    /// Busy GPU-seconds this round (excludes restart overhead).
    pub busy_gpu_secs: f64,
    /// GPU-seconds allocated this round.
    pub alloc_gpu_secs: f64,
    /// GPU-seconds available this round (current cluster x slot).
    pub avail_gpu_secs: f64,
    /// Whether this round's plan differs from the previous round's.
    pub plan_changed: bool,
    /// Jobs force-preempted at this round's boundary.
    pub preemptions: u64,
    /// Cluster events applied at this round's boundary.
    pub events_applied: u64,
    /// Jobs that completed during this round.
    pub completed: usize,
    /// Solver-internal counters (cumulative), for schedulers that
    /// expose them ([`crate::sched::Scheduler::solver_stats`]).
    pub solver: Option<SolverStats>,
    /// Wall-clock seconds inside `Scheduler::schedule` this round —
    /// the one non-deterministic field.
    pub sched_wall_secs: f64,
}

impl RoundTelemetry {
    /// JSON form. `include_timing` gates the wall-clock field so
    /// canonical streams stay reproducible (same convention as
    /// `ScenarioRecord::to_json`).
    pub fn to_json(&self, include_timing: bool) -> Json {
        let mut j = Json::obj()
            .set("round", self.round)
            .set("now", self.now)
            .set("scheduler", self.scheduler.as_str())
            .set("active_jobs", self.active_jobs)
            .set("scheduled_jobs", self.scheduled_jobs)
            .set("gpus_allocated", self.gpus_allocated)
            .set("busy_gpu_secs", self.busy_gpu_secs)
            .set("alloc_gpu_secs", self.alloc_gpu_secs)
            .set("avail_gpu_secs", self.avail_gpu_secs)
            .set("plan_changed", self.plan_changed)
            .set("preemptions", self.preemptions)
            .set("events_applied", self.events_applied)
            .set("completed", self.completed);
        if let Some(s) = self.solver {
            j.insert(
                "solver",
                Json::obj()
                    .set("memo_hits", s.memo_hits)
                    .set("memo_misses", s.memo_misses)
                    .set("dp_rounds", s.dp_rounds)
                    .set("greedy_rounds", s.greedy_rounds)
                    .set("rounds_with_change", s.rounds_with_change)
                    .set("find_alloc_calls", s.find_alloc_calls)
                    .set("candidates_scored", s.candidates_scored)
                    .set("rescore_conflicts", s.rescore_conflicts),
            );
        }
        if include_timing {
            j.insert("sched_wall_secs", self.sched_wall_secs);
        }
        j
    }
}

enum Out {
    File(BufWriter<File>),
    Mem(Vec<u8>),
}

/// Line-oriented JSONL destination for [`RoundTelemetry`] records.
///
/// Writing telemetry is orthogonal to [`crate::obs::enabled`]: a sink
/// handed to an engine is always written, so streams can be compared
/// across tracing states.
pub struct TelemetrySink {
    out: Out,
    include_timing: bool,
    records: u64,
}

impl TelemetrySink {
    /// Stream records to `path` (created/truncated). File streams keep
    /// the wall-clock field by default when `include_timing` is true.
    pub fn to_file(path: &Path, include_timing: bool) -> io::Result<Self> {
        let f = File::create(path)?;
        Ok(TelemetrySink {
            out: Out::File(BufWriter::new(f)),
            include_timing,
            records: 0,
        })
    }

    /// Buffer records in memory (tests; read back via
    /// [`TelemetrySink::contents`]).
    pub fn in_memory(include_timing: bool) -> Self {
        TelemetrySink {
            out: Out::Mem(Vec::new()),
            include_timing,
            records: 0,
        }
    }

    /// Append one record as a single JSON line.
    pub fn emit(&mut self, t: &RoundTelemetry) -> io::Result<()> {
        let line = t.to_json(self.include_timing).to_string();
        self.records += 1;
        match &mut self.out {
            Out::File(w) => {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")
            }
            Out::Mem(buf) => {
                buf.extend_from_slice(line.as_bytes());
                buf.push(b'\n');
                Ok(())
            }
        }
    }

    /// Records emitted so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// The buffered stream, for in-memory sinks (`None` for files).
    pub fn contents(&self) -> Option<&str> {
        match &self.out {
            Out::Mem(buf) => std::str::from_utf8(buf).ok(),
            Out::File(_) => None,
        }
    }

    /// Flush and close the stream.
    pub fn finish(self) -> io::Result<()> {
        match self.out {
            Out::File(mut w) => w.flush(),
            Out::Mem(_) => Ok(()),
        }
    }
}

/// Render a registry snapshot in the Prometheus text exposition format
/// (`# TYPE` comments, `_bucket{le=...}`/`_sum`/`_count` histogram
/// series). Metric dots become underscores (`hadar.dp_memo_hits` →
/// `hadar_dp_memo_hits`). Deterministic: sorted by metric name.
pub fn prometheus(reg: &Registry) -> String {
    let mut out = String::new();
    for m in reg.snapshot() {
        let name: String = m
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        match m.value {
            MetricValue::Counter(v) => {
                out.push_str(&format!("# TYPE {name} counter\n"));
                out.push_str(&format!("{name} {v}\n"));
            }
            MetricValue::Gauge(v) => {
                out.push_str(&format!("# TYPE {name} gauge\n"));
                out.push_str(&format!("{name} {v}\n"));
            }
            MetricValue::Histogram {
                buckets,
                count,
                sum_secs,
            } => {
                out.push_str(&format!("# TYPE {name} histogram\n"));
                let mut cum = 0u64;
                for (le, n) in buckets {
                    cum += n;
                    if le.is_infinite() {
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"+Inf\"}} {cum}\n"
                        ));
                    } else {
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"{le}\"}} {cum}\n"
                        ));
                    }
                }
                out.push_str(&format!("{name}_sum {sum_secs}\n"));
                out.push_str(&format!("{name}_count {count}\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: u64) -> RoundTelemetry {
        RoundTelemetry {
            round,
            now: round as f64 * 360.0,
            scheduler: "hadar".to_string(),
            active_jobs: 5,
            scheduled_jobs: 4,
            gpus_allocated: 6,
            busy_gpu_secs: 2100.0,
            alloc_gpu_secs: 2160.0,
            avail_gpu_secs: 2880.0,
            plan_changed: round == 0,
            preemptions: 0,
            events_applied: 0,
            completed: 1,
            solver: Some(SolverStats {
                memo_hits: 10,
                memo_misses: 20,
                dp_rounds: 1,
                greedy_rounds: 0,
                rounds_with_change: 1,
                find_alloc_calls: 30,
                candidates_scored: 90,
                rescore_conflicts: 2,
            }),
            sched_wall_secs: 0.001,
        }
    }

    #[test]
    fn sink_emits_one_line_per_record_and_gates_timing() {
        let mut sink = TelemetrySink::in_memory(false);
        sink.emit(&sample(0)).unwrap();
        sink.emit(&sample(1)).unwrap();
        assert_eq!(sink.records(), 2);
        let text = sink.contents().unwrap().to_string();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = crate::util::json::parse(line).unwrap();
            assert!(j.get("round").as_u64().is_some());
            assert_eq!(j.get("scheduler").as_str(), Some("hadar"));
            assert_eq!(j.get("solver").get("memo_hits").as_u64(), Some(10));
            assert!(j.get("sched_wall_secs").as_f64().is_none(),
                    "timing excluded from canonical streams");
        }

        let mut timed = TelemetrySink::in_memory(true);
        timed.emit(&sample(0)).unwrap();
        let j = crate::util::json::parse(timed.contents().unwrap().trim())
            .unwrap();
        assert!(j.get("sched_wall_secs").as_f64().is_some());
    }

    #[test]
    fn prometheus_renders_all_kinds() {
        let reg = Registry::new();
        reg.counter("t.hits").add(4);
        reg.gauge("t.depth").set(2.0);
        let h = reg.histogram("t.lat");
        h.record(0.5);
        h.record(200.0);
        let text = prometheus(&reg);
        assert!(text.contains("# TYPE t_hits counter\nt_hits 4\n"));
        assert!(text.contains("# TYPE t_depth gauge\nt_depth 2\n"));
        assert!(text.contains("# TYPE t_lat histogram\n"));
        assert!(text.contains("t_lat_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("t_lat_count 2\n"));
        // Cumulative buckets: the le="1" bucket already holds the 0.5 s
        // sample.
        assert!(text.contains("t_lat_bucket{le=\"1\"} 1\n"), "{text}");
    }
}
