//! Metrics registry: counters, gauges, and histograms behind cheap
//! atomic handles.
//!
//! A handle ([`Counter`], [`Gauge`], [`Histogram`]) is an `Arc` around
//! atomics: obtaining one takes the registry lock once, after which
//! every update is a single relaxed atomic operation — cheap enough for
//! solver inner loops. Hot-path call sites additionally gate on
//! [`crate::obs::enabled`] so the disabled path is one atomic load and a
//! branch, in line with the subsystem's off-by-default contract.
//!
//! The well-known instruments fed by the solvers and engines live in
//! [`CoreMetrics`] (lazily registered on first use via [`core`]);
//! [`Registry::snapshot`] feeds the Prometheus-style text dump in
//! [`crate::obs::export::prometheus`]. The full inventory is documented
//! in `docs/observability.md`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Histogram bucket count: powers of two from 2^-20 s (~1 µs) to 2^6 s
/// (64 s), plus one overflow bucket.
pub const HIST_BUCKETS: usize = 28;

/// Monotonically increasing event count.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n` to the counter.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins floating-point level (queue depth, etc.). Stored as
/// `f64` bits in an atomic.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistInner {
    /// `buckets[i]` counts samples with `value <= 2^(i-20)` seconds
    /// (non-cumulative); the last bucket catches everything larger.
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    /// Sum of samples in nanoseconds (saturating enough for our use:
    /// 2^64 ns ≈ 584 years of scheduler wall time).
    sum_ns: AtomicU64,
}

/// Distribution of non-negative second-valued samples over
/// power-of-two buckets (per-round solver wall clock, etc.).
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Histogram {
    /// Record one sample, in seconds.
    #[inline]
    pub fn record(&self, secs: f64) {
        let idx = bucket_index(secs);
        self.0.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        let ns = (secs.max(0.0) * 1e9) as u64;
        self.0.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of all samples, in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.0.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Non-cumulative bucket counts as `(upper bound in seconds, count)`;
    /// the final entry's bound is `f64::INFINITY`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        (0..HIST_BUCKETS)
            .map(|i| {
                (bucket_bound(i), self.0.buckets[i].load(Ordering::Relaxed))
            })
            .collect()
    }
}

/// Upper bound (seconds) of bucket `i`.
fn bucket_bound(i: usize) -> f64 {
    if i + 1 >= HIST_BUCKETS {
        f64::INFINITY
    } else {
        (2.0f64).powi(i as i32 - 20)
    }
}

/// Smallest bucket whose upper bound holds `secs`.
fn bucket_index(secs: f64) -> usize {
    if !(secs > 0.0) {
        return 0;
    }
    let i = secs.log2().ceil() as i64 + 20;
    i.clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

enum Handle {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A point-in-time reading of one metric ([`Registry::snapshot`]).
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    /// Counter value.
    Counter(u64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram reading.
    Histogram {
        /// Non-cumulative `(upper bound secs, count)` buckets.
        buckets: Vec<(f64, u64)>,
        /// Total samples.
        count: u64,
        /// Sum of samples (seconds).
        sum_secs: f64,
    },
}

/// One named metric in a [`Registry::snapshot`].
#[derive(Clone, Debug, PartialEq)]
pub struct MetricSnapshot {
    /// Dotted metric name (e.g. `hadar.dp_memo_hits`).
    pub name: String,
    /// Its current reading.
    pub value: MetricValue,
}

/// Named metric store. Handles are get-or-create: asking twice for the
/// same name returns clones sharing the same atomics.
pub struct Registry {
    inner: Mutex<BTreeMap<String, Handle>>,
}

impl Registry {
    /// Empty registry.
    pub const fn new() -> Self {
        Registry {
            inner: Mutex::new(BTreeMap::new()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Handle>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Get or create the counter `name`. Panics if `name` is already
    /// registered as a different kind (a programming error).
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.lock();
        let h = m.entry(name.to_string()).or_insert_with(|| {
            Handle::Counter(Counter(Arc::new(AtomicU64::new(0))))
        });
        match h {
            Handle::Counter(c) => c.clone(),
            _ => panic!("metric '{name}' is not a counter"),
        }
    }

    /// Get or create the gauge `name`. Panics on kind mismatch.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.lock();
        let h = m.entry(name.to_string()).or_insert_with(|| {
            Handle::Gauge(Gauge(Arc::new(AtomicU64::new(0))))
        });
        match h {
            Handle::Gauge(g) => g.clone(),
            _ => panic!("metric '{name}' is not a gauge"),
        }
    }

    /// Get or create the histogram `name`. Panics on kind mismatch.
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.lock();
        let h = m.entry(name.to_string()).or_insert_with(|| {
            Handle::Histogram(Histogram(Arc::new(HistInner {
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                count: AtomicU64::new(0),
                sum_ns: AtomicU64::new(0),
            })))
        });
        match h {
            Handle::Histogram(hh) => hh.clone(),
            _ => panic!("metric '{name}' is not a histogram"),
        }
    }

    /// Read every metric, sorted by name (deterministic order).
    pub fn snapshot(&self) -> Vec<MetricSnapshot> {
        let m = self.lock();
        m.iter()
            .map(|(name, h)| MetricSnapshot {
                name: name.clone(),
                value: match h {
                    Handle::Counter(c) => MetricValue::Counter(c.get()),
                    Handle::Gauge(g) => MetricValue::Gauge(g.get()),
                    Handle::Histogram(hh) => MetricValue::Histogram {
                        buckets: hh.buckets(),
                        count: hh.count(),
                        sum_secs: hh.sum_secs(),
                    },
                },
            })
            .collect()
    }

    /// Zero every registered metric (handles stay valid).
    pub fn reset(&self) {
        let m = self.lock();
        for h in m.values() {
            match h {
                Handle::Counter(c) => c.0.store(0, Ordering::Relaxed),
                Handle::Gauge(g) => g.0.store(0, Ordering::Relaxed),
                Handle::Histogram(hh) => {
                    for b in &hh.0.buckets {
                        b.store(0, Ordering::Relaxed);
                    }
                    hh.0.count.store(0, Ordering::Relaxed);
                    hh.0.sum_ns.store(0, Ordering::Relaxed);
                }
            }
        }
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

/// The process-wide registry — what the CLI's `--metrics-dump` prints.
pub fn global() -> &'static Registry {
    static R: Registry = Registry::new();
    &R
}

/// The well-known instruments fed by the solvers and engines (the
/// metric inventory in `docs/observability.md`). One lazy lookup per
/// process; call sites reach them via [`core`] and gate on
/// [`crate::obs::enabled`].
pub struct CoreMetrics {
    /// Hadar DP memo hits (includes the replay pass's revisits).
    pub dp_memo_hits: Counter,
    /// Hadar DP memo misses.
    pub dp_memo_misses: Counter,
    /// Rounds solved by the exact select/skip DP.
    pub dp_rounds: Counter,
    /// Rounds solved by the payoff-density greedy.
    pub greedy_rounds: Counter,
    /// Hadar `FIND_ALLOC` invocations (speculative scores and
    /// commit-time rescores both count; infeasible avail-bails too).
    pub hadar_find_alloc_calls: Counter,
    /// Candidate allocations scored across all Hadar `FIND_ALLOC` calls
    /// (packed + pure-spread + mixed-spread).
    pub hadar_candidates_scored: Counter,
    /// Speculatively scored jobs whose winning candidate touched a GPU
    /// type dirtied by an earlier commit and were rescored serially.
    pub hadar_rescore_conflicts: Counter,
    /// Hadar none-row cache hits: pending jobs skipped because a prior
    /// `FIND_ALLOC` under the same round signature proved no positive-
    /// payoff candidate exists.
    pub hadar_none_row_hits: Counter,
    /// HadarE gang-planner rounds.
    pub hadare_plan_rounds: Counter,
    /// HadarE warm-start gang rows computed (row-cache misses).
    pub hadare_warm_rows_computed: Counter,
    /// HadarE warm-start gang rows served from the cache.
    pub hadare_warm_rows_reused: Counter,
    /// HadarE warm-start row-cache clears forced by slot-inventory
    /// changes (node join/leave/capacity events).
    pub hadare_warm_invalidations: Counter,
    /// `ClusterState::checkpoint` calls.
    pub state_checkpoints: Counter,
    /// `ClusterState::rewind` calls.
    pub state_rewinds: Counter,
    /// Assignments undone across all rewinds (total rewind depth).
    pub state_rewound_assignments: Counter,
    /// Free-slot bucket scans (`ClusterState::free_slots_of_type`).
    pub state_slot_scans: Counter,
    /// Engine rounds executed.
    pub sim_rounds: Counter,
    /// Jobs force-preempted by node drains / capacity shrinks.
    pub sim_preemptions: Counter,
    /// Checkpoint-restart overhead charges applied.
    pub sim_restart_charges: Counter,
    /// Arrived, incomplete jobs at the latest round (waiting set depth).
    pub sim_queue_depth: Gauge,
    /// Jobs in the persistent active set at the latest scheduled round
    /// (the delta pipeline's waiting-set size).
    pub sim_active_jobs: Gauge,
    /// Round-delta arrivals consumed by schedulers (sum over rounds).
    pub sim_delta_arrivals: Counter,
    /// Round-delta completions consumed by schedulers (sum over rounds).
    pub sim_delta_completions: Counter,
    /// Per-round `Scheduler::schedule` wall clock (seconds).
    pub sched_round_secs: Histogram,
}

/// The [`CoreMetrics`] singleton, registered in [`global`].
pub fn core() -> &'static CoreMetrics {
    static C: OnceLock<CoreMetrics> = OnceLock::new();
    C.get_or_init(|| {
        let r = global();
        CoreMetrics {
            dp_memo_hits: r.counter("hadar.dp_memo_hits"),
            dp_memo_misses: r.counter("hadar.dp_memo_misses"),
            dp_rounds: r.counter("hadar.dp_rounds"),
            greedy_rounds: r.counter("hadar.greedy_rounds"),
            hadar_find_alloc_calls: r.counter("hadar.find_alloc_calls"),
            hadar_candidates_scored: r
                .counter("hadar.candidates_scored"),
            hadar_rescore_conflicts: r
                .counter("hadar.rescore_conflicts"),
            hadar_none_row_hits: r.counter("hadar.none_row_hits"),
            hadare_plan_rounds: r.counter("hadare.plan_rounds"),
            hadare_warm_rows_computed: r
                .counter("hadare.warm_rows_computed"),
            hadare_warm_rows_reused: r
                .counter("hadare.warm_rows_reused"),
            hadare_warm_invalidations: r
                .counter("hadare.warm_invalidations"),
            state_checkpoints: r.counter("cluster.checkpoints"),
            state_rewinds: r.counter("cluster.rewinds"),
            state_rewound_assignments: r
                .counter("cluster.rewound_assignments"),
            state_slot_scans: r.counter("cluster.slot_scans"),
            sim_rounds: r.counter("sim.rounds"),
            sim_preemptions: r.counter("sim.preemptions"),
            sim_restart_charges: r.counter("sim.restart_charges"),
            sim_queue_depth: r.gauge("sim.queue_depth"),
            sim_active_jobs: r.gauge("sim.active_jobs"),
            sim_delta_arrivals: r.counter("sim.delta_arrivals"),
            sim_delta_completions: r.counter("sim.delta_completions"),
            sched_round_secs: r.histogram("sim.sched_round_secs"),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_roundtrip() {
        let r = Registry::new();
        let c = r.counter("t.count");
        c.add(3);
        r.counter("t.count").add(2);
        assert_eq!(c.get(), 5, "handles share the same atomic");

        let g = r.gauge("t.depth");
        g.set(7.5);
        assert_eq!(r.gauge("t.depth").get(), 7.5);

        let h = r.histogram("t.lat");
        h.record(0.001); // 2^-10 bucket range
        h.record(0.001);
        h.record(100.0); // overflow bucket
        assert_eq!(h.count(), 3);
        assert!((h.sum_secs() - 100.002).abs() < 1e-6);
        let buckets = h.buckets();
        assert_eq!(buckets.last().unwrap().1, 1, "overflow bucket");
        let small: u64 = buckets
            .iter()
            .filter(|(le, _)| *le <= 0.001 * (1.0 + 1e-12))
            .map(|(_, n)| n)
            .sum();
        assert_eq!(small, 2, "1 ms samples land at or below the 2^-10 bound");

        let snap = r.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[0].name, "t.count");
        assert_eq!(snap[0].value, MetricValue::Counter(5));

        r.reset();
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn bucket_index_maps_powers_exactly() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-1.0), 0);
        // 2^-20 is the bound of bucket 0.
        assert_eq!(bucket_index((2.0f64).powi(-20)), 0);
        // Just above it spills into bucket 1.
        assert_eq!(bucket_index((2.0f64).powi(-20) * 1.01), 1);
        // 1 s = 2^0 -> bucket 20.
        assert_eq!(bucket_index(1.0), 20);
        // Anything above 2^6 s lands in the overflow bucket.
        assert_eq!(bucket_index(1e9), HIST_BUCKETS - 1);
    }
}
