//! Scoped spans with thread-local stacks and folded-stack export.
//!
//! A span is entered with [`span`] and closed when the returned RAII
//! guard drops. While tracing is enabled ([`crate::obs::enabled`]) each
//! guard pushes its name onto a thread-local stack, reads a monotonic
//! clock on enter/exit, and accumulates `(call count, nanoseconds)`
//! under the *folded path* — the `;`-joined stack, e.g.
//! `sim.round;sched.schedule;hadar.dp` — the exact line format
//! `flamegraph.pl` consumes (see [`folded`]).
//!
//! Disabled-path contract: [`span`] does one relaxed atomic load and
//! returns an inert guard — no clock read, no allocation, no lock, no
//! thread-local touch. The [`enters`] counter increments only on the
//! *enabled* path, so tests can assert the disabled path stayed cold by
//! counting instead of timing (`rust/tests/obs_telemetry.rs`).
//!
//! Accumulation is thread-local (lock-free on the hot path); [`flush`]
//! merges the calling thread's totals into the process-wide table that
//! [`folded`] and [`totals`] read. Engines and the sweep runner flush
//! at natural boundaries (end of run / scenario).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Enabled-path span entries since the last [`reset`] — the probe the
/// overhead-guard test counts (disabled spans must not move it).
static ENTERS: AtomicU64 = AtomicU64::new(0);

/// Process-wide folded totals: path -> (calls, nanoseconds). Fed only by
/// [`flush`], never on the span hot path.
static GLOBAL: Mutex<BTreeMap<String, (u64, u64)>> =
    Mutex::new(BTreeMap::new());

thread_local! {
    /// Names of the spans currently open on this thread, outermost first.
    static STACK: RefCell<Vec<&'static str>> = RefCell::new(Vec::new());
    /// This thread's folded totals, merged into [`GLOBAL`] by [`flush`].
    static LOCAL: RefCell<BTreeMap<String, (u64, u64)>> =
        RefCell::new(BTreeMap::new());
}

/// RAII span guard returned by [`span`]. Inert (all fields `None`) when
/// tracing was disabled at enter time.
pub struct Span {
    start: Option<Instant>,
}

/// Open a span named `name`. Drop the returned guard to close it.
///
/// `name` should follow the `layer.phase` naming scheme documented in
/// `docs/observability.md` (e.g. `hadar.find_alloc`). When tracing is
/// disabled this is one atomic load and a branch.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !crate::obs::enabled() {
        return Span { start: None };
    }
    enter(name)
}

#[cold]
fn enter(name: &'static str) -> Span {
    ENTERS.fetch_add(1, Ordering::Relaxed);
    STACK.with(|s| s.borrow_mut().push(name));
    Span {
        start: Some(Instant::now()),
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let ns = t0.elapsed().as_nanos() as u64;
            let key = STACK.with(|s| {
                let mut s = s.borrow_mut();
                let key = s.join(";");
                s.pop();
                key
            });
            LOCAL.with(|m| {
                let mut m = m.borrow_mut();
                let e = m.entry(key).or_insert((0, 0));
                e.0 += 1;
                e.1 += ns;
            });
        }
    }
}

/// Merge the calling thread's span totals into the process-wide table.
/// Cheap when the thread recorded nothing.
pub fn flush() {
    LOCAL.with(|m| {
        let mut m = m.borrow_mut();
        if m.is_empty() {
            return;
        }
        let mut g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        for (key, (calls, ns)) in std::mem::take(&mut *m) {
            let e = g.entry(key).or_insert((0, 0));
            e.0 += calls;
            e.1 += ns;
        }
    });
}

/// Folded-stack dump of every flushed span total: one
/// `path;to;span <nanoseconds>` line per distinct stack, sorted by path
/// (deterministic order). Pipe straight into `flamegraph.pl`. Flushes
/// the calling thread first.
pub fn folded() -> String {
    flush();
    let g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = String::new();
    for (path, &(_calls, ns)) in g.iter() {
        out.push_str(path);
        out.push(' ');
        out.push_str(&ns.to_string());
        out.push('\n');
    }
    out
}

/// Flushed span totals as `(folded path, calls, nanoseconds)` rows,
/// sorted by path. Flushes the calling thread first.
pub fn totals() -> Vec<(String, u64, u64)> {
    flush();
    let g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    g.iter().map(|(k, &(c, ns))| (k.clone(), c, ns)).collect()
}

/// Enabled-path span entries since the last [`reset`]. The overhead
/// guard asserts this does not move while tracing is disabled.
pub fn enters() -> u64 {
    ENTERS.load(Ordering::Relaxed)
}

/// Clear the calling thread's totals, the process-wide table, and the
/// [`enters`] counter. (Other threads' unflushed totals survive until
/// they flush — tests that reset serialize on one thread.)
pub fn reset() {
    LOCAL.with(|m| m.borrow_mut().clear());
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner()).clear();
    ENTERS.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_fold_and_disabled_spans_are_invisible() {
        let _g = crate::util::log::test_lock();
        crate::obs::set_enabled(false);
        reset();

        // Disabled: no probe movement, nothing recorded.
        let before = enters();
        for _ in 0..1000 {
            let _s = span("trace.test.off");
        }
        assert_eq!(enters(), before, "disabled spans must stay cold");
        assert!(!folded().contains("trace.test.off"));

        // Enabled: nesting produces the folded path.
        crate::obs::set_enabled(true);
        {
            let _a = span("trace.test.outer");
            let _b = span("trace.test.inner");
        }
        crate::obs::set_enabled(false);
        let dump = folded();
        assert!(
            dump.contains("trace.test.outer;trace.test.inner "),
            "{dump}"
        );
        assert!(dump.contains("\ntrace.test.outer ")
                    || dump.starts_with("trace.test.outer "),
                "{dump}");
        assert_eq!(enters(), before + 2);
        let rows = totals();
        let inner = rows
            .iter()
            .find(|(p, _, _)| p == "trace.test.outer;trace.test.inner")
            .expect("inner row");
        assert_eq!(inner.1, 1, "one call");
        reset();
        assert!(folded().is_empty());
    }
}
