//! Observability: span tracing, a metrics registry, and per-round
//! telemetry export (see `docs/observability.md`).
//!
//! Three layers, all self-contained (no external crates — same sandbox
//! constraint as the rest of `util`):
//!
//! * [`trace`] — lightweight scoped spans (RAII guard, thread-local span
//!   stack, monotonic nanosecond timers) over the scheduler and engine
//!   hot paths, exportable as a folded-stack (flamegraph-compatible)
//!   text dump.
//! * [`metrics`] — a registry of counters/gauges/histograms fed by the
//!   solvers and engines through cheap atomic handles (DP memo
//!   hits/misses, checkpoint/rewind depth, free-slot scans, queue depth,
//!   preemptions, restart-overhead charges, per-round solver wall-clock).
//! * [`export`] — the per-round JSONL telemetry stream
//!   (`hadar simulate --telemetry <file>`, `SweepSpec.telemetry`) and the
//!   Prometheus-style text snapshot (`hadar simulate --metrics-dump`).
//!
//! **Off by default, near-zero cost when disabled.** Every span/metric
//! hook is gated on one global flag read with a single relaxed atomic
//! load ([`enabled`]); the disabled path does no allocation, takes no
//! lock, and reads no clock. Telemetry never perturbs plans: spans and
//! metrics only *observe* — the same seed produces identical
//! [`crate::sched::RoundPlan`]s and identical non-timing telemetry with
//! tracing on or off (asserted by `rust/tests/obs_telemetry.rs`).

pub mod export;
pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Globally enable or disable span tracing and metric collection.
///
/// Telemetry JSONL streams ([`export::TelemetrySink`]) are independent of
/// this flag — a sink passed to an engine is always written — so the
/// determinism tests can compare streams across both flag states.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether tracing/metrics are collecting. One relaxed atomic load —
/// this is the *entire* disabled-path cost of every span and metric
/// hook (guarded callers branch on it and do nothing else).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Reset all observability state: span totals, the disabled-path probe
/// counter, and every registered metric. Test and long-lived-process
/// hygiene; never called on the hot path.
pub fn reset() {
    trace::reset();
    metrics::global().reset();
}
