//! `hadar` — CLI for the Hadar/HadarE scheduling framework.
//!
//! Subcommands map onto the paper's experiments (see DESIGN.md):
//!   workloads   Tables II/III
//!   motivate    Fig. 1 motivational example
//!   simulate    trace-driven simulation, Figs. 3-4; with --events, the
//!               dynamic-cluster churn comparison; with --preset/--sched/
//!               --telemetry/--metrics-dump/--trace-folded, a single
//!               observability run (see docs/observability.md)
//!   scale       Fig. 5 scheduling-time scalability
//!   rounds      Fig. 6 Hadar vs HadarE round timelines
//!   physical    Figs. 8-10 mixes grid
//!   slots       Figs. 11-12 slot-time sweeps
//!   sweep       declarative multi-threaded scenario sweeps (expt)
//!   train       end-to-end real-training emulation + Table IV
//!   bench       scheduler hot-path microbench -> BENCH_sched.json
//!   bench-info  where each figure's bench target lives
//!   lint        determinism & plan-path static analysis (CI gate;
//!               see docs/static-analysis.md)

use hadar::util::cli::{App, Args, Command, Parsed};

fn app() -> App {
    App::new("hadar", "heterogeneity-aware DL cluster scheduling (paper reproduction)")
        .command(Command::new("workloads", "print Tables II and III"))
        .command(Command::new("motivate", "Fig. 1 motivational example (Gavel vs Hadar)"))
        .command(
            Command::new("simulate", "trace-driven simulation (Figs. 3-4)")
                .opt("jobs", Some("480"), "number of trace jobs")
                .opt("seed", Some("42"), "trace seed")
                .opt("slot", Some("360"), "slot length in seconds")
                .opt("hours-scale", Some("1.0"), "scale on job GPU-hours")
                .opt("events", Some(""),
                     "cluster event timeline JSON; runs the churn-scenario \
                      comparison instead of Figs. 3-4")
                .opt("cluster", Some("sim60"),
                     "cluster preset for the churn comparison")
                .opt("preset", Some(""),
                     "cluster preset for a single-scheduler run (enables \
                      single-run mode)")
                .opt("sched", Some("hadar"),
                     "scheduler for the single-scheduler run")
                .opt("telemetry", Some(""),
                     "write per-round JSONL telemetry to this file \
                      (single-run mode)")
                .opt("trace-folded", Some(""),
                     "write flamegraph-compatible folded span stacks to \
                      this file (enables span tracing)")
                .switch("metrics-dump",
                        "print a Prometheus-style metrics snapshot after \
                         the run (enables metric collection)")
                .switch("log-json", "emit structured JSON log lines")
                .switch("log-timestamps", "prefix log lines with RFC-3339 \
                                           timestamps"),
        )
        .command(
            Command::new("scale", "Fig. 5 scheduling-time scalability")
                .opt("max", Some("2048"), "largest job count (powers of 2 from 32)")
                .opt("gang-nodes", Some("64"),
                     "--forked: nodes per GPU type in the scaled cluster")
                .opt("gang-gpus", Some("8"),
                     "--forked: GPUs per node in the scaled cluster")
                .switch("forked",
                        "sweep the forking HadarE planner instead: \
                         warm-start vs cold replanning on a scaled:NxG \
                         cluster"),
        )
        .command(Command::new("rounds", "Fig. 6 round-by-round Hadar vs HadarE"))
        .command(
            Command::new("physical", "Figs. 8-10 workload-mix grid")
                .opt("slot", Some("360"), "slot length in seconds"),
        )
        .command(
            Command::new("slots", "Figs. 11-12 slot-time sweeps")
                .opt("scheduler", Some("hadare"), "hadare or hadar"),
        )
        .command(
            Command::new(
                "sweep",
                "declarative scenario sweeps: parallel grid -> JSONL + report",
            )
            .opt("spec", Some(""),
                 "sweep spec JSON file (empty = built-in 16-scenario demo)")
            .opt("workers", Some("0"), "worker threads (0 = all cores)")
            .opt("out", Some("sweep-out"), "artifact output directory")
            .opt("baseline", Some("gavel"),
                 "baseline scheduler for the comparison report")
            .opt("from", Some(""),
                 "re-aggregate an existing summaries.jsonl (skips running)")
            .switch("dry-run", "print the expanded scenario grid and exit")
            .switch("log-json", "emit structured JSON log lines")
            .switch("log-timestamps", "prefix log lines with RFC-3339 \
                                       timestamps"),
        )
        .command(
            Command::new("train", "end-to-end real-training emulation (Table IV)")
                .opt("mix", Some("M-5"), "workload mix (M-1..M-12)")
                .opt("steps-scale", Some("0.01"), "virtual->real step ratio")
                .opt("seed", Some("42"), "emulation seed"),
        )
        .command(
            Command::new(
                "bench",
                "scheduler hot-path microbench: optimised vs reference solver",
            )
            .opt("out", Some("BENCH_sched.json"),
                 "artifact path written with --json")
            .opt("baseline", Some(""),
                 "committed baseline JSON to gate against (fails on >20% \
                  speedup regression on plans-equal rows)")
            .opt("warm-jobs", Some(""),
                 "comma-separated job counts for the warm_*/shard_* \
                  streaming rows (empty = profile default: 800 quick, \
                  20000,100000 full)")
            .opt("stream-jobs", Some(""),
                 "comma-separated job counts for the hadar_stream_*/\
                  hadar_shard_*/hadar_incr_* rows (empty = profile \
                  default; the serial-reference row is skipped above \
                  200k jobs, so e.g. 1000000 is a safe opt-in)")
            .switch("json", "write the BENCH_sched.json artifact")
            .switch("quick", "CI smoke profile: fewer cases and iterations"),
        )
        .command(Command::new("bench-info", "map figures/tables to bench targets"))
        .command(
            Command::new(
                "lint",
                "determinism & plan-path static analysis over the \
                 source tree (docs/static-analysis.md)",
            )
            .opt("src", Some(""),
                 "source root to lint (default: ./rust/src, then ./src)")
            .opt("out", Some(""), "also write the JSON report here")
            .switch("json",
                    "print the machine-readable JSON report instead of \
                     text"),
        )
}

/// Apply the shared `--log-json` / `--log-timestamps` switches.
fn apply_log_flags(args: &Args) {
    if args.flag("log-json") {
        hadar::util::log::set_json(true);
    }
    if args.flag("log-timestamps") {
        hadar::util::log::set_timestamps(true);
    }
}

/// Single-scheduler observability run: one scheduler on one preset, with
/// optional per-round telemetry, a Prometheus metrics snapshot, and a
/// folded-stack span export. `--metrics-dump` / `--trace-folded` enable
/// the (default-off) obs instrumentation; telemetry streams regardless —
/// it reads round state, not span state.
fn simulate_single(args: &Args) -> anyhow::Result<()> {
    use hadar::expt::runner;
    use hadar::expt::spec::{ClusterRef, EventsRef, ScenarioSpec,
                            WorkloadSpec};
    use hadar::obs;
    use hadar::obs::export::TelemetrySink;
    use hadar::sim::engine::SimConfig;

    let preset = {
        let p = args.get_str("preset");
        if p.is_empty() { "sim60".to_string() } else { p }
    };
    let folded_path = args.get_str("trace-folded");
    let metrics_dump = args.flag("metrics-dump");
    if metrics_dump || !folded_path.is_empty() {
        obs::set_enabled(true);
    }

    let slot = args.get_f64("slot");
    let spec = ScenarioSpec {
        scheduler: args.get_str("sched"),
        cluster: ClusterRef::Preset(preset),
        workload: WorkloadSpec::Trace {
            n_jobs: args.get_usize("jobs"),
            max_gpus: 8,
            all_at_start: true,
            hours_scale: args.get_f64("hours-scale"),
        },
        seed: args.get_u64("seed"),
        sim: SimConfig {
            slot_secs: slot,
            restart_overhead: 10.0,
            max_rounds: 50_000,
            horizon: 30.0 * 24.0 * 3600.0,
        },
        events: EventsRef::None,
    };

    let telemetry_path = args.get_str("telemetry");
    let mut sink = if telemetry_path.is_empty() {
        None
    } else {
        Some(TelemetrySink::to_file(
            std::path::Path::new(&telemetry_path), true)?)
    };
    let res = runner::run_scenario_observed(&spec, sink.as_mut())
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "{}: {} jobs done, ttd {:.0}s, gru {:.1}%, cru {:.1}%, {} rounds",
        res.scheduler,
        res.jct.len(),
        res.ttd,
        res.gru * 100.0,
        res.cru * 100.0,
        res.rounds,
    );
    if let Some(s) = sink.take() {
        let n = s.records();
        s.finish()?;
        println!("wrote {telemetry_path} ({n} records)");
    }
    if !folded_path.is_empty() {
        std::fs::write(&folded_path, obs::trace::folded())?;
        println!("wrote {folded_path}");
    }
    if metrics_dump {
        print!("{}", obs::export::prometheus(obs::metrics::global()));
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    apply_log_flags(args);
    // Single-run observability mode: any of the dedicated flags selects
    // one scheduler on one preset instead of the Figs. 3-4 comparison.
    if !args.get_str("preset").is_empty()
        || !args.get_str("telemetry").is_empty()
        || !args.get_str("trace-folded").is_empty()
        || args.flag("metrics-dump")
    {
        return simulate_single(args);
    }
    let events_path = args.get_str("events");
    if !events_path.is_empty() {
        // Dynamic-cluster mode: replay the event trace under every
        // scheduler and print the churn-comparison table.
        let text = std::fs::read_to_string(&events_path)?;
        let timeline = hadar::cluster::events::EventTimeline::parse(&text)
            .map_err(|e| anyhow::anyhow!("{events_path}: {e}"))?;
        let cfg = hadar::figures::churn::ChurnEvalConfig {
            cluster: args.get_str("cluster"),
            n_jobs: args.get_usize("jobs"),
            seed: args.get_u64("seed"),
            slot_secs: args.get_f64("slot"),
            hours_scale: args.get_f64("hours-scale"),
            ..Default::default()
        };
        let ev = hadar::figures::churn::run(&cfg, &timeline)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("{}", hadar::figures::churn::render(&ev));
        return Ok(());
    }
    let cfg = hadar::figures::trace_eval::TraceEvalConfig {
        n_jobs: args.get_usize("jobs"),
        seed: args.get_u64("seed"),
        slot_secs: args.get_f64("slot"),
        hours_scale: args.get_f64("hours-scale"),
    };
    let te = hadar::figures::trace_eval::run(&cfg);
    println!("{}", hadar::figures::trace_eval::render_fig3(&te));
    println!("{}", hadar::figures::trace_eval::render_fig4(&te));
    Ok(())
}

fn cmd_scale(args: &Args) {
    let max = args.get_usize("max");
    let mut scales = Vec::new();
    let mut n = 32;
    while n <= max {
        scales.push(n);
        n *= 2;
    }
    if args.flag("forked") {
        let pts = hadar::figures::fig5::run_forked(
            &scales,
            args.get_usize("gang-nodes"),
            args.get_usize("gang-gpus"),
        );
        println!("{}", hadar::figures::fig5::render_forked(&pts));
        return;
    }
    let pts = hadar::figures::fig5::run(&scales);
    println!("{}", hadar::figures::fig5::render(&pts));
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use hadar::expt::{artifact, report, runner, spec::SweepSpec};

    apply_log_flags(args);
    let baseline = args.get_str("baseline");

    // Re-aggregation path: load existing artifacts, render, done.
    let from = args.get_str("from");
    if !from.is_empty() {
        let records = artifact::load_jsonl(std::path::Path::new(&from))
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("{}", report::render(&records, &baseline));
        return Ok(());
    }

    let path = args.get_str("spec");
    let spec = if path.is_empty() {
        SweepSpec::demo()
    } else {
        let text = std::fs::read_to_string(&path)?;
        SweepSpec::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    };
    let scenarios = spec.expand();
    println!("sweep '{}': {} scenarios", spec.name, scenarios.len());
    if args.flag("dry-run") {
        for s in &scenarios {
            println!("  {}", s.id());
        }
        return Ok(());
    }

    let workers =
        runner::effective_workers(args.get_usize("workers"), scenarios.len());
    let out = args.get_str("out");
    std::fs::create_dir_all(&out)?;
    // `telemetry: true` in the spec streams one per-round JSONL file per
    // scenario into <out>/telemetry/.
    let telemetry_dir = if spec.telemetry {
        let dir = std::path::PathBuf::from(&out).join("telemetry");
        std::fs::create_dir_all(&dir)?;
        Some(dir)
    } else {
        None
    };
    // lint: allow(wall-clock, reason = "sweep wall-time banner for the operator; not consumed by any scheduler")
    let t0 = std::time::Instant::now();
    let results = runner::run_scenarios_observed(&scenarios, workers,
                                                 telemetry_dir.as_deref())
        .map_err(|e| anyhow::anyhow!(e))?;
    let wall = t0.elapsed().as_secs_f64();
    let records: Vec<artifact::ScenarioRecord> =
        results.iter().map(artifact::ScenarioRecord::from_run).collect();
    let summaries = format!("{out}/summaries.jsonl");
    artifact::write_jsonl(std::path::Path::new(&summaries), &records)?;
    let manifest = artifact::RunManifest {
        sweep: spec.name.clone(),
        scenarios: records.len(),
        workers,
        wall_secs: wall,
        sched_wall_secs_total: records
            .iter()
            .map(|r| r.sched_wall_secs)
            .sum(),
    };
    std::fs::write(
        format!("{out}/manifest.json"),
        manifest.to_json().pretty(),
    )?;

    println!("{}", report::render(&records, &baseline));
    println!(
        "wrote {summaries} + {out}/manifest.json ({} scenarios, {} workers, \
         {wall:.2}s)",
        records.len(),
        workers
    );
    if let Some(dir) = &telemetry_dir {
        println!("telemetry streams in {}", dir.display());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use hadar::sched::bench;
    let quick = args.flag("quick");
    let parse_jobs = |key: &str| -> Vec<usize> {
        args.get_str(key)
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .collect()
    };
    let warm_jobs = parse_jobs("warm-jobs");
    let stream_jobs = parse_jobs("stream-jobs");
    let results = bench::run_suite_with(
        quick,
        if warm_jobs.is_empty() { None } else { Some(&warm_jobs) },
        if stream_jobs.is_empty() { None } else { Some(&stream_jobs) },
    );
    print!("{}", bench::render(&results));
    if args.flag("json") {
        let out = args.get_str("out");
        std::fs::write(&out, bench::to_json(&results, quick).pretty())?;
        println!("wrote {out}");
    }
    // A broken row invariant (plan divergence, or a partial-node plan
    // that failed its occupancy check) is a solver bug, not a perf
    // number — fail loudly so CI smoke runs catch it even without the
    // property tests.
    if let Some(bad) = results.iter().find(|r| !r.plans_equal) {
        anyhow::bail!("{}: bench row invariant broken", bad.name);
    }
    // Perf regression gate against a committed baseline artifact.
    let baseline_path = args.get_str("baseline");
    if !baseline_path.is_empty() {
        let text = std::fs::read_to_string(&baseline_path)?;
        let base = hadar::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{baseline_path}: {e}"))?;
        let diffs = bench::compare_to_baseline(&results, &base, 0.20);
        print!("{}", bench::render_baseline(&diffs));
        let regressed: Vec<&str> = diffs
            .iter()
            .filter(|d| d.regressed)
            .map(|d| d.name.as_str())
            .collect();
        if !regressed.is_empty() {
            anyhow::bail!(
                "speedup regressed >20% vs {baseline_path}: {}",
                regressed.join(", ")
            );
        }
    }
    Ok(())
}

/// `hadar lint`: run the static-analysis pass and exit non-zero on any
/// finding (rule violation, stale pragma, or malformed pragma) — the
/// same contract the CI job gates on.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use std::path::PathBuf;
    let src = args.get_str("src");
    let root = if src.is_empty() {
        ["rust/src", "src"]
            .iter()
            .map(PathBuf::from)
            .find(|p| p.join("lib.rs").is_file())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "neither ./rust/src nor ./src holds a lib.rs; \
                     pass --src <dir>"
                )
            })?
    } else {
        PathBuf::from(src)
    };
    let report = hadar::analysis::lint_tree(&root)
        .map_err(|e| anyhow::anyhow!(e))?;
    let out = args.get_str("out");
    if !out.is_empty() {
        std::fs::write(&out, report.to_json().pretty())?;
    }
    if args.flag("json") {
        println!("{}", report.to_json().pretty());
    } else {
        print!("{}", report.render());
    }
    if !report.clean() {
        anyhow::bail!("{} lint finding(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    use hadar::exec::emulation::*;
    use hadar::sim::engine::SimConfig;
    let manifest = hadar::runtime::Manifest::load(
        hadar::runtime::Manifest::default_dir(),
    )
    .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts`"))?;
    let cfg = EmulationConfig {
        sim: SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 2_000,
            horizon: 1e7,
        },
        steps_scale: args.get_f64("steps-scale"),
        max_real_steps_per_round: 200,
        lr: 0.1,
        seed: args.get_u64("seed"),
    };
    let t4 = hadar::figures::table4::run(&manifest, &cfg)?;
    println!("{}", hadar::figures::table4::render(&t4));
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&argv) {
        Parsed::Help(text) => print!("{text}"),
        Parsed::Error(text) => {
            eprint!("{text}");
            std::process::exit(2);
        }
        Parsed::Run(cmd, args) => match cmd.as_str() {
            "workloads" => {
                println!("{}", hadar::figures::workloads::render_table2());
                println!("{}", hadar::figures::workloads::render_table3());
            }
            "motivate" => {
                let f = hadar::figures::fig1::run();
                println!("{}", hadar::figures::fig1::render(&f));
            }
            "simulate" => {
                if let Err(e) = cmd_simulate(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "scale" => cmd_scale(&args),
            "rounds" => {
                let f = hadar::figures::fig6::run();
                println!("{}", hadar::figures::fig6::render(&f));
            }
            "physical" => {
                let p = hadar::figures::physical::run(args.get_f64("slot"));
                println!("{}", hadar::figures::physical::render_fig8(&p));
                println!("{}", hadar::figures::physical::render_fig9(&p));
                println!("{}", hadar::figures::physical::render_fig10(&p));
            }
            "slots" => {
                let s = hadar::figures::slots::run(&args.get_str("scheduler"));
                println!("{}", hadar::figures::slots::render(&s));
            }
            "sweep" => {
                if let Err(e) = cmd_sweep(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "train" => {
                if let Err(e) = cmd_train(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "bench" => {
                if let Err(e) = cmd_bench(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "lint" => {
                if let Err(e) = cmd_lint(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "bench-info" => {
                println!(
                    "figure/table -> bench target (cargo bench --bench <name>)\n\
                     Fig. 1   fig1_motivation\n\
                     Fig. 3   fig3_gru\n\
                     Fig. 4   fig4_ttd_cdf\n\
                     Fig. 5   fig5_scalability\n\
                     Fig. 6   fig6_rounds\n\
                     Fig. 8   fig8_cru\n\
                     Fig. 9   fig9_ttd\n\
                     Fig. 10  fig10_jct\n\
                     Fig. 11  fig11_slot_hadare\n\
                     Fig. 12  fig12_slot_hadar\n\
                     Table IV table4_quality\n\
                     ablations ablation_hadar, ablation_estimator"
                );
            }
            other => {
                eprintln!("unhandled command {other}");
                std::process::exit(2);
            }
        },
    }
}
