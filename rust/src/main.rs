//! `hadar` — CLI for the Hadar/HadarE scheduling framework.
//!
//! Subcommands map onto the paper's experiments (see DESIGN.md):
//!   workloads   Tables II/III
//!   motivate    Fig. 1 motivational example
//!   simulate    trace-driven simulation, Figs. 3-4; with --events, the
//!               dynamic-cluster churn comparison
//!   scale       Fig. 5 scheduling-time scalability
//!   rounds      Fig. 6 Hadar vs HadarE round timelines
//!   physical    Figs. 8-10 mixes grid
//!   slots       Figs. 11-12 slot-time sweeps
//!   sweep       declarative multi-threaded scenario sweeps (expt)
//!   train       end-to-end real-training emulation + Table IV
//!   bench       scheduler hot-path microbench -> BENCH_sched.json
//!   bench-info  where each figure's bench target lives

use hadar::util::cli::{App, Args, Command, Parsed};

fn app() -> App {
    App::new("hadar", "heterogeneity-aware DL cluster scheduling (paper reproduction)")
        .command(Command::new("workloads", "print Tables II and III"))
        .command(Command::new("motivate", "Fig. 1 motivational example (Gavel vs Hadar)"))
        .command(
            Command::new("simulate", "trace-driven simulation (Figs. 3-4)")
                .opt("jobs", Some("480"), "number of trace jobs")
                .opt("seed", Some("42"), "trace seed")
                .opt("slot", Some("360"), "slot length in seconds")
                .opt("hours-scale", Some("1.0"), "scale on job GPU-hours")
                .opt("events", Some(""),
                     "cluster event timeline JSON; runs the churn-scenario \
                      comparison instead of Figs. 3-4")
                .opt("cluster", Some("sim60"),
                     "cluster preset for the churn comparison"),
        )
        .command(
            Command::new("scale", "Fig. 5 scheduling-time scalability")
                .opt("max", Some("2048"), "largest job count (powers of 2 from 32)"),
        )
        .command(Command::new("rounds", "Fig. 6 round-by-round Hadar vs HadarE"))
        .command(
            Command::new("physical", "Figs. 8-10 workload-mix grid")
                .opt("slot", Some("360"), "slot length in seconds"),
        )
        .command(
            Command::new("slots", "Figs. 11-12 slot-time sweeps")
                .opt("scheduler", Some("hadare"), "hadare or hadar"),
        )
        .command(
            Command::new(
                "sweep",
                "declarative scenario sweeps: parallel grid -> JSONL + report",
            )
            .opt("spec", Some(""),
                 "sweep spec JSON file (empty = built-in 16-scenario demo)")
            .opt("workers", Some("0"), "worker threads (0 = all cores)")
            .opt("out", Some("sweep-out"), "artifact output directory")
            .opt("baseline", Some("gavel"),
                 "baseline scheduler for the comparison report")
            .opt("from", Some(""),
                 "re-aggregate an existing summaries.jsonl (skips running)")
            .switch("dry-run", "print the expanded scenario grid and exit"),
        )
        .command(
            Command::new("train", "end-to-end real-training emulation (Table IV)")
                .opt("mix", Some("M-5"), "workload mix (M-1..M-12)")
                .opt("steps-scale", Some("0.01"), "virtual->real step ratio")
                .opt("seed", Some("42"), "emulation seed"),
        )
        .command(
            Command::new(
                "bench",
                "scheduler hot-path microbench: optimised vs reference solver",
            )
            .opt("out", Some("BENCH_sched.json"),
                 "artifact path written with --json")
            .switch("json", "write the BENCH_sched.json artifact")
            .switch("quick", "CI smoke profile: fewer cases and iterations"),
        )
        .command(Command::new("bench-info", "map figures/tables to bench targets"))
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let events_path = args.get_str("events");
    if !events_path.is_empty() {
        // Dynamic-cluster mode: replay the event trace under every
        // scheduler and print the churn-comparison table.
        let text = std::fs::read_to_string(&events_path)?;
        let timeline = hadar::cluster::events::EventTimeline::parse(&text)
            .map_err(|e| anyhow::anyhow!("{events_path}: {e}"))?;
        let cfg = hadar::figures::churn::ChurnEvalConfig {
            cluster: args.get_str("cluster"),
            n_jobs: args.get_usize("jobs"),
            seed: args.get_u64("seed"),
            slot_secs: args.get_f64("slot"),
            hours_scale: args.get_f64("hours-scale"),
            ..Default::default()
        };
        let ev = hadar::figures::churn::run(&cfg, &timeline)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("{}", hadar::figures::churn::render(&ev));
        return Ok(());
    }
    let cfg = hadar::figures::trace_eval::TraceEvalConfig {
        n_jobs: args.get_usize("jobs"),
        seed: args.get_u64("seed"),
        slot_secs: args.get_f64("slot"),
        hours_scale: args.get_f64("hours-scale"),
    };
    let te = hadar::figures::trace_eval::run(&cfg);
    println!("{}", hadar::figures::trace_eval::render_fig3(&te));
    println!("{}", hadar::figures::trace_eval::render_fig4(&te));
    Ok(())
}

fn cmd_scale(args: &Args) {
    let max = args.get_usize("max");
    let mut scales = Vec::new();
    let mut n = 32;
    while n <= max {
        scales.push(n);
        n *= 2;
    }
    let pts = hadar::figures::fig5::run(&scales);
    println!("{}", hadar::figures::fig5::render(&pts));
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    use hadar::expt::{artifact, report, runner, spec::SweepSpec};

    let baseline = args.get_str("baseline");

    // Re-aggregation path: load existing artifacts, render, done.
    let from = args.get_str("from");
    if !from.is_empty() {
        let records = artifact::load_jsonl(std::path::Path::new(&from))
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("{}", report::render(&records, &baseline));
        return Ok(());
    }

    let path = args.get_str("spec");
    let spec = if path.is_empty() {
        SweepSpec::demo()
    } else {
        let text = std::fs::read_to_string(&path)?;
        SweepSpec::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
    };
    let scenarios = spec.expand();
    println!("sweep '{}': {} scenarios", spec.name, scenarios.len());
    if args.flag("dry-run") {
        for s in &scenarios {
            println!("  {}", s.id());
        }
        return Ok(());
    }

    let workers =
        runner::effective_workers(args.get_usize("workers"), scenarios.len());
    let t0 = std::time::Instant::now();
    let results = runner::run_scenarios(&scenarios, workers)
        .map_err(|e| anyhow::anyhow!(e))?;
    let wall = t0.elapsed().as_secs_f64();
    let records: Vec<artifact::ScenarioRecord> =
        results.iter().map(artifact::ScenarioRecord::from_run).collect();

    let out = args.get_str("out");
    std::fs::create_dir_all(&out)?;
    let summaries = format!("{out}/summaries.jsonl");
    artifact::write_jsonl(std::path::Path::new(&summaries), &records)?;
    let manifest = artifact::RunManifest {
        sweep: spec.name.clone(),
        scenarios: records.len(),
        workers,
        wall_secs: wall,
        sched_wall_secs_total: records
            .iter()
            .map(|r| r.sched_wall_secs)
            .sum(),
    };
    std::fs::write(
        format!("{out}/manifest.json"),
        manifest.to_json().pretty(),
    )?;

    println!("{}", report::render(&records, &baseline));
    println!(
        "wrote {summaries} + {out}/manifest.json ({} scenarios, {} workers, \
         {wall:.2}s)",
        records.len(),
        workers
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use hadar::sched::bench;
    let quick = args.flag("quick");
    let results = bench::run_suite(quick);
    print!("{}", bench::render(&results));
    if args.flag("json") {
        let out = args.get_str("out");
        std::fs::write(&out, bench::to_json(&results, quick).pretty())?;
        println!("wrote {out}");
    }
    // A broken row invariant (plan divergence, or a partial-node plan
    // that failed its occupancy check) is a solver bug, not a perf
    // number — fail loudly so CI smoke runs catch it even without the
    // property tests.
    if let Some(bad) = results.iter().find(|r| !r.plans_equal) {
        anyhow::bail!("{}: bench row invariant broken", bad.name);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    use hadar::exec::emulation::*;
    use hadar::sim::engine::SimConfig;
    let manifest = hadar::runtime::Manifest::load(
        hadar::runtime::Manifest::default_dir(),
    )
    .map_err(|e| anyhow::anyhow!("{e}\nhint: run `make artifacts`"))?;
    let cfg = EmulationConfig {
        sim: SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 2_000,
            horizon: 1e7,
        },
        steps_scale: args.get_f64("steps-scale"),
        max_real_steps_per_round: 200,
        lr: 0.1,
        seed: args.get_u64("seed"),
    };
    let t4 = hadar::figures::table4::run(&manifest, &cfg)?;
    println!("{}", hadar::figures::table4::render(&t4));
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match app().parse(&argv) {
        Parsed::Help(text) => print!("{text}"),
        Parsed::Error(text) => {
            eprint!("{text}");
            std::process::exit(2);
        }
        Parsed::Run(cmd, args) => match cmd.as_str() {
            "workloads" => {
                println!("{}", hadar::figures::workloads::render_table2());
                println!("{}", hadar::figures::workloads::render_table3());
            }
            "motivate" => {
                let f = hadar::figures::fig1::run();
                println!("{}", hadar::figures::fig1::render(&f));
            }
            "simulate" => {
                if let Err(e) = cmd_simulate(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "scale" => cmd_scale(&args),
            "rounds" => {
                let f = hadar::figures::fig6::run();
                println!("{}", hadar::figures::fig6::render(&f));
            }
            "physical" => {
                let p = hadar::figures::physical::run(args.get_f64("slot"));
                println!("{}", hadar::figures::physical::render_fig8(&p));
                println!("{}", hadar::figures::physical::render_fig9(&p));
                println!("{}", hadar::figures::physical::render_fig10(&p));
            }
            "slots" => {
                let s = hadar::figures::slots::run(&args.get_str("scheduler"));
                println!("{}", hadar::figures::slots::render(&s));
            }
            "sweep" => {
                if let Err(e) = cmd_sweep(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "train" => {
                if let Err(e) = cmd_train(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "bench" => {
                if let Err(e) = cmd_bench(&args) {
                    eprintln!("error: {e:#}");
                    std::process::exit(1);
                }
            }
            "bench-info" => {
                println!(
                    "figure/table -> bench target (cargo bench --bench <name>)\n\
                     Fig. 1   fig1_motivation\n\
                     Fig. 3   fig3_gru\n\
                     Fig. 4   fig4_ttd_cdf\n\
                     Fig. 5   fig5_scalability\n\
                     Fig. 6   fig6_rounds\n\
                     Fig. 8   fig8_cru\n\
                     Fig. 9   fig9_ttd\n\
                     Fig. 10  fig10_jct\n\
                     Fig. 11  fig11_slot_hadare\n\
                     Fig. 12  fig12_slot_hadar\n\
                     Table IV table4_quality\n\
                     ablations ablation_hadar, ablation_estimator"
                );
            }
            other => {
                eprintln!("unhandled command {other}");
                std::process::exit(2);
            }
        },
    }
}
