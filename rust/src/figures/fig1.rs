//! Fig. 1 + §II-A motivational example: three jobs on a 2xV100 + 3xP100 +
//! 1xK80 cluster under Gavel vs Hadar — round-by-round remaining epochs,
//! CRU per round, and the total round count.

use crate::cluster::gpu::GpuType;
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::{Job, JobId};
use crate::jobs::model::DlModel;
use crate::jobs::queue::JobQueue;
use crate::sched::{gavel::Gavel, hadar::Hadar, Scheduler};
use crate::sim::engine::{self, SimConfig, SimResult};
use crate::util::table::Table;

/// The three motivational jobs: J1 (3 GPUs, 80 epochs), J2 (2, 30),
/// J3 (2, 50). Throughputs follow the §II-A X-matrix flavour, with
/// per-job heterogeneity sensitivity as in the paper's §I observation:
/// J1 is ResNet-50-steep (~8x V100:K80), J2 moderate, J3 A3C-flat (~1.4x)
/// — flat jobs are exactly the ones task-level mixing helps.
pub fn jobs() -> Vec<Job> {
    // (id, W_j, epochs, x_V100, x_P100, x_K80) — iterations/second chosen
    // so jobs span several 360 s rounds (10 iterations per epoch).
    let specs = [
        (1u64, 3usize, 80u64, 0.24, 0.15, 0.03),
        (2, 2, 30, 0.20, 0.14, 0.07),
        (3, 2, 50, 0.10, 0.09, 0.07),
    ];
    specs
        .iter()
        .map(|&(id, w, epochs, v, p, k)| {
            let mut j = Job::new(id, DlModel::ResNet18, 0.0, w, epochs, 10);
            j.set_throughput(GpuType::V100, v);
            j.set_throughput(GpuType::P100, p);
            j.set_throughput(GpuType::K80, k);
            j
        })
        .collect()
}

/// The motivational head-to-head results.
pub struct Fig1 {
    /// Gavel's run (job-level, single-type gangs).
    pub gavel: SimResult,
    /// Hadar's run (task-level, mixed-type gangs).
    pub hadar: SimResult,
}

/// Run both schedulers over the §II-A example.
pub fn run() -> Fig1 {
    let cluster = ClusterSpec::motivational();
    let cfg = SimConfig {
        slot_secs: 360.0,
        restart_overhead: 10.0,
        max_rounds: 200,
        horizon: 1e6,
    };
    let run_one = |mut s: Box<dyn Scheduler>| -> SimResult {
        let mut q = JobQueue::new();
        for j in jobs() {
            q.admit(j).unwrap();
        }
        engine::run(&mut q, s.as_mut(), &cluster, &cfg, true)
    };
    Fig1 {
        gavel: run_one(Box::new(Gavel::new())),
        hadar: run_one(Box::new(Hadar::new())),
    }
}

/// Render the round-by-round Fig. 1 tables.
pub fn render(f: &Fig1) -> String {
    let mut out = String::new();
    for (name, res) in [("Gavel", &f.gavel), ("Hadar", &f.hadar)] {
        out.push_str(&format!(
            "\n{name}: rounds={} CRU={:.0}% TTD={:.0}s\n",
            res.rounds,
            res.gru * 100.0,
            res.ttd
        ));
        let mut t = Table::new(&["round", "J1 rem", "J2 rem", "J3 rem",
                                 "busy GPUs", "CRU"]);
        for rec in &res.timeline {
            let rem = |id: u64| -> String {
                rec.jobs
                    .get(&JobId(id))
                    .map(|rj| format!("{:.0}ep", rj.remaining_before / 10.0))
                    .unwrap_or_else(|| "-".to_string())
            };
            let busy: usize =
                rec.jobs.values().map(|rj| rj.gpus).sum();
            t.row(&[
                format!("R{}", rec.round + 1),
                rem(1),
                rem(2),
                rem(3),
                format!("{busy}/6"),
                format!("{:.0}%",
                        100.0 * rec.busy_gpu_secs / rec.avail_gpu_secs),
            ]);
        }
        out.push_str(&t.render());
    }
    out.push_str(&format!(
        "\npaper: Hadar CRU ~87% vs Gavel ~78%, Hadar one round shorter\n\
         ours : Hadar CRU {:.0}% vs Gavel {:.0}%, rounds {} vs {}\n",
        f.hadar.gru * 100.0,
        f.gavel.gru * 100.0,
        f.hadar.rounds,
        f.gavel.rounds
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadar_dominates_gavel_on_motivational_example() {
        let f = run();
        // The paper's headline on this example: Hadar finishes at least one
        // round earlier with utilisation at or above Gavel's.
        assert!(f.hadar.rounds < f.gavel.rounds,
                "rounds: hadar {} vs gavel {}", f.hadar.rounds,
                f.gavel.rounds);
        assert!(f.hadar.ttd <= f.gavel.ttd,
                "TTD: hadar {} vs gavel {}", f.hadar.ttd, f.gavel.ttd);
        assert!(f.hadar.gru > f.gavel.gru - 0.02,
                "CRU: hadar {} vs gavel {}", f.hadar.gru, f.gavel.gru);
        // Stable placements: Hadar restarts fewer rounds than Gavel's
        // priority rotation.
        assert!(f.hadar.change_fraction <= f.gavel.change_fraction);
        assert_eq!(f.hadar.jct.len(), 3);
        assert_eq!(f.gavel.jct.len(), 3);
    }

    #[test]
    fn render_includes_rounds() {
        let f = run();
        let s = render(&f);
        assert!(s.contains("R1"));
        assert!(s.contains("CRU"));
    }
}
