//! Experiment drivers — one per paper table/figure (see DESIGN.md's
//! experiment index), plus the dynamic-cluster churn comparison that the
//! paper's static setup cannot express. Shared by `examples/` and
//! `rust/benches/`.

pub mod churn;
pub mod fig1;
pub mod fig5;
pub mod fig6;
pub mod physical;
pub mod slots;
pub mod table4;
pub mod trace_eval;
pub mod workloads;
