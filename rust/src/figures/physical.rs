//! Figs. 8-10 (physical clusters, §VI): CRU, TTD, and average JCT of
//! Gavel / Hadar / HadarE over the seven workload mixes (M-1 … M-12) on
//! both five-node clusters (AWS and the lab testbed), in virtual time.

use crate::expt::runner;
use crate::expt::spec::{ClusterRef, EventsRef, SweepSpec, WorkloadSpec};
use crate::sim::engine::SimConfig;
use crate::sim::metrics::Metrics;
use crate::trace::workload::MIX_NAMES;
use crate::util::stats;
use crate::util::table::{ratio, Table};

/// One (cluster, mix, scheduler) measurement.
#[derive(Clone, Debug)]
pub struct Cell {
    /// Cluster label (`"aws5"` / `"testbed5"`).
    pub cluster: String,
    /// Workload mix name (`"M-1"` … `"M-12"`).
    pub mix: String,
    /// Scheduler name.
    pub scheduler: String,
    /// The run's summary metrics.
    pub metrics: Metrics,
}

/// The full Figs. 8-10 grid.
pub struct Physical {
    /// All `(cluster, mix, scheduler)` measurements.
    pub cells: Vec<Cell>,
}

/// Schedulers of the physical-cluster comparison, in figure order.
pub const SCHEDULERS: [&str; 3] = ["gavel", "hadar", "hadare"];

/// The §VI engine parameters at a given slot length.
pub fn sim_cfg(slot_secs: f64) -> SimConfig {
    SimConfig {
        slot_secs,
        restart_overhead: 10.0,
        max_rounds: 20_000,
        horizon: 1e7,
    }
}

/// The Figs. 8-10 grid as a declarative sweep: 2 clusters x 7 mixes x
/// 3 schedulers at one slot length.
pub fn sweep_spec(slot_secs: f64) -> SweepSpec {
    SweepSpec {
        name: "physical".into(),
        schedulers: SCHEDULERS.iter().map(|s| s.to_string()).collect(),
        clusters: vec![
            ClusterRef::Preset("aws5".into()),
            ClusterRef::Preset("testbed5".into()),
        ],
        workloads: MIX_NAMES
            .iter()
            .map(|m| WorkloadSpec::Mix {
                name: m.to_string(),
                epochs_scale: 1.0,
            })
            .collect(),
        slots_secs: vec![slot_secs],
        seeds: vec![0],
        events: vec![EventsRef::None],
        base: sim_cfg(slot_secs),
        telemetry: false,
    }
}

/// Full grid for Figs. 8-10 at the paper's default 360 s slot, executed in
/// parallel by the `expt` runner.
pub fn run(slot_secs: f64) -> Physical {
    let results =
        runner::run_sweep(&sweep_spec(slot_secs), 0).expect("sweep runs");
    Physical {
        cells: results
            .iter()
            .map(|r| Cell {
                cluster: r.spec.cluster.label(),
                mix: r.spec.workload.label(),
                scheduler: r.spec.scheduler.clone(),
                metrics: Metrics::from_result(&r.result),
            })
            .collect(),
    }
}

/// Look up one grid cell's metrics (panics if absent — figure internals).
pub fn get<'a>(p: &'a Physical, cluster: &str, mix: &str, sched: &str)
               -> &'a Metrics {
    &p.cells
        .iter()
        .find(|c| c.cluster == cluster && c.mix == mix
              && c.scheduler == sched)
        .expect("cell exists")
        .metrics
}

fn mean_ratio(p: &Physical, cluster: &str, num: &str, den: &str,
              field: impl Fn(&Metrics) -> f64) -> f64 {
    let ratios: Vec<f64> = MIX_NAMES
        .iter()
        .map(|m| field(get(p, cluster, m, num)) / field(get(p, cluster, m, den)))
        .collect();
    stats::mean(&ratios)
}

/// Fig. 8 (CRU) rows per cluster.
pub fn render_fig8(p: &Physical) -> String {
    let mut out = String::new();
    for cluster in ["aws5", "testbed5"] {
        out.push_str(&format!("\nFig. 8 — CRU on {cluster}\n"));
        let mut t = Table::new(&["mix", "Gavel", "Hadar", "HadarE"]);
        for mix in MIX_NAMES {
            t.row(&[
                mix.to_string(),
                format!("{:.0}%", get(p, cluster, mix, "gavel").gru * 100.0),
                format!("{:.0}%", get(p, cluster, mix, "hadar").gru * 100.0),
                format!("{:.0}%", get(p, cluster, mix, "hadare").gru * 100.0),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "mean CRU gain vs Gavel: Hadar {:.2}x, HadarE {:.2}x \
             (paper: ~1.20x/1.21x and 1.56x/1.62x)\n",
            mean_ratio(p, cluster, "hadar", "gavel", |m| m.gru),
            mean_ratio(p, cluster, "hadare", "gavel", |m| m.gru),
        ));
    }
    out
}

/// Fig. 9 (TTD) rows per cluster.
pub fn render_fig9(p: &Physical) -> String {
    let mut out = String::new();
    for cluster in ["aws5", "testbed5"] {
        out.push_str(&format!("\nFig. 9 — TTD on {cluster}\n"));
        let mut t = Table::new(&["mix", "Gavel", "Hadar", "HadarE",
                                 "Gavel/Hadar", "Gavel/HadarE"]);
        for mix in MIX_NAMES {
            let g = get(p, cluster, mix, "gavel").ttd;
            let h = get(p, cluster, mix, "hadar").ttd;
            let e = get(p, cluster, mix, "hadare").ttd;
            t.row(&[
                mix.to_string(),
                format!("{:.0}s", g),
                format!("{:.0}s", h),
                format!("{:.0}s", e),
                ratio(g, h),
                ratio(g, e),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "mean TTD speedup vs Gavel: Hadar {:.2}x, HadarE {:.2}x \
             (paper: 1.17x/1.16x and 2.12x/1.79x-range)\n",
            mean_ratio(p, cluster, "gavel", "hadar", |m| m.ttd),
            mean_ratio(p, cluster, "gavel", "hadare", |m| m.ttd),
        ));
    }
    out
}

/// Fig. 10 (avg JCT with min/max ranges) rows per cluster.
pub fn render_fig10(p: &Physical) -> String {
    let mut out = String::new();
    for cluster in ["aws5", "testbed5"] {
        out.push_str(&format!("\nFig. 10 — JCT on {cluster}\n"));
        let mut t = Table::new(&["mix", "Gavel avg [min,max]",
                                 "Hadar avg [min,max]",
                                 "HadarE avg [min,max]"]);
        for mix in MIX_NAMES {
            let cell = |s: &str| -> String {
                let m = get(p, cluster, mix, s);
                format!("{:.0}s [{:.0},{:.0}]", m.jct_mean, m.jct_min,
                        m.jct_max)
            };
            t.row(&[mix.to_string(), cell("gavel"), cell("hadar"),
                    cell("hadare")]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "mean JCT reduction vs Gavel: Hadar {:.2}x, HadarE {:.2}x \
             (paper: 1.17x/1.23x and 2.23x/2.76x)\n",
            mean_ratio(p, cluster, "gavel", "hadar", |m| m.jct_mean.max(1e-9)),
            mean_ratio(p, cluster, "gavel", "hadare",
                       |m| m.jct_mean.max(1e-9)),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Physical {
        // Small slot keeps tests quick while preserving the ordering.
        run(90.0)
    }

    #[test]
    fn ordering_matches_paper_on_both_clusters() {
        let p = quick();
        for cluster in ["aws5", "testbed5"] {
            // Headline claim: HadarE boosts whole-cluster utilisation well
            // past both baselines (paper: 1.56x/1.62x vs Gavel).
            let e_cru = mean_ratio(&p, cluster, "hadare", "gavel", |m| m.gru);
            let h_cru = mean_ratio(&p, cluster, "hadar", "gavel", |m| m.gru);
            assert!(e_cru > 1.2, "{cluster}: hadare CRU ratio {e_cru}");
            assert!(e_cru > h_cru, "{cluster}: hadare {e_cru} vs {h_cru}");
            // Hadar beats Gavel on the allocated-slot CRU (stable
            // placements avoid Gavel's rotation restarts).
            let h_alloc =
                mean_ratio(&p, cluster, "hadar", "gavel", |m| m.cru);
            assert!(h_alloc >= 1.0, "{cluster}: hadar alloc-CRU {h_alloc}");
            let h_ttd = mean_ratio(&p, cluster, "gavel", "hadar", |m| m.ttd);
            let e_ttd = mean_ratio(&p, cluster, "gavel", "hadare", |m| m.ttd);
            assert!(h_ttd >= 1.0, "{cluster}: hadar TTD speedup {h_ttd}");
            assert!(e_ttd > h_ttd, "{cluster}: hadare {e_ttd}");
        }
    }

    #[test]
    fn all_cells_complete_all_jobs() {
        let p = quick();
        for c in &p.cells {
            let expect = crate::trace::workload::mix(&c.mix).unwrap().len();
            assert_eq!(c.metrics.completed, expect,
                       "{}/{}/{}", c.cluster, c.mix, c.scheduler);
        }
    }

    #[test]
    fn hadare_jct_range_is_tighter() {
        // Paper: JCT ranges more confined under HadarE.
        let p = quick();
        let mut tighter = 0;
        let mut total = 0;
        for cluster in ["aws5", "testbed5"] {
            for mix in ["M-5", "M-8", "M-10", "M-12"] {
                let e = get(&p, cluster, mix, "hadare");
                let g = get(&p, cluster, mix, "gavel");
                total += 1;
                if (e.jct_max - e.jct_min) <= (g.jct_max - g.jct_min) {
                    tighter += 1;
                }
            }
        }
        assert!(tighter * 2 >= total, "{tighter}/{total} tighter");
    }

    #[test]
    fn renders_cover_all_mixes() {
        let p = quick();
        for s in [render_fig8(&p), render_fig9(&p), render_fig10(&p)] {
            for m in MIX_NAMES {
                assert!(s.contains(m));
            }
        }
    }
}
