//! Table IV: inference quality of models trained under HadarE (forking)
//! vs Hadar (no forking) — **real training** through the PJRT runtime on
//! the emulated testbed cluster, M-5 mix.

use crate::cluster::spec::ClusterSpec;
use crate::exec::emulation::{
    run_hadare_emulation, run_scheduler_emulation, EmulationConfig,
};
use crate::exec::quality::{evaluate_quality, QualityReport};
use crate::jobs::model::QualityMetric;
use crate::runtime::artifacts::Manifest;
use crate::sched::hadar::Hadar;
use crate::trace::workload::physical_jobs;
use crate::util::table::Table;
use anyhow::Result;

/// The Table IV comparison plus its runs' headline numbers.
pub struct Table4 {
    /// Per-job quality rows (forking vs no forking).
    pub report: QualityReport,
    /// HadarE's virtual makespan (seconds).
    pub hadare_ttd: f64,
    /// Hadar's virtual makespan (seconds).
    pub hadar_ttd: f64,
    /// Real PJRT train steps executed across both runs.
    pub real_steps: u64,
}

/// Run both emulations over the M-5 mix and evaluate quality.
pub fn run(manifest: &Manifest, cfg: &EmulationConfig) -> Result<Table4> {
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-5", &cluster, 1.0).expect("M-5");
    let forked = run_hadare_emulation(&jobs, &cluster, manifest, cfg, None)?;
    let mut hadar = Hadar::new();
    let unforked =
        run_scheduler_emulation(&jobs, &mut hadar, &cluster, manifest, cfg)?;
    let pairs: Vec<_> = jobs.iter().map(|j| (j.id, j.model)).collect();
    let report = evaluate_quality(&pairs, &forked.models, &unforked.models,
                                  manifest, cfg.seed, cfg.seed ^ 0xEEAA)?;
    Ok(Table4 {
        report,
        hadare_ttd: forked.sim.ttd,
        hadar_ttd: unforked.sim.ttd,
        real_steps: forked.total_real_steps + unforked.total_real_steps,
    })
}

/// Render the Table IV quality table.
pub fn render(t4: &Table4) -> String {
    let mut t = Table::new(&["Training Job", "Forking (HadarE)",
                             "No Forking (Hadar)", "Metric", "winner"]);
    for row in &t4.report.rows {
        let fmt = |v: f64| match row.metric {
            QualityMetric::Acc => format!("{v:.2}"),
            QualityMetric::Mse => format!("{v:.3}"),
        };
        t.row(&[
            format!("{} ({})", row.model.task(), row.model.code()),
            fmt(row.forking),
            fmt(row.no_forking),
            match row.metric {
                QualityMetric::Acc => "ACC".to_string(),
                QualityMetric::Mse => "MSE (held-out CE)".to_string(),
            },
            if row.forking_wins() { "forking" } else { "no-forking" }
                .to_string(),
        ]);
    }
    let mut out = t.render();
    let wins =
        t4.report.rows.iter().filter(|r| r.forking_wins()).count();
    out.push_str(&format!(
        "forking wins {}/{} rows (paper: 5/5); virtual TTD: HadarE {:.0}s \
         vs Hadar {:.0}s; real train steps executed: {}\n",
        wins,
        t4.report.rows.len(),
        t4.hadare_ttd,
        t4.hadar_ttd,
        t4.real_steps
    ));
    out
}
