//! Figs. 3-4 (trace-driven evaluation, paper §IV): GPU resource
//! utilisation and completion CDF / TTD of the four schedulers over a
//! Philly-shaped 480-job trace on the 60-GPU simulated cluster.

use crate::expt::runner;
use crate::expt::spec::{ClusterRef, EventsRef, SweepSpec, WorkloadSpec};
use crate::sched;
use crate::sim::engine::{SimConfig, SimResult};
use crate::sim::metrics::{completion_cdf, Metrics};
use crate::util::table::{ratio, Chart, Table};

/// Knobs for the Figs. 3-4 trace evaluation.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvalConfig {
    /// Number of trace jobs (paper: 480).
    pub n_jobs: usize,
    /// Trace seed.
    pub seed: u64,
    /// Slot length `L` (seconds).
    pub slot_secs: f64,
    /// Scale on job GPU-hours (1.0 = paper magnitude; smaller runs faster).
    pub hours_scale: f64,
}

impl Default for TraceEvalConfig {
    fn default() -> Self {
        TraceEvalConfig {
            n_jobs: 480,
            seed: 42,
            slot_secs: 360.0,
            hours_scale: 1.0,
        }
    }
}

/// The Figs. 3-4 results, one entry per scheduler.
pub struct TraceEval {
    /// `(scheduler name, result)` in comparison order.
    pub results: Vec<(String, SimResult)>,
}

/// The Figs. 3-4 grid as a declarative sweep: four schedulers over one
/// Philly-shaped trace on `sim60` (scheduler is the only populated axis).
pub fn sweep_spec(cfg: &TraceEvalConfig) -> SweepSpec {
    SweepSpec {
        name: "trace_eval".into(),
        schedulers: sched::SCHEDULER_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect(),
        clusters: vec![ClusterRef::Preset("sim60".into())],
        workloads: vec![WorkloadSpec::Trace {
            n_jobs: cfg.n_jobs,
            max_gpus: 8,
            all_at_start: true,
            hours_scale: cfg.hours_scale,
        }],
        slots_secs: vec![cfg.slot_secs],
        seeds: vec![cfg.seed],
        events: vec![EventsRef::None],
        base: SimConfig {
            slot_secs: cfg.slot_secs,
            restart_overhead: 10.0,
            max_rounds: 50_000,
            horizon: 30.0 * 24.0 * 3600.0,
        },
        telemetry: false,
    }
}

/// Run the Figs. 3-4 sweep on all cores.
pub fn run(cfg: &TraceEvalConfig) -> TraceEval {
    let results = runner::run_sweep(&sweep_spec(cfg), 0).expect("sweep runs");
    TraceEval {
        results: results
            .into_iter()
            .map(|r| (r.spec.scheduler.clone(), r.result))
            .collect(),
    }
}

fn get<'a>(te: &'a TraceEval, name: &str) -> &'a SimResult {
    &te.results.iter().find(|(n, _)| n == name).unwrap().1
}

/// Fig. 3 rows: GRU per scheduler.
///
/// The paper's GRU is "the percentage of the total job run-time during
/// which GPUs are utilized" — i.e. utilisation over *allocated* time
/// (`SimResult::cru`): YARN-CS never checkpoints/restarts, so it tops the
/// chart while posting the worst TTD in Fig. 4; preemptive rotation
/// (Tiresias/Gavel) pays the 10 s restart out of every changed slot.
/// The whole-makespan busy fraction is shown alongside for context.
pub fn render_fig3(te: &TraceEval) -> String {
    let mut t = Table::new(&["scheduler", "GRU", "busy/makespan",
                             "paper expectation"]);
    let expect = [
        ("yarn-cs", "highest (non-preemptive)"),
        ("tiresias", "lowest band"),
        ("gavel", "mid"),
        ("hadar", "~YARN-CS, above Gavel/Tiresias"),
    ];
    for (name, note) in expect {
        let res = get(te, name);
        t.row(&[
            name.to_string(),
            format!("{:.1}%", res.cru * 100.0),
            format!("{:.1}%", res.gru * 100.0),
            note.to_string(),
        ]);
    }
    t.render()
}

/// Fig. 4: completion CDF chart + TTD ratios table.
pub fn render_fig4(te: &TraceEval) -> String {
    let mut out = String::new();
    let max_h = te
        .results
        .iter()
        .map(|(_, r)| r.ttd / 3600.0)
        .fold(0.0f64, f64::max);
    let points: Vec<f64> =
        (0..=40).map(|i| i as f64 * max_h / 40.0).collect();
    let mut chart = Chart::new(
        "Fig. 4 — cumulative fraction of completed jobs over time",
        "hours",
        "fraction complete",
    );
    for (name, res) in &te.results {
        chart.series(name, completion_cdf(res, &points));
    }
    out.push_str(&chart.render(72, 16));

    let hadar = get(te, "hadar");
    let mut t = Table::new(&["scheduler", "TTD", "vs Hadar", "median-50%",
                             "mean JCT"]);
    for (name, res) in &te.results {
        let m = Metrics::from_result(res);
        t.row(&[
            name.clone(),
            crate::util::table::human_time(res.ttd),
            ratio(res.ttd, hadar.ttd),
            crate::util::table::human_time(m.median_completion),
            crate::util::table::human_time(m.jct_mean),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "paper: Hadar TTD 40h; 1.21x vs Gavel, 1.35x vs Tiresias, 1.67x vs \
         YARN-CS; median-50% 1.20x vs Gavel, 1.40x vs Tiresias\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TraceEval {
        run(&TraceEvalConfig {
            n_jobs: 60,
            seed: 7,
            slot_secs: 360.0,
            hours_scale: 0.2,
        })
    }

    #[test]
    fn hadar_beats_baselines_on_ttd() {
        let te = small();
        let ttd = |n: &str| get(&te, n).ttd;
        assert!(ttd("hadar") <= ttd("gavel") * 1.05,
                "hadar {} vs gavel {}", ttd("hadar"), ttd("gavel"));
        assert!(ttd("hadar") < ttd("yarn-cs"),
                "hadar {} vs yarn {}", ttd("hadar"), ttd("yarn-cs"));
        // Everyone finishes the workload.
        for (n, r) in &te.results {
            assert_eq!(r.jct.len(), 60, "{n} completed {}", r.jct.len());
        }
    }

    #[test]
    fn hadar_utilisation_above_gavel() {
        let te = small();
        // Fig. 3's GRU (utilisation of allocated time).
        assert!(get(&te, "hadar").cru > get(&te, "gavel").cru * 0.98);
        // And the whole-makespan busy fraction.
        assert!(get(&te, "hadar").gru > get(&te, "gavel").gru * 0.95);
    }

    #[test]
    fn yarn_cs_tops_gru_but_loses_ttd() {
        // The paper's Fig. 3/4 tension: YARN-CS has the highest GRU
        // (non-preemptive, no restarts) and the worst TTD.
        let te = small();
        for other in ["tiresias", "gavel", "hadar"] {
            assert!(get(&te, "yarn-cs").cru >= get(&te, other).cru * 0.98,
                    "yarn vs {other}");
            assert!(get(&te, "yarn-cs").ttd >= get(&te, other).ttd,
                    "yarn TTD vs {other}");
        }
    }

    #[test]
    fn renders_have_all_schedulers() {
        let te = small();
        let s3 = render_fig3(&te);
        let s4 = render_fig4(&te);
        for n in ["hadar", "gavel", "tiresias", "yarn-cs"] {
            assert!(s3.contains(n));
            assert!(s4.contains(n));
        }
    }
}
