//! Tables II & III: the workload catalogues, printed as the paper lays
//! them out (plus the mix notation of §VI-B).

use crate::jobs::model::DlModel;
use crate::trace::workload::{mix, MIX_NAMES};
use crate::util::table::Table;

/// Render Table II (trace-driven evaluation workloads).
pub fn render_table2() -> String {
    let mut t = Table::new(&["Training Job", "Model", "Dataset", "Size"]);
    for m in DlModel::TABLE2 {
        t.row(&[
            m.task().to_string(),
            m.name().to_string(),
            m.dataset().to_string(),
            m.size_class().name().to_string(),
        ]);
    }
    format!("Table II — trace-driven evaluation workloads\n{}", t.render())
}

/// Render Table III (physical-cluster workloads + mix notation).
pub fn render_table3() -> String {
    let mut t = Table::new(&["Training Job", "Model", "Dataset", "Size"]);
    for m in DlModel::TABLE3 {
        t.row(&[
            format!("{} ({})", m.task(), m.code()),
            m.name().to_string(),
            m.dataset().to_string(),
            m.size_class().name().to_string(),
        ]);
    }
    let mut out =
        format!("Table III — physical-cluster workloads\n{}", t.render());
    out.push_str("\nworkload mixes:\n");
    for name in MIX_NAMES {
        let models = mix(name).unwrap();
        let codes: Vec<&str> = models.iter().map(|m| m.code()).collect();
        out.push_str(&format!("  {name:<5} = <{}>\n", codes.join(", ")));
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn tables_render() {
        let t2 = super::render_table2();
        assert!(t2.contains("ResNet-50") && t2.contains("ImageNet"));
        let t3 = super::render_table3();
        assert!(t3.contains("MiMa") && t3.contains("M-12"));
    }
}
