//! Churn-scenario comparison: every generic scheduler replayed against
//! one *identical* cluster-event trace (node joins, drains, maintenance
//! windows, capacity changes) over one trace workload.
//!
//! This is the dynamic-cluster counterpart of the Figs. 3-4 evaluation:
//! the static reproduction cannot express elastic capacity or failure
//! resilience, so this driver reports — per scheduler under the same
//! churn — completion counts, TTD, the nominal GRU, the
//! availability-normalised utilisation (ANU: busy GPU-seconds over the
//! GPU-seconds that actually existed), and drain-preemption counts.
//! Exposed as `hadar simulate --events <file>`.

use crate::cluster::events::EventTimeline;
use crate::expt::artifact::ScenarioRecord;
use crate::expt::runner;
use crate::expt::spec::{ClusterRef, EventsRef, ScenarioSpec, WorkloadSpec};
use crate::sched;
use crate::sim::engine::SimConfig;
use crate::util::table::{human_time, ratio, Table};

/// Workload/cluster knobs for the churn comparison (the event trace comes
/// separately, from a file or a generator).
#[derive(Clone, Debug)]
pub struct ChurnEvalConfig {
    /// Cluster preset name (see [`crate::expt::spec::preset`]).
    pub cluster: String,
    /// Number of trace jobs.
    pub n_jobs: usize,
    /// Cap on requested gang sizes.
    pub max_gpus: usize,
    /// Trace seed.
    pub seed: u64,
    /// Slot length `L` (seconds).
    pub slot_secs: f64,
    /// Scale on job GPU-hours (1.0 = paper magnitude).
    pub hours_scale: f64,
}

impl Default for ChurnEvalConfig {
    fn default() -> Self {
        ChurnEvalConfig {
            cluster: "sim60".into(),
            n_jobs: 60,
            max_gpus: 4,
            seed: 42,
            slot_secs: 360.0,
            hours_scale: 0.2,
        }
    }
}

/// The comparison outcome: one summary record per scheduler, all under
/// the same event trace.
pub struct ChurnEval {
    /// The event trace's label.
    pub timeline: String,
    /// Per-scheduler records, in [`sched::SCHEDULER_NAMES`] order.
    pub records: Vec<ScenarioRecord>,
}

/// Run every generic scheduler under `events` on the configured workload
/// (all cores).
pub fn run(cfg: &ChurnEvalConfig, events: &EventTimeline)
           -> Result<ChurnEval, String> {
    let scenarios: Vec<ScenarioSpec> = sched::SCHEDULER_NAMES
        .iter()
        .map(|s| ScenarioSpec {
            scheduler: s.to_string(),
            cluster: ClusterRef::Preset(cfg.cluster.clone()),
            workload: WorkloadSpec::Trace {
                n_jobs: cfg.n_jobs,
                max_gpus: cfg.max_gpus,
                all_at_start: true,
                hours_scale: cfg.hours_scale,
            },
            seed: cfg.seed,
            sim: SimConfig {
                slot_secs: cfg.slot_secs,
                ..Default::default()
            },
            events: EventsRef::Inline(events.clone()),
        })
        .collect();
    let results = runner::run_scenarios(&scenarios, 0)?;
    Ok(ChurnEval {
        timeline: if events.name.is_empty() {
            format!("{} events", events.events.len())
        } else {
            events.name.clone()
        },
        records: results.iter().map(ScenarioRecord::from_run).collect(),
    })
}

/// Render the churn-comparison table.
pub fn render(ev: &ChurnEval) -> String {
    let hadar_ttd = ev
        .records
        .iter()
        .find(|r| r.scheduler == "hadar")
        .map(|r| r.ttd);
    let mut out = format!(
        "churn comparison — identical event trace '{}' under every \
         scheduler\n",
        ev.timeline
    );
    let mut t = Table::new(&[
        "scheduler",
        "done",
        "TTD",
        "vs hadar",
        "GRU (nominal)",
        "ANU (available)",
        "CRU",
        "preempt",
    ]);
    for r in &ev.records {
        t.row(&[
            r.scheduler.clone(),
            format!("{}", r.completed),
            human_time(r.ttd),
            hadar_ttd
                .map(|h| ratio(r.ttd, h))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}%", r.gru * 100.0),
            format!("{:.1}%", r.anu * 100.0),
            format!("{:.1}%", r.cru * 100.0),
            format!("{}", r.preemptions),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "ANU normalises by the capacity that actually existed over time; \
         GRU by the nominal (initial) capacity.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::events::EventKind;

    fn small_cfg() -> ChurnEvalConfig {
        ChurnEvalConfig {
            cluster: "motivational".into(),
            n_jobs: 4,
            max_gpus: 2,
            seed: 3,
            slot_secs: 360.0,
            hours_scale: 0.05,
        }
    }

    #[test]
    fn identical_trace_compares_all_schedulers() {
        let mut events = EventTimeline {
            name: "drill".into(),
            events: Vec::new(),
        };
        // The P100 node goes down for two slots early on.
        events.push(
            360.0,
            EventKind::Maintenance { node: 1, duration: 720.0 },
        );
        let ev = run(&small_cfg(), &events).unwrap();
        assert_eq!(ev.records.len(), sched::SCHEDULER_NAMES.len());
        for r in &ev.records {
            assert_eq!(r.completed, 4, "{} under churn", r.scheduler);
            assert_eq!(r.events, "drill");
            // Capacity only ever shrinks: ANU >= GRU.
            assert!(r.anu >= r.gru - 1e-12, "{}", r.scheduler);
            assert!(r.anu <= 1.0 + 1e-9, "{}", r.scheduler);
        }
        let out = render(&ev);
        for s in sched::SCHEDULER_NAMES {
            assert!(out.contains(s), "{out}");
        }
        assert!(out.contains("preempt"), "{out}");
        assert!(out.contains("drill"), "{out}");
    }

    #[test]
    fn empty_timeline_reduces_to_the_static_comparison() {
        let ev = run(&small_cfg(), &EventTimeline::empty()).unwrap();
        for r in &ev.records {
            assert_eq!(r.preemptions, 0);
            assert!((r.anu - r.gru).abs() < 1e-12, "{}", r.scheduler);
        }
    }
}
