//! Figs. 11-12 (impact of time slots, §VI-D): CRU across slot lengths
//! {90, 180, 360, 720} seconds for HadarE (Fig. 11) and Hadar (Fig. 12)
//! over the workload mixes on both clusters.

use crate::expt::runner;
use crate::expt::spec::{ClusterRef, EventsRef, SweepSpec, WorkloadSpec};
use crate::figures::physical;
use crate::trace::workload::MIX_NAMES;
use crate::util::table::Table;

/// The slot lengths of Figs. 11-12 (seconds).
pub const SLOTS: [f64; 4] = [90.0, 180.0, 360.0, 720.0];

/// The Figs. 11-12 results for one scheduler.
#[derive(Clone, Debug)]
pub struct SlotSweep {
    /// Scheduler swept (`"hadare"` or `"hadar"`).
    pub scheduler: String,
    /// (cluster, mix, slot, cru)
    pub cells: Vec<(String, String, f64, f64)>,
}

/// The Figs. 11-12 grid as a declarative sweep: one scheduler over
/// 2 clusters x 7 mixes x 4 slot lengths.
pub fn sweep_spec(scheduler: &str) -> SweepSpec {
    SweepSpec {
        name: format!("slots_{scheduler}"),
        schedulers: vec![scheduler.to_string()],
        clusters: vec![
            ClusterRef::Preset("aws5".into()),
            ClusterRef::Preset("testbed5".into()),
        ],
        workloads: MIX_NAMES
            .iter()
            .map(|m| WorkloadSpec::Mix {
                name: m.to_string(),
                epochs_scale: 1.0,
            })
            .collect(),
        slots_secs: SLOTS.to_vec(),
        seeds: vec![0],
        events: vec![EventsRef::None],
        base: physical::sim_cfg(SLOTS[0]),
        telemetry: false,
    }
}

/// Run the Figs. 11-12 sweep on all cores.
pub fn run(scheduler: &str) -> SlotSweep {
    let results =
        runner::run_sweep(&sweep_spec(scheduler), 0).expect("sweep runs");
    SlotSweep {
        scheduler: scheduler.to_string(),
        cells: results
            .iter()
            .map(|r| {
                (
                    r.spec.cluster.label(),
                    r.spec.workload.label(),
                    r.spec.sim.slot_secs,
                    r.result.gru,
                )
            })
            .collect(),
    }
}

/// The CRU-maximising slot length for one `(cluster, mix)` cell.
pub fn best_slot(s: &SlotSweep, cluster: &str, mix: &str) -> f64 {
    s.cells
        .iter()
        .filter(|(c, m, _, _)| c == cluster && m == mix)
        // total_cmp: never panic on a degenerate (NaN) CRU cell.
        .max_by(|a, b| a.3.total_cmp(&b.3))
        .map(|&(_, _, slot, _)| slot)
        .unwrap_or(0.0)
}

/// Render the Fig. 11 / Fig. 12 tables.
pub fn render(s: &SlotSweep) -> String {
    let mut out = String::new();
    for cluster in ["aws5", "testbed5"] {
        out.push_str(&format!(
            "\n{} — CRU vs slot time on {cluster}\n",
            if s.scheduler == "hadare" { "Fig. 11 (HadarE)" }
            else { "Fig. 12 (Hadar)" }
        ));
        let mut t = Table::new(&["mix", "90s", "180s", "360s", "720s",
                                 "best"]);
        for mix in MIX_NAMES {
            let mut row = vec![mix.to_string()];
            for &slot in &SLOTS {
                let cru = s
                    .cells
                    .iter()
                    .find(|(c, m, sl, _)| c == cluster && m == mix
                          && *sl == slot)
                    .map(|&(_, _, _, g)| g)
                    .unwrap_or(0.0);
                row.push(format!("{:.0}%", cru * 100.0));
            }
            row.push(format!("{:.0}s", best_slot(s, cluster, mix)));
            t.row(&row);
        }
        out.push_str(&t.render());
    }
    out.push_str(
        "paper: larger mixes peak at 360 s (overhead-dominated below), \
         small mixes at 90 s\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid_and_crus_valid() {
        let s = run("hadare");
        assert_eq!(s.cells.len(), 2 * MIX_NAMES.len() * SLOTS.len());
        for &(_, _, _, cru) in &s.cells {
            assert!((0.0..=1.0).contains(&cru));
        }
    }

    #[test]
    fn overhead_penalises_very_short_slots_for_large_mixes() {
        // With a 10 s restart overhead, 90 s slots lose >= none of their
        // advantage on the biggest mix compared to 360 s in at least one
        // cluster — i.e. the best slot for M-12 is not always the
        // shortest (the paper's observed trade-off).
        let s = run("hadare");
        let best_aws = best_slot(&s, "aws5", "M-12");
        let best_tb = best_slot(&s, "testbed5", "M-12");
        assert!(best_aws >= 90.0 && best_tb >= 90.0);
    }

    #[test]
    fn render_lists_slots() {
        let s = run("hadar");
        let out = render(&s);
        assert!(out.contains("90s") && out.contains("720s"));
        assert!(out.contains("Fig. 12"));
    }
}
