//! Fig. 6: round-by-round node occupancy under Hadar vs HadarE on the
//! 5-node testbed — the illustration of why forking removes idle nodes.

use crate::cluster::spec::ClusterSpec;
use crate::jobs::queue::JobQueue;
use crate::sched::hadar::Hadar;
use crate::sim::engine::{self, SimConfig, SimResult};
use crate::sim::hadare_engine;
use crate::trace::workload::physical_jobs;
use crate::util::table::Table;

/// The Fig. 6 occupancy comparison.
pub struct Fig6 {
    /// Hadar's run (idle nodes when jobs < nodes).
    pub hadar: SimResult,
    /// HadarE's run (forking keeps every node busy).
    pub hadare: SimResult,
    /// Total GPUs in the evaluated cluster (the occupancy denominator —
    /// equal to the node count on the paper's single-GPU testbed, larger
    /// on multi-GPU clusters where HadarE books whole-node gangs).
    pub gpus: usize,
}

/// Run the M-3 mix on the testbed under both engines.
pub fn run() -> Fig6 {
    let cluster = ClusterSpec::testbed5();
    let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
    let cfg = SimConfig {
        slot_secs: 90.0,
        restart_overhead: 10.0,
        max_rounds: 2_000,
        horizon: 1e7,
    };
    let mut queue = JobQueue::new();
    for j in &jobs {
        queue.admit(j.clone()).unwrap();
    }
    let hadar =
        engine::run(&mut queue, &mut Hadar::new(), &cluster, &cfg, true);
    let hadare = hadare_engine::run(&jobs, &cluster, &cfg, None).sim;
    Fig6 {
        hadar,
        hadare,
        gpus: cluster.total_gpus(),
    }
}

/// Render the round-by-round occupancy tables.
pub fn render(f: &Fig6) -> String {
    let mut out = String::new();
    for (name, res) in [("Hadar", &f.hadar), ("HadarE", &f.hadare)] {
        out.push_str(&format!(
            "\n{name}: rounds={} CRU={:.0}% TTD={:.0}s\n",
            res.rounds,
            res.gru * 100.0,
            res.ttd
        ));
        let mut t = Table::new(&["round", "jobs running", "gpus busy",
                                 "round CRU"]);
        for rec in res.timeline.iter().take(12) {
            let gpus_busy: usize =
                rec.jobs.values().map(|rj| rj.gpus).sum();
            t.row(&[
                format!("R{}", rec.round + 1),
                rec.jobs.len().to_string(),
                format!("{gpus_busy}/{}", f.gpus),
                format!("{:.0}%",
                        100.0 * rec.busy_gpu_secs / rec.avail_gpu_secs),
            ]);
        }
        out.push_str(&t.render());
    }
    out.push_str(
        "paper: Hadar idles nodes whenever jobs < nodes; HadarE keeps every \
         node busy until the final round\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadare_keeps_nodes_busy_hadar_idles_them() {
        let f = run();
        // With 3 jobs on 5 nodes, Hadar can never use more than 3 nodes.
        let hadar_max: usize = f
            .hadar
            .timeline
            .iter()
            .map(|r| r.jobs.values().map(|rj| rj.gpus).sum())
            .max()
            .unwrap_or(0);
        assert!(hadar_max <= 3);
        // HadarE's first round uses all 5.
        let first: usize = f.hadare.timeline[0]
            .jobs
            .values()
            .map(|rj| rj.gpus)
            .sum();
        assert_eq!(first, 5);
        assert!(f.hadare.gru > f.hadar.gru);
        assert!(f.hadare.ttd < f.hadar.ttd);
    }
}
