//! Fig. 5 (scalability): scheduling time per round vs active-job count
//! (32 → 2048) for Hadar (incremental mode, per §IV-B) and Gavel, on a
//! cluster that grows with the job count.

use crate::cluster::spec::ClusterSpec;
use crate::jobs::queue::JobQueue;
use crate::sched::gavel::Gavel;
use crate::sched::hadar::{Hadar, HadarConfig};
use crate::sched::{RoundCtx, Scheduler};
use crate::trace::philly::{generate, TraceConfig};
use crate::trace::workload::materialize;
use crate::util::table::Table;
use std::time::Instant;

/// One scalability measurement at a given active-job count.
#[derive(Clone, Debug)]
pub struct Fig5Point {
    /// Active jobs (and proportional cluster size).
    pub jobs: usize,
    /// Hadar's mean per-round decision time (ms).
    pub hadar_ms: f64,
    /// Hadar's decision time in incremental mode (ms).
    pub hadar_incremental_ms: f64,
    /// Gavel's mean per-round decision time (ms).
    pub gavel_ms: f64,
    /// Fraction of incremental rounds that changed allocations.
    pub change_fraction: f64,
}

/// Measure the wall-clock of a *single scheduling decision* at each scale
/// (the paper plots per-round decision time).
pub fn run(scales: &[usize]) -> Vec<Fig5Point> {
    let mut out = Vec::new();
    for &n in scales {
        // Cluster grows with jobs: ~1 GPU per job, 4 per node, 3 types.
        let nodes_per_type = (n / 12).max(1);
        let cluster = ClusterSpec::scaled(nodes_per_type, 4);
        let trace = generate(&TraceConfig {
            n_jobs: n,
            seed: 11,
            all_at_start: true,
            max_gpus: 4,
            ..Default::default()
        });
        let jobs = materialize(&trace, &cluster, 11);
        let mut queue = JobQueue::new();
        for j in jobs {
            queue.admit(j);
        }
        let active = queue.active_at(0.0);
        let time_one = |s: &mut dyn Scheduler, rounds: usize| -> f64 {
            let mut total = 0.0;
            for round in 0..rounds {
                let ctx = RoundCtx {
                    round: round as u64,
                    now: round as f64 * 360.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    cluster: &cluster,
                };
                let t0 = Instant::now();
                let _ = s.schedule(&ctx);
                total += t0.elapsed().as_secs_f64();
            }
            total / rounds as f64 * 1e3
        };
        let mut hadar = Hadar::new();
        let hadar_ms = time_one(&mut hadar, 3);
        let mut hadar_inc = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let hadar_incremental_ms = time_one(&mut hadar_inc, 3);
        let mut gavel = Gavel::new();
        let gavel_ms = time_one(&mut gavel, 3);
        out.push(Fig5Point {
            jobs: n,
            hadar_ms,
            hadar_incremental_ms,
            gavel_ms,
            change_fraction: hadar_inc.stats.rounds_with_change as f64
                / hadar_inc.stats.rounds.max(1) as f64,
        });
    }
    out
}

/// Render the Fig. 5 scaling table.
pub fn render(points: &[Fig5Point]) -> String {
    let mut t = Table::new(&["jobs", "Hadar (ms)", "Hadar-incr (ms)",
                             "Gavel (ms)"]);
    for p in points {
        t.row(&[
            p.jobs.to_string(),
            format!("{:.2}", p.hadar_ms),
            format!("{:.2}", p.hadar_incremental_ms),
            format!("{:.2}", p.gavel_ms),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "paper: Hadar ≈ Gavel scaling; <7 min/round at ~2000 jobs (their \
         python prototype — ours is rust, so absolute values are ms)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduling_time_stays_sane_and_subquadratic() {
        let pts = run(&[32, 128, 512]);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            // Far under the paper's 7-minute bound.
            assert!(p.hadar_ms < 60_000.0, "{} ms", p.hadar_ms);
            assert!(p.gavel_ms < 60_000.0);
        }
        // 16x jobs on a 16x cluster: growth should stay near the O(n*H)
        // envelope (256x), far from cubic blow-up. (The paper's own Fig. 5
        // curve is superlinear too — decision time grows with job count.)
        let grow = pts[2].hadar_ms / pts[0].hadar_ms.max(0.001);
        assert!(grow < 1000.0, "scaling factor {grow}");
    }

    #[test]
    fn incremental_second_round_is_cheap() {
        let pts = run(&[128]);
        // Incremental mode re-uses previous allocations, so its mean over
        // 3 rounds (2 of which are no-ops) is below the full recompute.
        assert!(pts[0].hadar_incremental_ms <= pts[0].hadar_ms * 1.5);
    }
}
