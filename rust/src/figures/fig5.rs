//! Fig. 5 (scalability): scheduling time per round vs active-job count
//! (32 → 2048) for Hadar (incremental mode, per §IV-B) and Gavel, on a
//! cluster that grows with the job count.
//!
//! The `--forked` variant ([`run_forked`]) extends the sweep to the
//! streaming regime: the forking HadarE planner on a *fixed* `scaled:NxG`
//! multi-GPU cluster, warm start ([`HadarE::plan_round_with`] with a
//! populated row cache and the previous round's bindings) against cold
//! replanning on the identical round. The plans must match exactly; the
//! speedup is the sublinear-decision-time claim the `warm_*` bench rows
//! gate on (see `docs/performance.md`).

// lint: allow-file(wall-clock, reason = "Fig. 5 IS a scheduling-time measurement; per-round wall time is the figure's y-axis, not a scheduling input")

use crate::cluster::spec::ClusterSpec;
use crate::forking::forker::ForkIds;
use crate::forking::tracker::JobTracker;
use crate::jobs::queue::JobQueue;
use crate::sched::gavel::Gavel;
use crate::sched::hadar::{Hadar, HadarConfig};
use crate::sched::hadare::{alloc_throughput, HadarE, PrevRound};
use crate::sched::{RoundCtx, Scheduler};
use crate::trace::philly::{generate, TraceConfig};
use crate::trace::workload::materialize;
use crate::util::table::Table;
use std::time::Instant;

/// One scalability measurement at a given active-job count.
#[derive(Clone, Debug)]
pub struct Fig5Point {
    /// Active jobs (and proportional cluster size).
    pub jobs: usize,
    /// Hadar's mean per-round decision time (ms).
    pub hadar_ms: f64,
    /// Hadar's decision time in incremental mode (ms).
    pub hadar_incremental_ms: f64,
    /// Gavel's mean per-round decision time (ms).
    pub gavel_ms: f64,
    /// Fraction of incremental rounds that changed allocations.
    pub change_fraction: f64,
}

/// Measure the wall-clock of a *single scheduling decision* at each scale
/// (the paper plots per-round decision time).
pub fn run(scales: &[usize]) -> Vec<Fig5Point> {
    let mut out = Vec::new();
    for &n in scales {
        // Cluster grows with jobs: ~1 GPU per job, 4 per node, 3 types.
        let nodes_per_type = (n / 12).max(1);
        let cluster = ClusterSpec::scaled(nodes_per_type, 4);
        let trace = generate(&TraceConfig {
            n_jobs: n,
            seed: 11,
            all_at_start: true,
            max_gpus: 4,
            ..Default::default()
        });
        let jobs = materialize(&trace, &cluster, 11);
        let mut queue = JobQueue::new();
        for j in jobs {
            queue.admit(j).unwrap();
        }
        let active = queue.active_at(0.0);
        let time_one = |s: &mut dyn Scheduler, rounds: usize| -> f64 {
            let mut total = 0.0;
            for round in 0..rounds {
                let ctx = RoundCtx {
                    round: round as u64,
                    now: round as f64 * 360.0,
                    slot_secs: 360.0,
                    horizon: 1e7,
                    queue: &queue,
                    active: &active,
                    delta: None,
                    cluster: &cluster,
                };
                let t0 = Instant::now();
                let _ = s.schedule(&ctx);
                total += t0.elapsed().as_secs_f64();
            }
            total / rounds as f64 * 1e3
        };
        let mut hadar = Hadar::new();
        let hadar_ms = time_one(&mut hadar, 3);
        let mut hadar_inc = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let hadar_incremental_ms = time_one(&mut hadar_inc, 3);
        let mut gavel = Gavel::new();
        let gavel_ms = time_one(&mut gavel, 3);
        out.push(Fig5Point {
            jobs: n,
            hadar_ms,
            hadar_incremental_ms,
            gavel_ms,
            change_fraction: hadar_inc.stats.rounds_with_change as f64
                / hadar_inc.stats.rounds.max(1) as f64,
        });
    }
    out
}

/// Render the Fig. 5 scaling table.
pub fn render(points: &[Fig5Point]) -> String {
    let mut t = Table::new(&["jobs", "Hadar (ms)", "Hadar-incr (ms)",
                             "Gavel (ms)"]);
    for p in points {
        t.row(&[
            p.jobs.to_string(),
            format!("{:.2}", p.hadar_ms),
            format!("{:.2}", p.hadar_incremental_ms),
            format!("{:.2}", p.gavel_ms),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "paper: Hadar ≈ Gavel scaling; <7 min/round at ~2000 jobs (their \
         python prototype — ours is rust, so absolute values are ms)\n",
    );
    out
}

/// One warm-vs-cold forking-planner measurement at a given job count
/// (the `--forked` streaming-scale sweep).
#[derive(Clone, Debug)]
pub struct ForkScalePoint {
    /// Queued jobs in the decision.
    pub jobs: usize,
    /// Cold full-replanning decision time, mean over the measured
    /// rounds (ms).
    pub cold_ms: f64,
    /// Warm-start decision time on the identical rounds (ms).
    pub warm_ms: f64,
    /// `cold_ms / warm_ms`.
    pub speedup: f64,
    /// Whether every warm plan matched its cold twin exactly.
    pub plans_match: bool,
    /// Cached throughput rows the warm planner reused instead of
    /// recomputing (the deterministic counterpart of the speedup).
    pub rows_reused: u64,
}

/// Warm-start vs cold-replanning sweep of the forking HadarE planner on
/// a fixed `scaled:{nodes_per_type}x{gpus_per_node}` cluster. Round 0
/// populates the warm planner's row cache and yields the carry-over
/// bindings; every parent then reports half a slot of progress (so the
/// priority order shifts but nobody finishes), and rounds 1–2 are timed
/// warm vs cold on identical state.
pub fn run_forked(scales: &[usize], nodes_per_type: usize,
                  gpus_per_node: usize) -> Vec<ForkScalePoint> {
    let mut out = Vec::new();
    for &n in scales {
        let cluster = ClusterSpec::scaled(nodes_per_type.max(1),
                                          gpus_per_node.max(1));
        let trace = generate(&TraceConfig {
            n_jobs: n,
            seed: 11,
            all_at_start: true,
            max_gpus: 4,
            ..Default::default()
        });
        let mut queue = JobQueue::new();
        for j in materialize(&trace, &cluster, 11) {
            queue.admit(j).unwrap();
        }
        let ids = ForkIds {
            max_job_count: (n as u64).max(64),
        };
        let mut tracker = JobTracker::new(ids);
        for j in queue.iter() {
            tracker.register(j.id, j.total_iters(),
                             &[ids.copy_id(j.id, 1)]);
        }
        let active = queue.active_at(0.0);
        let slot = 360.0;
        let ctx = |round: u64| RoundCtx {
            round,
            now: round as f64 * slot,
            slot_secs: slot,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let mut warm = HadarE::new(1);
        let p0 = warm.plan_round(&ctx(0), &tracker);
        let prev = PrevRound::from_plan(&p0, &tracker, 10.0);
        for (&copy, alloc) in &p0.allocations {
            let parent = tracker.resolve(copy);
            if let Some(job) = queue.get(parent) {
                let x = alloc_throughput(job, alloc, &warm.gang);
                tracker.report_steps(copy, x * slot * 0.5);
            }
        }
        let reused0 = warm.stats.rows_reused;
        let mut cold_total = 0.0;
        let mut warm_total = 0.0;
        let mut plans_match = true;
        for round in 1..=2u64 {
            let c = ctx(round);
            let t0 = Instant::now();
            let cold_plan =
                HadarE::new(1).plan_round_cold(&c, &tracker, &prev);
            cold_total += t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let warm_plan = warm.plan_round_with(&c, &tracker, &prev);
            warm_total += t0.elapsed().as_secs_f64();
            plans_match &= cold_plan.allocations == warm_plan.allocations;
        }
        let cold_ms = cold_total / 2.0 * 1e3;
        let warm_ms = warm_total / 2.0 * 1e3;
        out.push(ForkScalePoint {
            jobs: n,
            cold_ms,
            warm_ms,
            speedup: if warm_ms > 0.0 { cold_ms / warm_ms } else { 0.0 },
            plans_match,
            rows_reused: warm.stats.rows_reused - reused0,
        });
    }
    out
}

/// Render the `--forked` streaming-scale table.
pub fn render_forked(points: &[ForkScalePoint]) -> String {
    let mut t = Table::new(&["jobs", "cold (ms)", "warm (ms)", "speedup",
                             "rows reused", "plans"]);
    for p in points {
        t.row(&[
            p.jobs.to_string(),
            format!("{:.3}", p.cold_ms),
            format!("{:.3}", p.warm_ms),
            format!("{:.2}x", p.speedup),
            p.rows_reused.to_string(),
            if p.plans_match { "match" } else { "DIVERGED" }.to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "warm start must match cold replanning exactly; the speedup is \
         the sublinear-decision-time claim (bench warm_* rows gate it)\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduling_time_stays_sane_and_subquadratic() {
        let pts = run(&[32, 128, 512]);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            // Far under the paper's 7-minute bound.
            assert!(p.hadar_ms < 60_000.0, "{} ms", p.hadar_ms);
            assert!(p.gavel_ms < 60_000.0);
        }
        // 16x jobs on a 16x cluster: growth should stay near the O(n*H)
        // envelope (256x), far from cubic blow-up. (The paper's own Fig. 5
        // curve is superlinear too — decision time grows with job count.)
        let grow = pts[2].hadar_ms / pts[0].hadar_ms.max(0.001);
        assert!(grow < 1000.0, "scaling factor {grow}");
    }

    #[test]
    fn incremental_second_round_is_cheap() {
        let pts = run(&[128]);
        // Incremental mode re-uses previous allocations: over 3 rounds
        // of an identical queue only round 0 may change the allocation,
        // so the solver's own change counter — deterministic, unlike the
        // wall-clock ratio this test used to assert on — is at most 1/3
        // and nonzero (round 0 allocates from scratch).
        assert!(pts[0].change_fraction > 0.0,
                "round 0 must register a change: {}",
                pts[0].change_fraction);
        assert!(pts[0].change_fraction <= 1.0 / 3.0 + 1e-9,
                "steady-state rounds must not replan: {}",
                pts[0].change_fraction);
    }

    #[test]
    fn forked_warm_scale_smoke() {
        let pts = run_forked(&[48], 2, 2);
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.plans_match, "warm plan diverged from cold");
        assert!(p.rows_reused > 0, "warm rounds must hit the row cache");
        assert!(p.cold_ms >= 0.0 && p.warm_ms >= 0.0);
        let table = render_forked(&pts);
        assert!(table.contains("match"), "{table}");
    }
}
