//! Summary statistics and CDFs for metrics reporting.

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    // total_cmp: NaN samples sort to the ends instead of panicking.
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum (`inf` for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (`-inf` for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF over the samples evaluated at `points`: fraction of
/// samples `<= p` for each point. Used for Fig. 4 (completion CDF).
pub fn ecdf_at(samples: &[f64], points: &[f64]) -> Vec<f64> {
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    points
        .iter()
        .map(|p| {
            let count = sorted.partition_point(|x| x <= p);
            count as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// First sample value at which the ECDF reaches fraction `f` (e.g. the
/// paper's "median time to complete 50% of jobs").
pub fn quantile_of_completion(samples: &[f64], f: f64) -> f64 {
    percentile(samples, f * 100.0)
}

/// Running summary for streaming measurements (bench harness).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Samples seen.
    pub n: usize,
    /// Running sum.
    pub sum: f64,
    /// Running sum of squares.
    pub sum_sq: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one sample.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Mean of the samples so far (0 if none).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Population standard deviation of the samples so far.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64) - m * m).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((median(&xs) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn ecdf_monotone_and_bounded() {
        let samples = [1.0, 2.0, 2.0, 3.0, 10.0];
        let points = [0.0, 1.0, 2.0, 5.0, 10.0, 20.0];
        let cdf = ecdf_at(&samples, &points);
        assert_eq!(cdf[0], 0.0);
        assert_eq!(*cdf.last().unwrap(), 1.0);
        for w in cdf.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!((cdf[2] - 0.6).abs() < 1e-12); // 3 of 5 samples <= 2
    }

    #[test]
    fn summary_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn nan_samples_do_not_panic_percentile_or_ecdf() {
        // NaN-comparator regression: the sorts used partial_cmp().unwrap()
        // and panicked on the first NaN sample. total_cmp orders NaN to
        // the ends; the well-formed quantiles stay sane.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        let p0 = percentile(&xs, 0.0);
        assert_eq!(p0, 1.0, "negative-NaN-free input keeps min at 1.0");
        let _ = percentile(&xs, 50.0);
        let _ = median(&xs);
        let cdf = ecdf_at(&xs, &[0.0, 2.0, 100.0]);
        assert_eq!(cdf.len(), 3);
        for w in cdf.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
