//! Lightweight property-testing harness (proptest substitute).
//!
//! The real `proptest` crate is unavailable (no network); this provides the
//! part the test suite needs: seeded random case generation, a fixed case
//! budget, and greedy input shrinking for failures. Used by
//! `rust/tests/prop_*.rs` for scheduler/coordinator invariants.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct Config {
    /// Random cases to generate.
    pub cases: usize,
    /// Generator seed (printed on failure for reproduction).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` against `cases` random inputs drawn by `gen`. On failure,
/// tries up to 64 shrink steps via `shrink` (smaller candidates of the
/// failing input) and panics with the minimal reproduction found.
pub fn check<T, G, S, P>(cfg: Config, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink: repeatedly take the first failing smaller candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 64;
            'outer: while budget > 0 {
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x})\n  input: {:?}\n  error: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

/// Convenience: no shrinking.
pub fn check_no_shrink<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check(cfg, gen, |_| Vec::new(), prop);
}

/// Shrinker for vectors: drop halves, drop single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 8 {
        for i in 0..v.len() {
            let mut smaller = v.to_vec();
            smaller.remove(i);
            out.push(smaller);
        }
    }
    out
}

/// Shrinker for integers: toward zero.
pub fn shrink_int(x: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if x > 0 {
        out.push(x / 2);
        out.push(x - 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check_no_shrink(
            Config { cases: 50, seed: 1 },
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check_no_shrink(
            Config { cases: 50, seed: 2 },
            |rng| rng.below(100),
            |&x| {
                if x < 90 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 90"))
                }
            },
        );
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property: all vectors have length < 4. Shrinking should find a
        // minimal failing vector (length exactly 4).
        let result = std::panic::catch_unwind(|| {
            check(
                Config { cases: 50, seed: 3 },
                |rng| {
                    (0..rng.range_u(0, 12))
                        .map(|_| rng.below(10))
                        .collect::<Vec<u64>>()
                },
                |v| shrink_vec(v),
                |v| {
                    if v.len() < 4 {
                        Ok(())
                    } else {
                        Err(format!("len {}", v.len()))
                    }
                },
            )
        });
        let payload = result.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("len 4"), "shrunk to minimal length: {msg}");
    }

    #[test]
    fn int_shrinker_descends() {
        assert_eq!(shrink_int(10), vec![5, 9]);
        assert!(shrink_int(0).is_empty());
    }
}
