//! ASCII tables and series plots — how every paper figure/table is rendered.
//!
//! The bench harness prints the same rows/series the paper reports; these
//! helpers keep that output aligned and diffable.

/// A simple aligned table with a header row.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given header row.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (panics on arity mismatch with the header).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append one row of `Display` values.
    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| format!("{c}")).collect();
        self.row(&cells)
    }

    /// Render the aligned ASCII table.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            let _ = ncols;
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// A labelled (x, y) series rendered as a unicode line chart — stands in for
/// the paper's figures in terminal output.
pub struct Chart {
    /// Chart title line.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    series: Vec<(String, Vec<(f64, f64)>)>,
}

impl Chart {
    /// Empty chart with labels.
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Chart {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
        }
    }

    /// Add one named `(x, y)` series.
    pub fn series(&mut self, name: &str, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push((name.to_string(), points));
        self
    }

    /// Render as a `width x height` character grid with per-series glyphs.
    pub fn render(&self, width: usize, height: usize) -> String {
        const GLYPHS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];
        let all: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|(_, pts)| pts.iter().cloned())
            .collect();
        if all.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; width]; height];
        for (si, (_, pts)) in self.series.iter().enumerate() {
            let glyph = GLYPHS[si % GLYPHS.len()];
            for &(x, y) in pts {
                let cx = ((x - x0) / (x1 - x0) * (width - 1) as f64).round()
                    as usize;
                let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round()
                    as usize;
                grid[height - 1 - cy][cx.min(width - 1)] = glyph;
            }
        }
        let mut out = format!("{}\n", self.title);
        out.push_str(&format!("{:>10} {}\n", format!("{:.3}", y1), "▲"));
        for row in &grid {
            out.push_str("           ");
            out.push_str(&row.iter().collect::<String>());
            out.push('\n');
        }
        out.push_str(&format!(
            "{:>10} └{}▶ {}\n",
            format!("{:.3}", y0),
            "─".repeat(width),
            self.x_label
        ));
        let legend: Vec<String> = self
            .series
            .iter()
            .enumerate()
            .map(|(i, (name, _))| format!("{} {}", GLYPHS[i % GLYPHS.len()], name))
            .collect();
        out.push_str(&format!("           [{}] y = {}\n", legend.join("  "),
                              self.y_label));
        out
    }
}

/// Format a ratio as the paper does: `1.20x`.
pub fn ratio(a: f64, b: f64) -> String {
    if b == 0.0 {
        "inf".to_string()
    } else {
        format!("{:.2}x", a / b)
    }
}

/// Format seconds as `1h 23m` / `45.2s`.
pub fn human_time(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else if secs >= 60.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{:.1}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["sched", "TTD (h)", "ratio"]);
        t.row(&["Hadar".into(), "40.0".into(), "1.00x".into()]);
        t.row(&["Gavel".into(), "48.4".into(), "1.21x".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("sched"));
        assert!(lines[2].contains("Hadar"));
        // All lines same width.
        assert_eq!(lines[0].chars().count(), lines[2].chars().count());
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn chart_renders_all_series() {
        let mut c = Chart::new("Fig. 4", "hours", "fraction complete");
        c.series("Hadar", vec![(0.0, 0.0), (40.0, 1.0)]);
        c.series("Gavel", vec![(0.0, 0.0), (48.0, 1.0)]);
        let s = c.render(40, 10);
        assert!(s.contains("Fig. 4"));
        assert!(s.contains("* Hadar"));
        assert!(s.contains("+ Gavel"));
    }

    #[test]
    fn helpers() {
        assert_eq!(ratio(48.0, 40.0), "1.20x");
        assert_eq!(human_time(7200.0), "2.0h");
        assert_eq!(human_time(90.0), "1.5m");
        assert_eq!(human_time(5.0), "5.0s");
    }
}
