//! Minimal JSON parser + emitter.
//!
//! Substrate module: `serde`/`serde_json` are unavailable in this sandbox
//! (no network), so configs, the AOT artifact manifest, and experiment
//! reports go through this self-contained implementation. It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null) and pretty/compact emission.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic iteration
/// (reports diff cleanly across runs).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// A parse failure with its byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value truncated to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Numeric value truncated to `u64`.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array contents, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---------------------------------------------------------- construction

    /// Fresh empty object (builder root).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style field insertion (panics on non-objects — builder misuse).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// In-place field insertion (panics on non-objects — builder misuse).
    pub fn insert(&mut self, key: &str, val: impl Into<Json>) {
        match self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::insert on non-object"),
        }
    }

    // ------------------------------------------------------------- emission

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ------------------------------------------------------------------ parsing

/// Parse a complete JSON document (trailing content is an error).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: keep simple (BMP only); the
                            // manifest/config never contain astral chars.
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nums":[1,2.5,-3],"s":"he\"llo","t":true,"z":null}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("name", "hadar")
            .set("jobs", 480usize)
            .set("ok", true)
            .set("ratios", vec![1.0, 1.2]);
        assert_eq!(v.get("jobs").as_usize(), Some(480));
        assert_eq!(v.get("ratios").at(1).as_f64(), Some(1.2));
    }

    #[test]
    fn missing_fields_are_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert_eq!(v.get("nope").get("deeper"), &Json::Null);
        assert_eq!(v.at(3), &Json::Null);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": 1,
          "variants": {"tiny": {"param_count": 87040,
            "params": [{"name": "tok_emb", "shape": [256, 64],
                        "kind": "normal", "scale": 0.02}]}}
        }"#;
        let v = parse(src).unwrap();
        let tiny = v.get("variants").get("tiny");
        assert_eq!(tiny.get("param_count").as_usize(), Some(87040));
        assert_eq!(tiny.get("params").at(0).get("shape").at(1).as_usize(),
                   Some(64));
    }
}
