//! Declarative command-line parser (clap substitute — no network for crates).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
enum Kind {
    Value { default: Option<String> },
    Switch,
}

#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    kind: Kind,
}

/// One (sub)command: a set of options plus metadata.
#[derive(Clone, Debug)]
pub struct Command {
    /// Subcommand name (first argv token).
    pub name: String,
    /// One-line description for `--help`.
    pub about: String,
    opts: Vec<Opt>,
}

impl Command {
    /// Command with no options yet.
    pub fn new(name: &str, about: &str) -> Self {
        Command {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
        }
    }

    /// `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            kind: Kind::Value {
                default: default.map(|s| s.to_string()),
            },
        });
        self
    }

    /// Boolean `--name` switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            kind: Kind::Switch,
        });
        self
    }

    fn usage(&self, program: &str) -> String {
        let mut out = format!("{} {} — {}\n\noptions:\n", program, self.name,
                              self.about);
        for o in &self.opts {
            let line = match &o.kind {
                Kind::Value { default: Some(d) } => {
                    format!("  --{} <v>   {} (default: {})", o.name, o.help, d)
                }
                Kind::Value { default: None } => {
                    format!("  --{} <v>   {} (required)", o.name, o.help)
                }
                Kind::Switch => format!("  --{}       {}", o.name, o.help),
            };
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

/// Parsed arguments for one command.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    /// Tokens that were not `--options`.
    pub positional: Vec<String>,
}

impl Args {
    /// Option value if provided (or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Option value; panics if absent (required options are checked at
    /// parse time, so this is a programming error).
    pub fn get_str(&self, name: &str) -> String {
        self.values
            .get(name)
            .cloned()
            .unwrap_or_else(|| panic!("missing required --{name}"))
    }

    /// Option value parsed as `usize`.
    pub fn get_usize(&self, name: &str) -> usize {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    /// Option value parsed as `u64`.
    pub fn get_u64(&self, name: &str) -> u64 {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    /// Option value parsed as `f64`.
    pub fn get_f64(&self, name: &str) -> f64 {
        self.get_str(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    /// Whether a boolean switch was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.switches.get(name).copied().unwrap_or(false)
    }
}

/// Top-level application: subcommands + dispatch.
pub struct App {
    /// Program name (usage headers).
    pub name: String,
    /// One-line description for the overview.
    pub about: String,
    commands: Vec<Command>,
}

/// What an argv parse produced.
pub enum Parsed {
    /// (command name, parsed args)
    Run(String, Args),
    /// Help/usage text to print; exit 0.
    Help(String),
    /// Error text; exit 2.
    Error(String),
}

impl App {
    /// App with no commands yet.
    pub fn new(name: &str, about: &str) -> Self {
        App {
            name: name.to_string(),
            about: about.to_string(),
            commands: Vec::new(),
        }
    }

    /// Register a subcommand.
    pub fn command(mut self, cmd: Command) -> Self {
        self.commands.push(cmd);
        self
    }

    fn overview(&self) -> String {
        let mut out = format!("{} — {}\n\ncommands:\n", self.name, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str(&format!(
            "\nrun `{} <command> --help` for command options\n",
            self.name
        ));
        out
    }

    /// Parse an argv (without the program name).
    pub fn parse(&self, argv: &[String]) -> Parsed {
        if argv.is_empty()
            || argv[0] == "--help"
            || argv[0] == "-h"
            || argv[0] == "help"
        {
            return Parsed::Help(self.overview());
        }
        let cmd = match self.commands.iter().find(|c| c.name == argv[0]) {
            Some(c) => c,
            None => {
                return Parsed::Error(format!(
                    "unknown command '{}'\n\n{}",
                    argv[0],
                    self.overview()
                ))
            }
        };
        let mut args = Args::default();
        // Seed defaults.
        for o in &cmd.opts {
            if let Kind::Value {
                default: Some(d), ..
            } = &o.kind
            {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Parsed::Help(cmd.usage(&self.name));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = match cmd.opts.iter().find(|o| o.name == name) {
                    Some(o) => o,
                    None => {
                        return Parsed::Error(format!(
                            "unknown option --{name} for '{}'\n\n{}",
                            cmd.name,
                            cmd.usage(&self.name)
                        ))
                    }
                };
                match &opt.kind {
                    Kind::Switch => {
                        args.switches.insert(name, true);
                    }
                    Kind::Value { .. } => {
                        let value = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                match argv.get(i) {
                                    Some(v) => v.clone(),
                                    None => {
                                        return Parsed::Error(format!(
                                            "--{name} expects a value"
                                        ))
                                    }
                                }
                            }
                        };
                        args.values.insert(name, value);
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &cmd.opts {
            if let Kind::Value { default: None } = &o.kind {
                if !args.values.contains_key(&o.name) {
                    return Parsed::Error(format!(
                        "missing required --{}\n\n{}",
                        o.name,
                        cmd.usage(&self.name)
                    ));
                }
            }
        }
        Parsed::Run(cmd.name.clone(), args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("hadar", "DL cluster scheduler")
            .command(
                Command::new("simulate", "trace-driven simulation")
                    .opt("jobs", Some("480"), "number of jobs")
                    .opt("seed", Some("42"), "rng seed")
                    .opt("sched", None, "scheduler name")
                    .switch("verbose", "chatty output"),
            )
            .command(Command::new("workloads", "print Table II/III"))
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_values() {
        match app().parse(&argv(&["simulate", "--sched", "hadar"])) {
            Parsed::Run(name, args) => {
                assert_eq!(name, "simulate");
                assert_eq!(args.get_usize("jobs"), 480);
                assert_eq!(args.get_str("sched"), "hadar");
                assert!(!args.flag("verbose"));
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn parses_equals_form_and_switch() {
        match app().parse(&argv(&[
            "simulate",
            "--jobs=64",
            "--sched=gavel",
            "--verbose",
        ])) {
            Parsed::Run(_, args) => {
                assert_eq!(args.get_usize("jobs"), 64);
                assert!(args.flag("verbose"));
            }
            _ => panic!("expected Run"),
        }
    }

    #[test]
    fn missing_required_is_error() {
        assert!(matches!(
            app().parse(&argv(&["simulate"])),
            Parsed::Error(_)
        ));
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(matches!(app().parse(&argv(&["nope"])), Parsed::Error(_)));
        assert!(matches!(
            app().parse(&argv(&["simulate", "--sched", "x", "--bogus"])),
            Parsed::Error(_)
        ));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&argv(&[])), Parsed::Help(_)));
        assert!(matches!(app().parse(&argv(&["--help"])), Parsed::Help(_)));
        assert!(matches!(
            app().parse(&argv(&["simulate", "--help"])),
            Parsed::Help(_)
        ));
    }
}
