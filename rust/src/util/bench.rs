//! Bench harness (criterion substitute) for `[[bench]] harness = false`
//! targets.
//!
//! Every paper figure/table has a bench target under `rust/benches/` that
//! (1) regenerates the figure's rows/series via this harness, printing the
//! same quantities the paper reports, and (2) times the run. Timing method:
//! warmup iterations followed by measured iterations, reporting
//! mean ± stddev / min / max.

use crate::util::stats::Summary;
use std::time::Instant;

/// One named measurement: warmup runs, timed runs, a summary line.
pub struct Bencher {
    /// Label printed in the summary line.
    pub name: String,
    warmup: usize,
    iters: usize,
}

impl Bencher {
    /// Bencher with 1 warmup and 5 measured iterations.
    pub fn new(name: &str) -> Self {
        Bencher {
            name: name.to_string(),
            warmup: 1,
            iters: 5,
        }
    }

    /// Set the warmup iteration count.
    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Set the measured iteration count.
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    /// Time `f`, printing a criterion-style summary line. Returns the last
    /// result so benches can also *print* the figure it regenerates.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> T {
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut s = Summary::new();
        let mut last = None;
        for _ in 0..self.iters.max(1) {
            // lint: allow(wall-clock, reason = "the bench harness exists to measure wall time; results are reporting-only")
            let t0 = Instant::now();
            let out = f();
            s.add(t0.elapsed().as_secs_f64() * 1e3);
            last = Some(out);
        }
        println!(
            "bench {:<40} {:>10.3} ms ± {:>8.3} (min {:.3}, max {:.3}, n={})",
            self.name,
            s.mean(),
            s.stddev(),
            s.min,
            s.max,
            s.n
        );
        last.unwrap()
    }
}

/// Section header in bench output, mirroring the paper's figure captions.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_returns_result() {
        let out = Bencher::new("t").warmup(0).iters(3).run(|| 2 + 2);
        assert_eq!(out, 4);
    }
}
