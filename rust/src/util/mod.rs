//! Self-contained substrate utilities (see DESIGN.md §Substitutions: the
//! usual crates — serde, clap, rand, criterion, proptest — are unavailable
//! in this sandbox, so each has a focused, tested replacement here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
