//! Leveled stderr logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

/// Verbosity levels, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Progress messages (the default level).
    Info = 2,
    /// Diagnostic detail.
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info by default

/// Set the global verbosity threshold.
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// Whether messages at `level` are currently emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(Ordering::Relaxed)
}

/// Emit one message to stderr if the level is enabled.
pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

/// Log at Info level with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) }
}

/// Log at Warn level with `format!` syntax (trailing underscore:
/// `warn` collides with the built-in lint attribute namespace).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) }
}

/// Log at Debug level with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) }
}

/// Log at Error level with `format!` syntax.
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, &format!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
