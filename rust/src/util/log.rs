//! Leveled stderr logger with a global verbosity switch, optional
//! RFC-3339 timestamps, and a line-oriented JSON mode.
//!
//! The `info!`/`warn_!`/`debug!`/`error!` macros are the stable surface;
//! [`log_kv`] additionally carries structured key-value fields, which
//! the JSON mode ([`set_json`], the CLI's `--log-json`) emits as object
//! members instead of flattening into the message:
//!
//! ```text
//! [INFO ] sweep done scenarios=12             # text mode
//! {"level":"info","msg":"sweep done","scenarios":"12"}   # --log-json
//! ```
//!
//! All switches are process-wide atomics; tests that flip them must
//! serialize through [`test_lock`] and restore the prior state on exit
//! (see [`level_gating`](self::tests) for the pattern).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{SystemTime, UNIX_EPOCH};

/// Verbosity levels, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Progress messages (the default level).
    Info = 2,
    /// Diagnostic detail.
    Debug = 3,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        }
    }

    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info by default
static JSON_MODE: AtomicBool = AtomicBool::new(false);
static TIMESTAMPS: AtomicBool = AtomicBool::new(false);

/// Set the global verbosity threshold.
pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

/// The current verbosity threshold (so tests and guards can restore it).
pub fn get_level() -> Level {
    Level::from_u8(VERBOSITY.load(Ordering::Relaxed))
}

/// Whether messages at `level` are currently emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(Ordering::Relaxed)
}

/// Switch between human-readable lines and one-JSON-object-per-line
/// output (the CLI's `--log-json`).
pub fn set_json(on: bool) {
    JSON_MODE.store(on, Ordering::Relaxed);
}

/// Whether JSON line mode is on.
pub fn json_mode() -> bool {
    JSON_MODE.load(Ordering::Relaxed)
}

/// Prefix each line with an RFC-3339 UTC timestamp (the CLI's
/// `--log-timestamps`; always included as a `ts` member in JSON mode
/// while on).
pub fn set_timestamps(on: bool) {
    TIMESTAMPS.store(on, Ordering::Relaxed);
}

/// Whether timestamps are being emitted.
pub fn timestamps() -> bool {
    TIMESTAMPS.load(Ordering::Relaxed)
}

/// Render `unix` seconds as RFC-3339 UTC (`YYYY-MM-DDTHH:MM:SSZ`).
/// Days-to-civil conversion per Howard Hinnant's algorithm.
fn rfc3339(unix: u64) -> String {
    let days = (unix / 86_400) as i64;
    let secs = unix % 86_400;
    let (h, m, s) = (secs / 3600, (secs % 3600) / 60, secs % 60);
    // civil_from_days, shifted so the era starts 0000-03-01.
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let mo = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if mo <= 2 { y + 1 } else { y };
    format!("{y:04}-{mo:02}-{d:02}T{h:02}:{m:02}:{s:02}Z")
}

/// Minimal JSON string escaping for log values (quotes, backslashes,
/// control characters).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Render one log line. Pure — no globals, no clock — so both output
/// modes are unit-testable: `json` selects the mode, `unix_ts` supplies
/// the timestamp (omitted when `None`).
pub fn format_line(level: Level, msg: &str, fields: &[(&str, &str)],
                   json: bool, unix_ts: Option<u64>) -> String {
    if json {
        let mut line = String::from("{");
        if let Some(ts) = unix_ts {
            line.push_str(&format!("\"ts\":\"{}\",", rfc3339(ts)));
        }
        line.push_str(&format!(
            "\"level\":\"{}\",\"msg\":\"{}\"",
            level.name(),
            json_escape(msg)
        ));
        for (k, v) in fields {
            line.push_str(&format!(
                ",\"{}\":\"{}\"",
                json_escape(k),
                json_escape(v)
            ));
        }
        line.push('}');
        line
    } else {
        let mut line = String::new();
        if let Some(ts) = unix_ts {
            line.push_str(&rfc3339(ts));
            line.push(' ');
        }
        line.push_str(&format!("[{}] {}", level.tag(), msg));
        for (k, v) in fields {
            line.push_str(&format!(" {k}={v}"));
        }
        line
    }
}

/// Emit one message to stderr if the level is enabled.
pub fn log(level: Level, msg: &str) {
    log_kv(level, msg, &[]);
}

/// Emit one message with structured key-value fields to stderr if the
/// level is enabled. Fields render as ` k=v` suffixes in text mode and
/// as string members in JSON mode.
pub fn log_kv(level: Level, msg: &str, fields: &[(&str, &str)]) {
    if !enabled(level) {
        return;
    }
    let ts = if timestamps() {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .ok()
            .map(|d| d.as_secs())
    } else {
        None
    };
    eprintln!("{}", format_line(level, msg, fields, json_mode(), ts));
}

/// Serialize tests that touch process-wide observability/logging state
/// (the verbosity/JSON/timestamp atomics here, and the span/metric
/// globals in [`crate::obs`]). Lock poisoning is ignored — a failed
/// test must not cascade.
#[doc(hidden)]
pub fn test_lock() -> MutexGuard<'static, ()> {
    static M: Mutex<()> = Mutex::new(());
    M.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Log at Info level with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) }
}

/// Log at Warn level with `format!` syntax (trailing underscore:
/// `warn` collides with the built-in lint attribute namespace).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) }
}

/// Log at Debug level with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) }
}

/// Log at Error level with `format!` syntax.
#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, &format!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Restores the level it captured when dropped, so a panicking
    /// assertion cannot leak a flipped verbosity into parallel tests.
    struct LevelGuard(Level);

    impl Drop for LevelGuard {
        fn drop(&mut self) {
            set_level(self.0);
        }
    }

    #[test]
    fn level_gating() {
        let _serial = test_lock();
        let _restore = LevelGuard(get_level());
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn format_line_text_and_json() {
        let fields = [("jobs", "12"), ("cluster", "sim60")];
        let text =
            format_line(Level::Info, "sweep done", &fields, false, None);
        assert_eq!(text, "[INFO ] sweep done jobs=12 cluster=sim60");

        let json = format_line(Level::Warn, "odd \"thing\"", &fields, true,
                               None);
        let v = crate::util::json::parse(&json).unwrap();
        assert_eq!(v.get("level").as_str(), Some("warn"));
        assert_eq!(v.get("msg").as_str(), Some("odd \"thing\""));
        assert_eq!(v.get("jobs").as_str(), Some("12"));
        assert_eq!(v.get("cluster").as_str(), Some("sim60"));
        assert!(v.get("ts").as_str().is_none(), "no ts unless requested");
    }

    #[test]
    fn rfc3339_renders_known_instants() {
        assert_eq!(rfc3339(0), "1970-01-01T00:00:00Z");
        // 2000-02-29 (leap day) 12:34:56 UTC.
        assert_eq!(rfc3339(951_827_696), "2000-02-29T12:34:56Z");
        // 2026-08-07 00:00:00 UTC.
        assert_eq!(rfc3339(1_786_060_800), "2026-08-07T00:00:00Z");
        let j = format_line(Level::Info, "x", &[], true, Some(0));
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("ts").as_str(), Some("1970-01-01T00:00:00Z"));
    }
}
