//! Leveled stderr logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(2); // Info by default

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, &format!($($t)*)) }
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, &format!($($t)*)) }
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, &format!($($t)*)) }
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, &format!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
