//! Deterministic PRNG + distributions.
//!
//! Substrate module: the `rand` crate is unavailable in this sandbox (no
//! network; only the `xla` crate's vendored closure exists), so the
//! simulator, trace generator, and parameter initialiser use this
//! self-contained xoshiro256** implementation. Determinism is a feature:
//! every experiment and bench (see `docs/performance.md`) is reproducible
//! from its seed.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 — used to seed xoshiro from a single u64.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (probability ~0, but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream (e.g. per job, per node).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (used by the Rust-side parameter
    /// initialiser mirroring `model.init_params`).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / lambda
    }

    /// Log-normal (heavy-tailed durations, per Philly-trace analyses).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-like rank sample over `n` items with exponent `s` (synthetic
    /// token corpora). Uses rejection-free inverse-CDF over precomputed
    /// weights for small `n`; callers cache `ZipfTable` for hot loops.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        // Simple inverse-transform; O(n) worst case but n is small (vocab
        // sampling goes through ZipfTable instead).
        let norm: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Weighted index sample; weights need not be normalised.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

/// Precomputed cumulative Zipf weights for fast repeated sampling.
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// Table over `n` ranks with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let norm = acc;
        for c in cdf.iter_mut() {
            *c /= norm;
        }
        ZipfTable { cdf }
    }

    /// Draw one rank (0-based).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // total_cmp: the cdf is finite by construction, but a total
        // order keeps a degenerate table from panicking the draw.
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_table_matches_direct() {
        let mut r = Rng::new(23);
        let table = ZipfTable::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[table.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
