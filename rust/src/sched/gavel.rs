//! **Gavel** baseline [Narayanan et al., OSDI'20] — job-level
//! heterogeneity-aware round-based scheduling.
//!
//! Gavel computes an optimal time-fraction matrix `Y` (how much of each
//! GPU type each job should receive) and realises it with round-based
//! priorities `Y_{jr} / rounds_received_j`. The crucial contrast with
//! Hadar (paper §II-A): **within a round all tasks of a job run on a
//! single GPU type** — if no one type has `W_j` free GPUs, the job waits,
//! even when a mixed-type set would satisfy it.
//!
//! `Y` here is the max-min-fair water-filling approximation of Gavel's LP:
//! each job's normalised effective throughput per type, balanced so
//! per-type demand matches capacity in expectation.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::{RoundCtx, Scheduler};
use std::collections::BTreeMap;

/// The Gavel baseline (see module docs).
pub struct Gavel {
    /// Rounds of service received per (job, GPU type) — Gavel's priority
    /// denominator tracks how much of each type a job has already had.
    rounds_received: BTreeMap<(JobId, GpuType), f64>,
}

impl Default for Gavel {
    fn default() -> Self {
        Self::new()
    }
}

impl Gavel {
    /// Fresh scheduler with no service history.
    pub fn new() -> Self {
        Gavel {
            rounds_received: BTreeMap::new(),
        }
    }

    /// Approximate Gavel's optimal allocation matrix `Y` for the active
    /// jobs: normalised per-type throughput, water-filled against per-type
    /// capacity so heavily-demanded types are shared.
    fn compute_y(jobs: &[&Job], gpu_types: &[GpuType],
                 capacity: &BTreeMap<GpuType, usize>)
                 -> BTreeMap<(JobId, GpuType), f64> {
        let mut y = BTreeMap::new();
        // Start with throughput-proportional preferences per job.
        for job in jobs {
            let total: f64 = gpu_types
                .iter()
                .map(|&r| job.throughput_on(r))
                .sum();
            if total <= 0.0 {
                continue;
            }
            for &r in gpu_types {
                y.insert((job.id, r), job.throughput_on(r) / total);
            }
        }
        // Water-fill: scale down columns whose expected demand (in GPUs)
        // exceeds capacity.
        for &r in gpu_types {
            let demand: f64 = jobs
                .iter()
                .map(|j| {
                    y.get(&(j.id, r)).copied().unwrap_or(0.0)
                        * j.gpus_requested as f64
                })
                .sum();
            let cap = capacity.get(&r).copied().unwrap_or(0) as f64;
            if demand > cap && demand > 0.0 {
                let scale = cap / demand;
                for job in jobs {
                    if let Some(v) = y.get_mut(&(job.id, r)) {
                        *v *= scale;
                    }
                }
            }
        }
        y
    }

    /// Try to place `W_j` GPUs of one single type `r` (Gavel's job-level
    /// constraint), consolidating on as few nodes as possible.
    fn place_single_type(state: &ClusterState, w: usize, r: GpuType)
                         -> Option<JobAllocation> {
        if state.free_of_type(r) < w {
            return None;
        }
        let mut slots: Vec<(usize, usize)> = (0..state.n_nodes())
            .map(|h| (h, state.free(h, r)))
            .filter(|&(_, f)| f > 0)
            .collect();
        slots.sort_by(|a, b| b.1.cmp(&a.1));
        let mut alloc = JobAllocation::new();
        let mut need = w;
        for (h, free) in slots {
            if need == 0 {
                break;
            }
            let take = free.min(need);
            alloc.add(h, r, take);
            need -= take;
        }
        (need == 0).then_some(alloc)
    }
}

impl Scheduler for Gavel {
    fn name(&self) -> &'static str {
        "gavel"
    }

    /// Completion: drop the job's per-type service counters — priorities
    /// only ever consult live jobs, and on long traces the map would
    /// otherwise grow with every job ever admitted.
    fn job_completed(&mut self, job: JobId) {
        self.rounds_received.retain(|&(id, _), _| id != job);
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        let jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        if jobs.is_empty() {
            return RoundPlan::new();
        }
        let gpu_types = ctx.cluster.gpu_types();
        let capacity: BTreeMap<GpuType, usize> = gpu_types
            .iter()
            .map(|&r| (r, ctx.cluster.capacity_of(r)))
            .collect();
        let y = Self::compute_y(&jobs, &gpu_types, &capacity);

        // Priority list: (job, type) pairs by Y / rounds_received.
        let mut prios: Vec<(f64, JobId, GpuType)> = Vec::new();
        for job in &jobs {
            for &r in &gpu_types {
                let rr = self
                    .rounds_received
                    .get(&(job.id, r))
                    .copied()
                    .unwrap_or(0.0);
                let yv = y.get(&(job.id, r)).copied().unwrap_or(0.0);
                if yv > 0.0 {
                    prios.push((yv / (1.0 + rr), job.id, r));
                }
            }
        }
        // total_cmp: a NaN priority (e.g. a NaN throughput row leaking
        // into Y) must not panic the round; NaN sorts first and simply
        // fails to place.
        prios.sort_by(|a, b| b.0.total_cmp(&a.0));

        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();
        let mut placed: BTreeMap<JobId, bool> = BTreeMap::new();
        for (_, id, r) in prios {
            if placed.contains_key(&id) {
                continue;
            }
            let job = ctx.queue.get(id).unwrap();
            if job.throughput_on(r) <= 0.0 {
                continue;
            }
            if let Some(alloc) =
                Self::place_single_type(&state, job.gpus_requested.max(1), r)
            {
                for a in alloc.assignments(id) {
                    state.allocate(a);
                }
                plan.insert(id, alloc);
                placed.insert(id, true);
            }
        }
        for id in plan.scheduled_jobs() {
            for g in plan.get(id).unwrap().gpu_types() {
                *self.rounds_received.entry((id, g)).or_insert(0.0) += 1.0;
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    fn mk_job(id: u64, w: usize) -> Job {
        let mut j = Job::new(id, DlModel::ResNet18, 0.0, w, 10, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        j
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            delta: None,
            cluster,
        }
    }

    #[test]
    fn cannot_mix_types_for_one_job() {
        // The paper's §I example: job wants 3 GPUs; cluster has 2 V100 +
        // 3 P100 + 1 K80. Gavel must place all 3 on P100 (the only type
        // with >= 3), never mixing.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 3)).unwrap();
        let active = vec![JobId(1)];
        let mut g = Gavel::new();
        let plan = g.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(1)).expect("P100 pool fits it");
        assert_eq!(alloc.gpu_types().len(), 1, "single type only");
        assert_eq!(alloc.gpu_types()[0], GpuType::P100);
    }

    #[test]
    fn job_waits_when_no_single_type_fits() {
        // 4-GPU job: no type has 4 free -> must wait (Hadar would run it).
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 4)).unwrap();
        let active = vec![JobId(1)];
        let mut g = Gavel::new();
        let plan = g.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none());
    }

    #[test]
    fn rounds_received_rotates_service() {
        // Two jobs compete for the only V100 pair; after J1 is served its
        // priority drops and J2 gets the fast type.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 2)).unwrap();
        queue.admit(mk_job(2, 2)).unwrap();
        let active = vec![JobId(1), JobId(2)];
        let mut g = Gavel::new();
        let p1 = g.schedule(&ctx(&queue, &active, &cluster));
        let first_v100: Vec<JobId> = p1
            .allocations
            .iter()
            .filter(|(_, a)| a.gpu_types().contains(&GpuType::V100))
            .map(|(&id, _)| id)
            .collect();
        assert_eq!(first_v100.len(), 1);
        let p2 = g.schedule(&ctx(&queue, &active, &cluster));
        let second_v100: Vec<JobId> = p2
            .allocations
            .iter()
            .filter(|(_, a)| a.gpu_types().contains(&GpuType::V100))
            .map(|(&id, _)| id)
            .collect();
        assert_eq!(second_v100.len(), 1);
        assert_ne!(first_v100[0], second_v100[0], "service rotates");
    }

    #[test]
    fn job_completed_drops_service_history() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 2)).unwrap();
        queue.admit(mk_job(2, 2)).unwrap();
        let active = vec![JobId(1), JobId(2)];
        let mut g = Gavel::new();
        let _ = g.schedule(&ctx(&queue, &active, &cluster));
        assert!(g.rounds_received.keys().any(|&(id, _)| id == JobId(1)));
        g.job_completed(JobId(1));
        assert!(!g.rounds_received.keys().any(|&(id, _)| id == JobId(1)));
        assert!(g.rounds_received.keys().any(|&(id, _)| id == JobId(2)));
    }

    #[test]
    fn water_filling_caps_demand() {
        let jobs_owned: Vec<Job> = (0..10).map(|i| mk_job(i, 4)).collect();
        let jobs: Vec<&Job> = jobs_owned.iter().collect();
        let types = vec![GpuType::V100, GpuType::P100, GpuType::K80];
        let cap: BTreeMap<GpuType, usize> =
            types.iter().map(|&r| (r, 4usize)).collect();
        let y = Gavel::compute_y(&jobs, &types, &cap);
        for &r in &types {
            let demand: f64 = jobs
                .iter()
                .map(|j| y[&(j.id, r)] * j.gpus_requested as f64)
                .sum();
            assert!(demand <= 4.0 + 1e-9, "{r:?} over-subscribed: {demand}");
        }
    }

    #[test]
    fn nan_throughput_job_is_skipped_without_panic() {
        // NaN-comparator regression (mirrors hadar.rs's
        // nan_and_zero_throughput_rows_are_never_scheduled): a NaN
        // throughput row produces NaN Y entries; the priority sort must
        // not panic and the malformed job simply fails to place.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        let mut bad = mk_job(1, 2);
        for g in GpuType::ALL {
            bad.set_throughput(g, f64::NAN);
        }
        queue.admit(bad).unwrap();
        queue.admit(mk_job(2, 2)).unwrap();
        let active = vec![JobId(1), JobId(2)];
        let mut g = Gavel::new();
        let plan = g.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none(), "NaN row never schedules");
        assert!(plan.get(JobId(2)).is_some(), "well-formed job still runs");
    }
}
