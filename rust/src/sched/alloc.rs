//! Round plans: what a scheduler returns for one scheduling round.
//!
//! §Perf note: [`JobAllocation`] used to wrap a `BTreeMap`, which
//! heap-allocates a tree node per pool touched — and Hadar's `FIND_ALLOC`
//! builds a fresh candidate allocation per (job, node) pair per DP node.
//! [`SlotMap`] keeps the same sorted-map semantics in an inline array
//! (spilling to a `Vec` only past [`SlotMap::INLINE`] pools, i.e. only for
//! unusually scattered gangs), so candidate generation allocates nothing
//! on the common path. See `docs/performance.md`.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::Assignment;
use crate::jobs::job::JobId;
use std::collections::BTreeMap;

/// One `(node, gpu-type) -> count` entry of a [`SlotMap`].
type SlotEntry = ((usize, GpuType), usize);

/// A small sorted map from `(node, gpu type)` to GPU count, stored inline.
///
/// Drop-in replacement for the `BTreeMap` that used to back
/// [`JobAllocation::slots`]: entries are kept sorted by key, iteration
/// order and item types match `BTreeMap::iter`/`keys`, and equality is by
/// entry content. The first [`SlotMap::INLINE`] pools live in a fixed
/// array; only allocations spanning more pools than that touch the heap.
#[derive(Clone)]
pub struct SlotMap {
    /// Live entries in `inline` when `spill` is empty.
    len: usize,
    /// Inline storage, sorted by key; entries at `len..` are padding.
    inline: [SlotEntry; SlotMap::INLINE],
    /// Overflow storage: when non-empty it holds *all* entries (sorted)
    /// and `inline`/`len` are ignored.
    spill: Vec<SlotEntry>,
}

const PAD: SlotEntry = ((0, GpuType::V100), 0);

impl SlotMap {
    /// Pools stored without heap allocation. Eight covers every gang the
    /// evaluation clusters produce (a spread 8-GPU gang on single-GPU
    /// nodes); larger gangs spill and still work.
    pub const INLINE: usize = 8;

    /// Empty map.
    pub fn new() -> Self {
        SlotMap {
            len: 0,
            inline: [PAD; SlotMap::INLINE],
            spill: Vec::new(),
        }
    }

    /// Sorted live entries.
    #[inline]
    fn entries(&self) -> &[SlotEntry] {
        if self.spill.is_empty() {
            &self.inline[..self.len]
        } else {
            &self.spill
        }
    }

    /// Add `count` to the entry for `key`, inserting it in sorted position
    /// if new.
    fn add(&mut self, key: (usize, GpuType), count: usize) {
        if !self.spill.is_empty() {
            match self.spill.binary_search_by(|e| e.0.cmp(&key)) {
                Ok(i) => self.spill[i].1 += count,
                Err(i) => self.spill.insert(i, (key, count)),
            }
            return;
        }
        let live = &self.inline[..self.len];
        match live.binary_search_by(|e| e.0.cmp(&key)) {
            Ok(i) => self.inline[i].1 += count,
            Err(i) => {
                if self.len < SlotMap::INLINE {
                    // Shift the tail right and drop the new entry in.
                    self.inline.copy_within(i..self.len, i + 1);
                    self.inline[i] = (key, count);
                    self.len += 1;
                } else {
                    // Inline storage exhausted: spill everything.
                    let mut v = self.inline.to_vec();
                    v.insert(i, (key, count));
                    self.spill = v;
                    self.len = 0;
                }
            }
        }
    }

    /// Number of pools with an entry.
    pub fn len(&self) -> usize {
        self.entries().len()
    }

    /// Whether no pool has an entry.
    pub fn is_empty(&self) -> bool {
        self.entries().is_empty()
    }

    /// Iterate entries in key order, `BTreeMap::iter`-style items.
    pub fn iter(&self) -> impl Iterator<Item = (&(usize, GpuType), &usize)> {
        self.entries().iter().map(|e| (&e.0, &e.1))
    }

    /// Iterate keys in order, `BTreeMap::keys`-style items.
    pub fn keys(&self) -> impl Iterator<Item = &(usize, GpuType)> {
        self.entries().iter().map(|e| &e.0)
    }

    /// Iterate counts in key order.
    pub fn values(&self) -> impl Iterator<Item = &usize> {
        self.entries().iter().map(|e| &e.1)
    }

    /// The count for one pool, if present.
    pub fn get(&self, key: &(usize, GpuType)) -> Option<&usize> {
        let entries = self.entries();
        entries
            .binary_search_by(|e| e.0.cmp(key))
            .ok()
            .map(|i| &entries[i].1)
    }
}

impl Default for SlotMap {
    fn default() -> Self {
        SlotMap::new()
    }
}

impl PartialEq for SlotMap {
    fn eq(&self, other: &Self) -> bool {
        self.entries() == other.entries()
    }
}

impl std::fmt::Debug for SlotMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_map()
            .entries(self.entries().iter().map(|e| (e.0, e.1)))
            .finish()
    }
}

impl<'a> IntoIterator for &'a SlotMap {
    type Item = (&'a (usize, GpuType), &'a usize);
    type IntoIter = SlotMapIter<'a>;

    fn into_iter(self) -> Self::IntoIter {
        SlotMapIter {
            entries: self.entries(),
            pos: 0,
        }
    }
}

/// Borrowing iterator over a [`SlotMap`] (the `for (&k, &v) in &map` form).
pub struct SlotMapIter<'a> {
    entries: &'a [SlotEntry],
    pos: usize,
}

impl<'a> Iterator for SlotMapIter<'a> {
    type Item = (&'a (usize, GpuType), &'a usize);

    fn next(&mut self) -> Option<Self::Item> {
        let e = self.entries.get(self.pos)?;
        self.pos += 1;
        Some((&e.0, &e.1))
    }
}

/// The allocation decided for one job in one round: its `w_{jh}^r` entries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobAllocation {
    /// (node, gpu type) -> count.
    pub slots: SlotMap,
}

impl JobAllocation {
    /// Empty allocation.
    pub fn new() -> Self {
        JobAllocation::default()
    }

    /// Add `count` GPUs of `gpu` on `node` (0 is a no-op).
    pub fn add(&mut self, node: usize, gpu: GpuType, count: usize) {
        if count > 0 {
            self.slots.add((node, gpu), count);
        }
    }

    /// Total workers `Σ w_{jh}^r` in this allocation.
    pub fn total_gpus(&self) -> usize {
        self.slots.values().sum()
    }

    /// Whether nothing was allocated.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// GPU types used (for the bottleneck rule Eq. (1b)).
    pub fn gpu_types(&self) -> Vec<GpuType> {
        let mut types: Vec<GpuType> =
            self.slots.keys().map(|&(_, g)| g).collect();
        types.sort();
        types.dedup();
        types
    }

    /// Distinct nodes used (consolidation / comm-cost check).
    pub fn nodes(&self) -> Vec<usize> {
        let mut nodes: Vec<usize> =
            self.slots.keys().map(|&(h, _)| h).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Expand into per-pool [`Assignment`]s for `job`, in key order.
    pub fn assignments(&self, job: JobId) -> Vec<Assignment> {
        self.slots
            .iter()
            .map(|(&(node, gpu), &count)| Assignment {
                job,
                node,
                gpu,
                count,
            })
            .collect()
    }
}

/// A full round plan: job -> allocation. Jobs absent from the map receive
/// nothing this round (the all-or-nothing constraint (1e) is enforced by
/// the schedulers: present jobs get exactly `W_j` GPUs).
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Job -> allocation (absent = nothing this round).
    pub allocations: BTreeMap<JobId, JobAllocation>,
}

impl RoundPlan {
    /// Empty plan.
    pub fn new() -> Self {
        RoundPlan::default()
    }

    /// Record a job's allocation (empty allocations are dropped).
    pub fn insert(&mut self, job: JobId, alloc: JobAllocation) {
        if !alloc.is_empty() {
            self.allocations.insert(job, alloc);
        }
    }

    /// The job's allocation this round, if any.
    pub fn get(&self, job: JobId) -> Option<&JobAllocation> {
        self.allocations.get(&job)
    }

    /// Jobs that received GPUs, in id order.
    pub fn scheduled_jobs(&self) -> Vec<JobId> {
        self.allocations.keys().copied().collect()
    }

    /// Total GPUs handed out this round.
    pub fn total_gpus(&self) -> usize {
        self.allocations.values().map(|a| a.total_gpus()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_accounting() {
        let mut a = JobAllocation::new();
        a.add(0, GpuType::V100, 2);
        a.add(1, GpuType::P100, 1);
        a.add(0, GpuType::V100, 1); // accumulates
        a.add(2, GpuType::K80, 0); // ignored
        assert_eq!(a.total_gpus(), 4);
        assert_eq!(a.gpu_types(), vec![GpuType::V100, GpuType::P100]);
        assert_eq!(a.nodes(), vec![0, 1]);
        let asg = a.assignments(JobId(3));
        assert_eq!(asg.len(), 2);
        assert!(asg.iter().all(|x| x.job == JobId(3)));
    }

    #[test]
    fn plan_skips_empty_allocations() {
        let mut plan = RoundPlan::new();
        plan.insert(JobId(1), JobAllocation::new());
        assert!(plan.scheduled_jobs().is_empty());
        let mut a = JobAllocation::new();
        a.add(0, GpuType::K80, 1);
        plan.insert(JobId(2), a);
        assert_eq!(plan.scheduled_jobs(), vec![JobId(2)]);
        assert_eq!(plan.total_gpus(), 1);
    }

    #[test]
    fn slot_map_stays_sorted_and_spills() {
        let mut m = SlotMap::new();
        // Insert in reverse node order across more pools than fit inline.
        for h in (0..SlotMap::INLINE + 3).rev() {
            m.add((h, GpuType::V100), h + 1);
        }
        assert_eq!(m.len(), SlotMap::INLINE + 3);
        let keys: Vec<usize> = m.keys().map(|&(h, _)| h).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "iteration stays key-ordered after spill");
        assert_eq!(m.get(&(4, GpuType::V100)), Some(&5));
        assert_eq!(m.get(&(4, GpuType::K80)), None);
        // Accumulation still works post-spill.
        m.add((4, GpuType::V100), 10);
        assert_eq!(m.get(&(4, GpuType::V100)), Some(&15));
    }

    #[test]
    fn slot_map_matches_btreemap_semantics() {
        let mut m = SlotMap::new();
        let mut b: BTreeMap<(usize, GpuType), usize> = BTreeMap::new();
        let pairs = [
            (3, GpuType::K80, 1),
            (0, GpuType::V100, 2),
            (3, GpuType::P100, 4),
            (0, GpuType::V100, 1),
            (1, GpuType::T4, 3),
        ];
        for &(h, g, c) in &pairs {
            m.add((h, g), c);
            *b.entry((h, g)).or_insert(0) += c;
        }
        let got: Vec<_> = m.iter().map(|(&k, &v)| (k, v)).collect();
        let want: Vec<_> = b.iter().map(|(&k, &v)| (k, v)).collect();
        assert_eq!(got, want);
        assert_eq!(m.len(), b.len());
    }
}
