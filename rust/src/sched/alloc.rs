//! Round plans: what a scheduler returns for one scheduling round.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::Assignment;
use crate::jobs::job::JobId;
use std::collections::BTreeMap;

/// The allocation decided for one job in one round: its `w_{jh}^r` entries.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobAllocation {
    /// (node, gpu type) -> count.
    pub slots: BTreeMap<(usize, GpuType), usize>,
}

impl JobAllocation {
    /// Empty allocation.
    pub fn new() -> Self {
        JobAllocation::default()
    }

    /// Add `count` GPUs of `gpu` on `node` (0 is a no-op).
    pub fn add(&mut self, node: usize, gpu: GpuType, count: usize) {
        if count > 0 {
            *self.slots.entry((node, gpu)).or_insert(0) += count;
        }
    }

    /// Total workers `Σ w_{jh}^r` in this allocation.
    pub fn total_gpus(&self) -> usize {
        self.slots.values().sum()
    }

    /// Whether nothing was allocated.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// GPU types used (for the bottleneck rule Eq. (1b)).
    pub fn gpu_types(&self) -> Vec<GpuType> {
        let mut types: Vec<GpuType> =
            self.slots.keys().map(|&(_, g)| g).collect();
        types.sort();
        types.dedup();
        types
    }

    /// Distinct nodes used (consolidation / comm-cost check).
    pub fn nodes(&self) -> Vec<usize> {
        let mut nodes: Vec<usize> =
            self.slots.keys().map(|&(h, _)| h).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes
    }

    /// Expand into per-pool [`Assignment`]s for `job`.
    pub fn assignments(&self, job: JobId) -> Vec<Assignment> {
        self.slots
            .iter()
            .map(|(&(node, gpu), &count)| Assignment {
                job,
                node,
                gpu,
                count,
            })
            .collect()
    }
}

/// A full round plan: job -> allocation. Jobs absent from the map receive
/// nothing this round (the all-or-nothing constraint (1e) is enforced by
/// the schedulers: present jobs get exactly `W_j` GPUs).
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Job -> allocation (absent = nothing this round).
    pub allocations: BTreeMap<JobId, JobAllocation>,
}

impl RoundPlan {
    /// Empty plan.
    pub fn new() -> Self {
        RoundPlan::default()
    }

    /// Record a job's allocation (empty allocations are dropped).
    pub fn insert(&mut self, job: JobId, alloc: JobAllocation) {
        if !alloc.is_empty() {
            self.allocations.insert(job, alloc);
        }
    }

    /// The job's allocation this round, if any.
    pub fn get(&self, job: JobId) -> Option<&JobAllocation> {
        self.allocations.get(&job)
    }

    /// Jobs that received GPUs, in id order.
    pub fn scheduled_jobs(&self) -> Vec<JobId> {
        self.allocations.keys().copied().collect()
    }

    /// Total GPUs handed out this round.
    pub fn total_gpus(&self) -> usize {
        self.allocations.values().map(|a| a.total_gpus()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_accounting() {
        let mut a = JobAllocation::new();
        a.add(0, GpuType::V100, 2);
        a.add(1, GpuType::P100, 1);
        a.add(0, GpuType::V100, 1); // accumulates
        a.add(2, GpuType::K80, 0); // ignored
        assert_eq!(a.total_gpus(), 4);
        assert_eq!(a.gpu_types(), vec![GpuType::V100, GpuType::P100]);
        assert_eq!(a.nodes(), vec![0, 1]);
        let asg = a.assignments(JobId(3));
        assert_eq!(asg.len(), 2);
        assert!(asg.iter().all(|x| x.job == JobId(3)));
    }

    #[test]
    fn plan_skips_empty_allocations() {
        let mut plan = RoundPlan::new();
        plan.insert(JobId(1), JobAllocation::new());
        assert!(plan.scheduled_jobs().is_empty());
        let mut a = JobAllocation::new();
        a.add(0, GpuType::K80, 1);
        plan.insert(JobId(2), a);
        assert_eq!(plan.scheduled_jobs(), vec![JobId(2)]);
        assert_eq!(plan.total_gpus(), 1);
    }
}
