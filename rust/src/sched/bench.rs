//! Scheduler hot-path microbench: the zero-clone solver
//! ([`crate::sched::hadar`]) timed against the frozen pre-optimisation
//! baseline ([`crate::sched::reference`]), on both solve paths (exact DP
//! at queue ≤ `dp_job_cap`, payoff-density greedy at 100-1000 jobs) and
//! two clusters (`sim60`, `synthetic256`) — plus two **fork paths**:
//!
//! * `fork_*`: the flat-table HadarE whole-node planner against the
//!   frozen [`crate::sched::reference::RefHadarE`] on a 60-node
//!   *single-GPU* cluster (the equivalence domain, so `plans_equal` stays
//!   meaningful; large copy-count rounds are exactly where the old
//!   per-candidate `BTreeMap` probes dominated);
//! * `fork_shared_*`: the partial-node (per-pool) planner against the
//!   whole-node planner on the two-pool `big:20x4` big-node cluster —
//!   here the plans *intentionally* differ (sharing big nodes is the
//!   point), so the row's `plans_equal` bit instead records the
//!   partial-node occupancy invariant: the shared plan books every GPU
//!   and at least one node carries two parents.
//!
//! …and two **streaming-scale paths** on the `scaled:64x8` multi-GPU
//! preset (192 nodes / 1536 GPUs, up to 100k jobs):
//!
//! * `warm_*`: the warm-start planner ([`HadarE::plan_round_with`] with
//!   a populated row cache and the previous round's bindings) against
//!   cold full replanning ([`HadarE::plan_round_cold`]) on the identical
//!   round — the plans must be identical, and the speedup is the
//!   sublinear-decision-time claim (the acceptance floor is ≥2x at 100k
//!   jobs; in practice the cache prunes the matrix from O(jobs) rows to
//!   O(slots));
//! * `shard_*`: cold replanning at 1 worker vs the resolved multi-worker
//!   count — plans must be **bit-identical** (the determinism
//!   guarantee), while the speedup is machine-dependent and therefore
//!   never gates against the baseline.
//!
//! …and the **Hadar streaming family** on the same preset — the
//! task-level solver's counterpart to the rows above, measuring the
//! speculative-parallel-scoring greedy of [`crate::sched::hadar`]:
//!
//! * `hadar_stream_*`: one greedy round, the frozen serial
//!   [`RefHadar`] vs the index-accelerated speculative solver — plans
//!   must be identical (`plans-equal`, so the row gates; ≥2x at 100k
//!   jobs is the acceptance floor);
//! * `hadar_shard_*`: the same round at `plan_threads` 1 vs the
//!   resolved multi-worker count — `plans-equal-parallel`, bit-identical
//!   plans required but the thread speedup never gates;
//! * `hadar_incr_*`: a steady-state round 1 — cold full replanning by a
//!   fresh non-incremental solver vs the incremental solver carrying
//!   round 0's allocations over (with the full-cluster dispatch skip) —
//!   `plans-carried`: the carried plan must equal round 0's plan
//!   bit-for-bit, and the cold-vs-incremental speedup gates.
//!
//! …and the **delta rows** (`delta_*`) at the same streaming job
//! counts: the queue layer's per-round boundary cost, full O(jobs)
//! scans vs the indexed delta pipeline the engines run after the
//! round-delta refactor — see [`run_delta_cases`].
//!
//! The serial reference is skipped above 200k jobs (its comparator
//! sorts dominate and tell us nothing new), so a 1M-job `--stream-jobs`
//! run emits only the `hadar_shard_*`/`hadar_incr_*`/`delta_*` rows and
//! stays minutes-scale.
//!
//! Shared by the `hadar bench` CLI subcommand (which emits
//! `BENCH_sched.json`, the artifact the perf trajectory tracks — see
//! `docs/performance.md`) and `benches/l3_sched_micro.rs`. Every
//! measurement also cross-checks its row invariant — a broken
//! equivalence (or occupancy) shows up in the artifact, not just in the
//! property tests.

// lint: allow-file(wall-clock, reason = "a microbench measures wall time by definition; every timing here lands in BENCH_sched.json, never in a plan")

use crate::cluster::spec::ClusterSpec;
use crate::forking::forker::ForkIds;
use crate::forking::tracker::JobTracker;
use crate::jobs::queue::JobQueue;
use crate::sched::hadar::Hadar;
use crate::sched::hadare::{GangConfig, HadarE};
use crate::sched::reference::{RefHadar, RefHadarE};
use crate::sched::{RoundCtx, RoundPlan, Scheduler};
use crate::trace::philly::{generate, TraceConfig};
use crate::trace::workload::materialize;
use crate::util::json::Json;
use std::time::Instant;

/// One measured comparison: a (cluster, queue size) point on one solve
/// path, with the reference and optimised per-decision latencies.
#[derive(Clone, Debug)]
pub struct CaseResult {
    /// Case label, e.g. `dp_sim60_12jobs`.
    pub name: String,
    /// `"dp"` or `"greedy"` — which solve path the queue size triggers.
    pub path: &'static str,
    /// Cluster preset name.
    pub cluster: String,
    /// Queued jobs in the decision.
    pub jobs: usize,
    /// Reference (pre-optimisation / whole-node) decision latency,
    /// best-of-N ms.
    pub ref_ms: f64,
    /// Optimised decision latency, best-of-N ms.
    pub opt_ms: f64,
    /// `ref_ms / opt_ms`.
    pub speedup: f64,
    /// Which correctness invariant [`CaseResult::plans_equal`] reports:
    /// `"plans-equal"` (identical [`RoundPlan`]s from both solvers, the
    /// `dp`/`greedy`/`fork`/`warm`/`hadar-stream` rows),
    /// `"plans-carried"` (`hadar-incr` rows: the incremental round-1
    /// plan equals round 0's plan bit-for-bit), `"occupancy"` (the
    /// partial-node invariant — every GPU booked, at least one node
    /// shared by two parents — on `fork-shared` rows, where whole-node
    /// and per-pool plans intentionally differ), or
    /// `"plans-equal-parallel"` (`shard`/`hadar-shard` rows:
    /// bit-identical plans at 1 vs N workers; the invariant still fails
    /// the CLI on divergence, but the speedup is machine-dependent so
    /// the row never gates against the committed baseline). The
    /// baseline gate acts on `plans-equal` and `plans-carried` rows
    /// only. Keeps `BENCH_sched.json` self-describing for
    /// artifact-diffing tools.
    pub check: &'static str,
    /// Whether the row's invariant (see [`CaseResult::check`]) held.
    pub plans_equal: bool,
}

/// Queue sizes per path. `quick` is the CI smoke profile: one point per
/// (path, cluster), a couple of iterations — seconds, not minutes.
fn case_grid(quick: bool) -> Vec<(&'static str, ClusterSpec, usize)> {
    let mut grid = Vec::new();
    let dp_sizes: &[usize] = if quick { &[8] } else { &[8, 12] };
    let greedy_sizes: &[usize] =
        if quick { &[100] } else { &[100, 400, 1000] };
    let clusters: [fn() -> ClusterSpec; 2] =
        [ClusterSpec::sim60, ClusterSpec::synthetic256];
    for mk in clusters {
        for &n in dp_sizes {
            grid.push(("dp", mk(), n));
        }
        for &n in greedy_sizes {
            grid.push(("greedy", mk(), n));
        }
    }
    grid
}

/// Deterministic queue for one case: a Philly-flavoured trace, everything
/// arrived at t=0 so the decision sees the whole queue.
fn case_queue(cluster: &ClusterSpec, n_jobs: usize) -> JobQueue {
    let trace = generate(&TraceConfig {
        n_jobs,
        seed: 3,
        all_at_start: true,
        max_gpus: 4,
        ..Default::default()
    });
    let mut queue = JobQueue::new();
    for j in materialize(&trace, cluster, 3) {
        queue.admit(j).unwrap();
    }
    queue
}

/// Best-of-`iters` wall time of one scheduling decision, fresh scheduler
/// per iteration (cold per-job caches — the honest per-round cost).
/// Returns (best ms, the last plan).
fn time_decision(
    iters: usize,
    mut mk: impl FnMut() -> Box<dyn Scheduler>,
    ctx: &RoundCtx,
) -> (f64, RoundPlan) {
    let mut best = f64::INFINITY;
    let mut plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let mut s = mk();
        let t0 = Instant::now();
        plan = s.schedule(ctx);
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    (best, plan)
}

/// 60 single-GPU nodes (20 per sim60 type) — the fork-path bench
/// cluster. Single-GPU so the frozen `RefHadarE` and the gang planner
/// must produce identical plans, keeping `plans_equal` a live check.
fn fork_cluster() -> ClusterSpec {
    let mut c = ClusterSpec::scaled(20, 1);
    c.name = "sgl60".into();
    c
}

/// Tracker over the case queue's jobs, each forked `copies` ways. The
/// id-space stride adapts to the queue (streaming cases go to 100k
/// jobs) but never shrinks below the historical 1024, so the copy ids —
/// and therefore the plans — of the existing `fork_*` rows are
/// unchanged.
fn fork_tracker(queue: &JobQueue, copies: u64) -> JobTracker {
    let max_id = queue.iter().map(|j| j.id.0).max().unwrap_or(0);
    let ids = ForkIds {
        max_job_count: (max_id + 1).max(1024),
    };
    let mut tracker = JobTracker::new(ids);
    for j in queue.iter() {
        tracker.register(
            j.id,
            j.total_iters(),
            &(1..=copies).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
        );
    }
    tracker
}

/// Which planner a fork-path measurement times.
#[derive(Clone, Copy)]
enum ForkPlanner {
    /// The frozen pre-gang `RefHadarE`.
    Reference,
    /// The live planner in whole-node compatibility mode.
    WholeNode,
    /// The live planner with partial-node (per-pool) gangs.
    Shared,
}

/// Best-of-`iters` wall time of one HadarE `plan_round`, fresh planner
/// per iteration. Returns (best ms, the last plan).
fn time_hadare_decision(
    iters: usize,
    copies: u64,
    planner: ForkPlanner,
    ctx: &RoundCtx,
    tracker: &JobTracker,
) -> (f64, RoundPlan) {
    let mut best = f64::INFINITY;
    let mut plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        plan = match planner {
            ForkPlanner::Reference => {
                RefHadarE::new(copies).plan_round(ctx, tracker)
            }
            ForkPlanner::WholeNode => {
                HadarE::new(copies).plan_round(ctx, tracker)
            }
            ForkPlanner::Shared => {
                HadarE::with_gang(copies, GangConfig::shared())
                    .plan_round(ctx, tracker)
            }
        };
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    (best, plan)
}

/// The `fork-shared` row invariant: the per-pool plan books every GPU of
/// `cluster` and at least one node carries copies of two different
/// parents.
fn shared_plan_invariant(plan: &RoundPlan, cluster: &ClusterSpec,
                         tracker: &JobTracker) -> bool {
    if plan.total_gpus() != cluster.total_gpus() {
        return false;
    }
    let mut parents_by_node: std::collections::BTreeMap<
        usize,
        std::collections::BTreeSet<crate::jobs::job::JobId>,
    > = std::collections::BTreeMap::new();
    for (&copy, alloc) in &plan.allocations {
        for node in alloc.nodes() {
            parents_by_node
                .entry(node)
                .or_default()
                .insert(tracker.resolve(copy));
        }
    }
    parents_by_node.values().any(|ps| ps.len() >= 2)
}

/// The streaming-scale bench cluster: `scaled:64x8` — 192 nodes (64 per
/// sim60 type), 8 GPUs each, 1536 GPUs. Single-pool nodes, so whole-node
/// and per-pool modes coincide and `plans_equal` stays a live check.
fn scaled_cluster() -> ClusterSpec {
    let mut c = ClusterSpec::scaled(64, 8);
    c.name = "scaled64x8".into();
    c
}

/// The `warm_*`/`shard_*` streaming-scale rows at one job count. Shared
/// setup — one copy per parent (the streaming regime: jobs ≫ slots), a
/// round-0 plan that populates the warm planner's row cache and yields
/// the carry-over bindings, then half a slot of reported progress so
/// every parent stays live with a shifted priority order — and two
/// measurements of the same steady-state round 1:
///
/// * `warm`: [`HadarE::plan_round_with`] (cached rows) vs
///   [`HadarE::plan_round_cold`] (full matrix) — `check: plans-equal`,
///   so the row gates against the committed baseline;
/// * `shard`: `plan_round_cold` at 1 worker vs the resolved multi-worker
///   count — `check: plans-equal-parallel`; the plans must still match
///   bit-for-bit (the CLI fails otherwise) but the thread speedup never
///   gates.
fn run_stream_cases(iters: usize, n_jobs: usize,
                    out: &mut Vec<CaseResult>) {
    use crate::sched::hadare::{alloc_throughput, PrevRound};
    use crate::sched::resolve_plan_threads;
    let cluster = scaled_cluster();
    let copies = 1u64;
    let queue = case_queue(&cluster, n_jobs);
    let mut tracker = fork_tracker(&queue, copies);
    let active = queue.active_at(0.0);
    let slot = 360.0;
    let ctx0 = RoundCtx {
        round: 0,
        now: 0.0,
        slot_secs: slot,
        horizon: 1e7,
        queue: &queue,
        active: &active,
        delta: None,
        cluster: &cluster,
    };
    let mut warm = HadarE::new(copies);
    let p0 = warm.plan_round(&ctx0, &tracker);
    let prev = PrevRound::from_plan(&p0, &tracker, 10.0);
    for (&copy, alloc) in &p0.allocations {
        let parent = tracker.resolve(copy);
        if let Some(job) = queue.get(parent) {
            let x = alloc_throughput(job, alloc, &warm.gang);
            tracker.report_steps(copy, x * slot * 0.5);
        }
    }
    let ctx1 = RoundCtx {
        round: 1,
        now: slot,
        slot_secs: slot,
        horizon: 1e7,
        queue: &queue,
        active: &active,
        delta: None,
        cluster: &cluster,
    };

    // warm row: cold replanning (reference) vs the warm-start path.
    let cold = HadarE::new(copies);
    let mut ref_ms = f64::INFINITY;
    let mut ref_plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        ref_plan = cold.plan_round_cold(&ctx1, &tracker, &prev);
        ref_ms = ref_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut opt_ms = f64::INFINITY;
    let mut opt_plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        opt_plan = warm.plan_round_with(&ctx1, &tracker, &prev);
        opt_ms = opt_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    out.push(CaseResult {
        name: format!("warm_{}_{n_jobs}jobs", cluster.name),
        path: "warm",
        cluster: cluster.name.clone(),
        jobs: n_jobs,
        ref_ms,
        opt_ms,
        speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
        check: "plans-equal",
        plans_equal: ref_plan.allocations == opt_plan.allocations,
    });

    // shard row: the same cold decision, 1 worker vs multi-worker.
    let single = HadarE::with_gang(copies, GangConfig {
        plan_threads: 1,
        ..GangConfig::default()
    });
    let multi = HadarE::with_gang(copies, GangConfig {
        plan_threads: resolve_plan_threads(0).max(2),
        ..GangConfig::default()
    });
    let mut s_ms = f64::INFINITY;
    let mut s_plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        s_plan = single.plan_round_cold(&ctx1, &tracker, &prev);
        s_ms = s_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut m_ms = f64::INFINITY;
    let mut m_plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        m_plan = multi.plan_round_cold(&ctx1, &tracker, &prev);
        m_ms = m_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    out.push(CaseResult {
        name: format!("shard_{}_{n_jobs}jobs", cluster.name),
        path: "shard",
        cluster: cluster.name.clone(),
        jobs: n_jobs,
        ref_ms: s_ms,
        opt_ms: m_ms,
        speedup: if m_ms > 0.0 { s_ms / m_ms } else { 0.0 },
        check: "plans-equal-parallel",
        plans_equal: s_plan.allocations == m_plan.allocations,
    });
}

/// The `delta_*` rows: the queue layer's steady-state round-boundary
/// cost — the pre-refactor full path (an O(jobs) [`JobQueue::active_at`]
/// status scan every round) against the indexed delta path the engines
/// now run ([`JobQueue::poll_round`] + [`JobQueue::waiting`] +
/// [`JobQueue::next_arrival_after`], O(churn + active)). No solver runs:
/// the row isolates what the delta-pipeline refactor changed, so the
/// speedup is the O(jobs)-vs-O(delta) claim itself (the acceptance floor
/// is ≥2x at 100k jobs). Both paths retire the same jobs each round and
/// must report identical waiting sets and next-arrival probes
/// (`check: plans-equal`, so the row gates against the committed
/// baseline).
///
/// The stream is sized like the streaming rows: ~512 arrivals per round
/// over `jobs/512` rounds, and each round retires everything beyond the
/// newest 512 waiting jobs — a mid-stream steady state where the full
/// scan touches every job ever admitted while the delta path touches
/// only the round's churn.
fn run_delta_cases(iters: usize, n_jobs: usize, out: &mut Vec<CaseResult>) {
    use crate::jobs::job::{Job, JobId};
    use crate::jobs::model::DlModel;
    let cluster = scaled_cluster();
    let slot = 360.0;
    // ~512 arrivals per round; small counts still spread over 8 rounds.
    let span_rounds = (n_jobs / 512).max(8);
    let keep = 512usize;
    let mut base = JobQueue::new();
    for i in 0..n_jobs {
        let arrival =
            slot * span_rounds as f64 * (i as f64 / n_jobs as f64);
        base.admit(Job::new(i as u64, DlModel::Lstm, arrival, 1, 1, 100))
            .unwrap();
    }
    // Warm to mid-stream steady state with the same per-round lifecycle
    // the timed window applies.
    let warm_rounds = span_rounds / 2;
    for r in 0..warm_rounds {
        let now = r as f64 * slot;
        base.poll_round(now);
        let act = base.waiting();
        for &id in act.iter().take(act.len().saturating_sub(keep)) {
            base.complete(id, now);
        }
    }
    let window = 32usize;
    let start = warm_rounds;
    // One timed pass over the steady-state window: per round, read the
    // waiting set and the next arrival, then retire everything beyond
    // the newest `keep` jobs. The retire cost is identical on both
    // sides; only the boundary reads differ.
    let measure = |use_index: bool| {
        let mut best = f64::INFINITY;
        let mut rounds: Vec<(Vec<JobId>, Option<f64>)> = Vec::new();
        for _ in 0..iters.max(1) {
            let mut q = base.clone();
            rounds.clear();
            let t0 = Instant::now();
            for r in 0..window {
                let now = (start + r) as f64 * slot;
                let act = if use_index {
                    q.poll_round(now);
                    q.waiting()
                } else {
                    q.active_at(now)
                };
                let next = q.next_arrival_after(now);
                for &id in
                    act.iter().take(act.len().saturating_sub(keep))
                {
                    q.complete(id, now);
                }
                rounds.push((act, next));
            }
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        (best, rounds)
    };
    let (ref_ms, ref_rounds) = measure(false);
    let (opt_ms, opt_rounds) = measure(true);
    out.push(CaseResult {
        name: format!("delta_{}_{n_jobs}jobs", cluster.name),
        path: "delta",
        cluster: cluster.name.clone(),
        jobs: n_jobs,
        ref_ms,
        opt_ms,
        speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
        check: "plans-equal",
        plans_equal: ref_rounds == opt_rounds,
    });
}

/// Above this queue size the `hadar_stream_*` serial-reference row is
/// skipped: `RefHadar`'s per-comparison `t_min` sorts dominate its wall
/// time there, so the ratio stops measuring the solver. The optimised
/// rows (`hadar_shard_*`, `hadar_incr_*`) still run — that is what
/// keeps a 1M-job `--stream-jobs` invocation minutes-scale.
const HADAR_REF_JOB_CAP: usize = 200_000;

/// The `hadar_stream_*`/`hadar_shard_*`/`hadar_incr_*` rows at one job
/// count (module docs): the task-level solver on one `scaled:64x8`
/// greedy round against (a) the frozen serial [`RefHadar`], (b) itself
/// at 1 worker, and (c) cold full replanning of a steady-state round
/// that incremental mode carries over entirely.
fn run_hadar_stream_cases(iters: usize, n_jobs: usize,
                          out: &mut Vec<CaseResult>) {
    use crate::sched::hadar::HadarConfig;
    use crate::sched::resolve_plan_threads;
    let cluster = scaled_cluster();
    let queue = case_queue(&cluster, n_jobs);
    let active = queue.active_at(0.0);
    let slot = 360.0;
    let ctx0 = RoundCtx {
        round: 0,
        now: 0.0,
        slot_secs: slot,
        horizon: 1e7,
        queue: &queue,
        active: &active,
        delta: None,
        cluster: &cluster,
    };

    // hadar_stream row: frozen serial reference vs the speculative
    // solver on the identical round-0 decision.
    if n_jobs <= HADAR_REF_JOB_CAP {
        let (ref_ms, ref_plan) =
            time_decision(iters, || Box::new(RefHadar::new()), &ctx0);
        let (opt_ms, opt_plan) =
            time_decision(iters, || Box::new(Hadar::new()), &ctx0);
        out.push(CaseResult {
            name: format!("hadar_stream_{}_{n_jobs}jobs", cluster.name),
            path: "hadar-stream",
            cluster: cluster.name.clone(),
            jobs: n_jobs,
            ref_ms,
            opt_ms,
            speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
            check: "plans-equal",
            plans_equal: ref_plan.allocations == opt_plan.allocations,
        });
    }

    // hadar_shard row: the same decision at 1 worker vs the resolved
    // multi-worker count — the determinism guarantee under load.
    let (s_ms, s_plan) = time_decision(
        iters,
        || {
            Box::new(Hadar::with_config(HadarConfig {
                plan_threads: 1,
                ..Default::default()
            }))
        },
        &ctx0,
    );
    let threads = resolve_plan_threads(0).max(2);
    let (m_ms, m_plan) = time_decision(
        iters,
        || {
            Box::new(Hadar::with_config(HadarConfig {
                plan_threads: threads,
                ..Default::default()
            }))
        },
        &ctx0,
    );
    out.push(CaseResult {
        name: format!("hadar_shard_{}_{n_jobs}jobs", cluster.name),
        path: "hadar-shard",
        cluster: cluster.name.clone(),
        jobs: n_jobs,
        ref_ms: s_ms,
        opt_ms: m_ms,
        speedup: if m_ms > 0.0 { s_ms / m_ms } else { 0.0 },
        check: "plans-equal-parallel",
        plans_equal: s_plan.allocations == m_plan.allocations,
    });

    // hadar_incr row: steady-state round 1. The incremental solver
    // carries round 0's allocations over (full-state dispatch skip);
    // the reference is a fresh non-incremental solver replanning the
    // whole queue at the same round-1 context. The invariant is that
    // the carried plan IS round 0's plan, bit for bit.
    let mut incr = Hadar::with_config(HadarConfig {
        incremental: true,
        ..Default::default()
    });
    let p0 = incr.schedule(&ctx0);
    let ctx1 = RoundCtx {
        round: 1,
        now: slot,
        slot_secs: slot,
        horizon: 1e7,
        queue: &queue,
        active: &active,
        delta: None,
        cluster: &cluster,
    };
    let (cold_ms, _) = time_decision(iters, || Box::new(Hadar::new()), &ctx1);
    let mut incr_ms = f64::INFINITY;
    let mut incr_plan = RoundPlan::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        incr_plan = incr.schedule(&ctx1);
        incr_ms = incr_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    out.push(CaseResult {
        name: format!("hadar_incr_{}_{n_jobs}jobs", cluster.name),
        path: "hadar-incr",
        cluster: cluster.name.clone(),
        jobs: n_jobs,
        ref_ms: cold_ms,
        opt_ms: incr_ms,
        speedup: if incr_ms > 0.0 { cold_ms / incr_ms } else { 0.0 },
        check: "plans-carried",
        plans_equal: !p0.allocations.is_empty()
            && incr_plan.allocations == p0.allocations,
    });
}

/// Run the full comparison suite with the profile's default
/// streaming-scale job counts: one small point (800 jobs) in `quick`
/// mode — the in-tree unit test runs this in debug builds — and
/// 20k/100k in the full profile. CI's bench smoke overrides the sizes
/// to the 100k acceptance point via `hadar bench --warm-jobs` /
/// `--stream-jobs`.
pub fn run_suite(quick: bool) -> Vec<CaseResult> {
    run_suite_with(quick, None, None)
}

/// [`run_suite`] with explicit streaming-scale job counts:
/// `hadare_stream_jobs` drives the `warm_*`/`shard_*` rows and
/// `hadar_stream_jobs` the `hadar_stream_*`/`hadar_shard_*`/
/// `hadar_incr_*` rows. `None` means the profile default (800 quick,
/// 20k/100k full); `Some(&[])` skips that family.
pub fn run_suite_with(quick: bool, hadare_stream_jobs: Option<&[usize]>,
                      hadar_stream_jobs: Option<&[usize]>)
                      -> Vec<CaseResult> {
    let default_stream: &[usize] =
        if quick { &[800] } else { &[20_000, 100_000] };
    let hadare_jobs = hadare_stream_jobs.unwrap_or(default_stream);
    let hadar_jobs = hadar_stream_jobs.unwrap_or(default_stream);
    let iters = if quick { 3 } else { 7 };
    let mut out = Vec::new();
    for (path, cluster, n_jobs) in case_grid(quick) {
        let queue = case_queue(&cluster, n_jobs);
        let active = queue.active_at(0.0);
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let (ref_ms, ref_plan) =
            time_decision(iters, || Box::new(RefHadar::new()), &ctx);
        let (opt_ms, opt_plan) =
            time_decision(iters, || Box::new(Hadar::new()), &ctx);
        out.push(CaseResult {
            name: format!("{path}_{}_{n_jobs}jobs", cluster.name),
            path,
            cluster: cluster.name.clone(),
            jobs: n_jobs,
            ref_ms,
            opt_ms,
            speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
            check: "plans-equal",
            plans_equal: ref_plan.allocations == opt_plan.allocations,
        });
    }

    // Fork path: HadarE whole-node planning, flat tables vs the frozen
    // BTreeMap reference, at full copy budget (= node count).
    let fork_sizes: &[usize] = if quick { &[16] } else { &[16, 64] };
    for &n_jobs in fork_sizes {
        let cluster = fork_cluster();
        let copies = cluster.nodes.len() as u64;
        let queue = case_queue(&cluster, n_jobs);
        let tracker = fork_tracker(&queue, copies);
        let active = queue.active_at(0.0);
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let (ref_ms, ref_plan) = time_hadare_decision(
            iters, copies, ForkPlanner::Reference, &ctx, &tracker);
        let (opt_ms, opt_plan) = time_hadare_decision(
            iters, copies, ForkPlanner::WholeNode, &ctx, &tracker);
        out.push(CaseResult {
            name: format!("fork_{}_{n_jobs}jobs", cluster.name),
            path: "fork",
            cluster: cluster.name.clone(),
            jobs: n_jobs,
            ref_ms,
            opt_ms,
            speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
            check: "plans-equal",
            plans_equal: ref_plan.allocations == opt_plan.allocations,
        });
    }

    // Fork-shared path: partial-node (per-pool) planning vs whole-node
    // planning on the two-pool big-node cluster. `ref` times the
    // whole-node mode, `opt` the per-pool mode (which plans 2x the slots
    // on this cluster); the row's boolean is the occupancy invariant, not
    // plan equality — see the module docs.
    let shared_sizes: &[usize] = if quick { &[16] } else { &[16, 64] };
    for &n_jobs in shared_sizes {
        let cluster = ClusterSpec::big(20, 4);
        let copies = cluster.nodes.len() as u64;
        let queue = case_queue(&cluster, n_jobs);
        let tracker = fork_tracker(&queue, copies);
        let active = queue.active_at(0.0);
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 1e7,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let (ref_ms, _) = time_hadare_decision(
            iters, copies, ForkPlanner::WholeNode, &ctx, &tracker);
        let (opt_ms, opt_plan) = time_hadare_decision(
            iters, copies, ForkPlanner::Shared, &ctx, &tracker);
        out.push(CaseResult {
            name: format!("fork_shared_{}_{n_jobs}jobs", cluster.name),
            path: "fork-shared",
            cluster: cluster.name.clone(),
            jobs: n_jobs,
            ref_ms,
            opt_ms,
            speedup: if opt_ms > 0.0 { ref_ms / opt_ms } else { 0.0 },
            check: "occupancy",
            plans_equal: shared_plan_invariant(&opt_plan, &cluster,
                                               &tracker),
        });
    }

    // Streaming-scale paths: warm-start vs cold replanning, and 1-vs-N
    // worker sharding, on the scaled:64x8 preset. One iteration in quick
    // mode — at 100k jobs even the cold reference plan is the dominant
    // cost, and the row invariants (plan equality) are per-iteration.
    let stream_iters = if quick { 1 } else { 2 };
    for &n_jobs in hadare_jobs {
        run_stream_cases(stream_iters, n_jobs, &mut out);
    }

    // Hadar streaming family: the task-level solver's serial-vs-
    // speculative, 1-vs-N-worker, and cold-vs-incremental rows on the
    // same preset.
    for &n_jobs in hadar_jobs {
        run_hadar_stream_cases(stream_iters, n_jobs, &mut out);
    }

    // Delta rows: the queue layer's round-boundary cost (full scan vs
    // the indexed delta pipeline) at the same streaming job counts.
    for &n_jobs in hadar_jobs {
        run_delta_cases(stream_iters, n_jobs, &mut out);
    }
    out
}

/// Human-readable comparison table.
pub fn render(results: &[CaseResult]) -> String {
    let mut out = String::from(
        "case                            path    jobs    ref ms    opt ms  \
         speedup  check\n",
    );
    for r in results {
        out.push_str(&format!(
            "{:<30} {:>6} {:>7} {:>9.3} {:>9.3} {:>7.2}x  {}\n",
            r.name,
            r.path,
            r.jobs,
            r.ref_ms,
            r.opt_ms,
            r.speedup,
            if r.plans_equal { "ok" } else { "BROKEN" },
        ));
    }
    out
}

/// The `BENCH_sched.json` document: suite metadata + one object per case.
pub fn to_json(results: &[CaseResult], quick: bool) -> Json {
    let cases: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj()
                .set("name", r.name.as_str())
                .set("path", r.path)
                .set("cluster", r.cluster.as_str())
                .set("jobs", r.jobs)
                .set("ref_ms", r.ref_ms)
                .set("opt_ms", r.opt_ms)
                .set("speedup", r.speedup)
                .set("check", r.check)
                .set("plans_equal", r.plans_equal)
        })
        .collect();
    Json::obj()
        .set("bench", "sched")
        .set("quick", quick)
        .set("cases", Json::Arr(cases))
}

/// One row of a current-vs-committed-baseline comparison (the CI perf
/// regression gate over `BENCH_baseline.json`).
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineDiff {
    /// Case name shared by both runs.
    pub name: String,
    /// Speedup recorded in the committed baseline.
    pub base_speedup: f64,
    /// Speedup measured by the current run.
    pub cur_speedup: f64,
    /// `cur_speedup / base_speedup`.
    pub ratio: f64,
    /// Whether the current speedup fell below the tolerance band
    /// (`cur < base * (1 - tolerance)`).
    pub regressed: bool,
}

/// Whether rows with this check label gate against the committed
/// baseline. `plans-equal` and `plans-carried` compare a reference and
/// an optimised run of the *same* decision, so their ratio is a real
/// regression signal; `occupancy` rows compare two different planners
/// and `plans-equal-parallel` rows measure machine-dependent thread
/// scaling, so neither gates.
fn check_gates(check: &str) -> bool {
    check == "plans-equal" || check == "plans-carried"
}

/// Diff the current suite against a committed `BENCH_sched.json`-shaped
/// baseline document. Only rows whose `check` label gates
/// (`plans-equal` and `plans-carried`) participate. Cases present on only one side are
/// skipped (grid drift is handled by refreshing the baseline, not by
/// failing CI). `tolerance` is the allowed fractional drop, e.g. `0.20`
/// fails anything slower than 80% of baseline.
pub fn compare_to_baseline(results: &[CaseResult], baseline: &Json,
                           tolerance: f64) -> Vec<BaselineDiff> {
    let mut base: std::collections::BTreeMap<&str, f64> =
        std::collections::BTreeMap::new();
    if let Some(cases) = baseline.get("cases").as_arr() {
        for c in cases {
            if !c.get("check").as_str().map_or(false, check_gates) {
                continue;
            }
            if let (Some(name), Some(speedup)) =
                (c.get("name").as_str(), c.get("speedup").as_f64())
            {
                base.insert(name, speedup);
            }
        }
    }
    let mut out = Vec::new();
    for r in results {
        if !check_gates(r.check) {
            continue;
        }
        let Some(&base_speedup) = base.get(r.name.as_str()) else {
            continue;
        };
        if base_speedup <= 0.0 {
            continue;
        }
        out.push(BaselineDiff {
            name: r.name.clone(),
            base_speedup,
            cur_speedup: r.speedup,
            ratio: r.speedup / base_speedup,
            regressed: r.speedup < base_speedup * (1.0 - tolerance),
        });
    }
    out
}

/// Render the baseline comparison table; regressed rows say so.
pub fn render_baseline(diffs: &[BaselineDiff]) -> String {
    let mut out = String::from(
        "case                            base x     cur x    ratio  gate\n",
    );
    for d in diffs {
        out.push_str(&format!(
            "{:<30} {:>7.2} {:>9.2} {:>8.2}  {}\n",
            d.name,
            d.base_speedup,
            d.cur_speedup,
            d.ratio,
            if d.regressed { "REGRESSED" } else { "ok" },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_suite_covers_both_paths_and_agrees() {
        let results = run_suite(true);
        assert!(results.iter().any(|r| r.path == "dp"));
        assert!(results.iter().any(|r| r.path == "greedy"));
        assert!(results.iter().any(|r| r.path == "fork"),
                "hadare ref-vs-opt row present");
        assert!(results.iter().any(|r| r.path == "fork-shared"),
                "partial-node big-cluster row present");
        assert!(results.iter().any(|r| r.path == "warm"),
                "warm-start streaming row present");
        assert!(results.iter().any(|r| r.path == "shard"),
                "sharded streaming row present");
        assert!(results.iter().any(|r| r.path == "hadar-stream"),
                "hadar serial-vs-speculative row present");
        assert!(results.iter().any(|r| r.path == "hadar-shard"),
                "hadar 1-vs-N-worker row present");
        assert!(results.iter().any(|r| r.path == "hadar-incr"),
                "hadar cold-vs-incremental row present");
        assert!(results.iter().any(|r| r.path == "delta"),
                "queue delta-pipeline row present");
        for r in &results {
            let want = match r.path {
                "fork-shared" => "occupancy",
                "shard" | "hadar-shard" => "plans-equal-parallel",
                "hadar-incr" => "plans-carried",
                _ => "plans-equal",
            };
            assert_eq!(r.check, want, "{}: check label", r.name);
        }
        assert!(results.iter().any(|r| r.cluster == "synthetic256"));
        assert!(results.iter().any(|r| r.cluster == "big20x4"));
        assert!(results.iter().any(|r| r.cluster == "scaled64x8"));
        for r in &results {
            assert!(r.plans_equal, "{}: row invariant broken", r.name);
            assert!(r.ref_ms >= 0.0 && r.opt_ms >= 0.0);
        }
        let table = render(&results);
        assert!(table.contains("speedup"));
    }

    #[test]
    fn json_artifact_roundtrips() {
        let results = vec![CaseResult {
            name: "dp_sim60_8jobs".into(),
            path: "dp",
            cluster: "sim60".into(),
            jobs: 8,
            ref_ms: 1.5,
            opt_ms: 0.3,
            speedup: 5.0,
            check: "plans-equal",
            plans_equal: true,
        }];
        let text = to_json(&results, true).pretty();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("bench").as_str(), Some("sched"));
        assert_eq!(v.get("quick").as_bool(), Some(true));
        let case = v.get("cases").at(0);
        assert_eq!(case.get("jobs").as_usize(), Some(8));
        assert_eq!(case.get("check").as_str(), Some("plans-equal"));
        assert_eq!(case.get("plans_equal").as_bool(), Some(true));
        assert_eq!(case.get("speedup").as_f64(), Some(5.0));
    }

    fn case(name: &str, check: &'static str, speedup: f64) -> CaseResult {
        CaseResult {
            name: name.into(),
            path: "dp",
            cluster: "sim60".into(),
            jobs: 8,
            ref_ms: 1.0,
            opt_ms: 1.0 / speedup.max(1e-9),
            speedup,
            check,
            plans_equal: true,
        }
    }

    #[test]
    fn baseline_gate_flags_only_real_regressions() {
        let baseline = to_json(
            &[
                case("dp_sim60_8jobs", "plans-equal", 4.0),
                case("greedy_sim60_100jobs", "plans-equal", 2.0),
                case("fork_shared_big20x4_16jobs", "occupancy", 3.0),
                case("hadar_incr_scaled64x8_100000jobs", "plans-carried",
                     2.0),
                case("hadar_shard_scaled64x8_100000jobs",
                     "plans-equal-parallel", 3.0),
            ],
            true,
        );
        let current = [
            // 4.0 -> 3.5 is within the 20% band.
            case("dp_sim60_8jobs", "plans-equal", 3.5),
            // 2.0 -> 1.0 is a regression.
            case("greedy_sim60_100jobs", "plans-equal", 1.0),
            // occupancy rows never gate, however large the swing.
            case("fork_shared_big20x4_16jobs", "occupancy", 0.1),
            // unknown-to-baseline cases are skipped.
            case("dp_new_case_12jobs", "plans-equal", 0.1),
            // plans-carried rows gate like plans-equal rows.
            case("hadar_incr_scaled64x8_100000jobs", "plans-carried", 1.0),
            // thread-scaling rows never gate.
            case("hadar_shard_scaled64x8_100000jobs",
                 "plans-equal-parallel", 0.1),
        ];
        let diffs = compare_to_baseline(&current, &baseline, 0.20);
        assert_eq!(diffs.len(), 3);
        assert!(!diffs[0].regressed, "{:?}", diffs[0]);
        assert!(diffs[1].regressed, "{:?}", diffs[1]);
        assert!(diffs[2].regressed, "incr row gates: {:?}", diffs[2]);
        let table = render_baseline(&diffs);
        assert!(table.contains("REGRESSED"), "{table}");
        assert!(table.contains("ok"), "{table}");
    }
}
