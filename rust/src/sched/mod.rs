//! Schedulers: the paper's contribution (Hadar, HadarE) and its three
//! baselines (Gavel, Tiresias, YARN-CS), behind one trait so the
//! discrete-time simulator (§IV) and the physical-cluster emulation (§VI)
//! drive them identically.

pub mod alloc;
pub mod bench;
pub mod gavel;
pub mod hadar;
pub mod hadare;
pub mod price;
pub mod reference;
pub mod tiresias;
pub mod yarn_cs;

pub use alloc::{JobAllocation, RoundPlan};

use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::JobId;
use crate::jobs::queue::JobQueue;

pub use crate::jobs::queue::RoundDelta;

/// Everything a scheduler sees in one round.
pub struct RoundCtx<'a> {
    /// Round number (0-based).
    pub round: u64,
    /// Virtual time at round start (seconds).
    pub now: f64,
    /// Slot length `L` (seconds).
    pub slot_secs: f64,
    /// Horizon `T` for the utility lower bound in Eq. (7).
    pub horizon: f64,
    /// All jobs, across their whole lifecycle.
    pub queue: &'a JobQueue,
    /// Arrived, incomplete jobs (waiting set `Q`).
    pub active: &'a [JobId],
    /// What changed since the previous round — arrivals, completions,
    /// preemptions, cluster events ([`JobQueue::poll_round`] plus the
    /// engine's event count). `None` when the caller replans from the
    /// full list (one-shot contexts, benches, the frozen references);
    /// delta-aware schedulers must then fall back to full derivation.
    /// When `Some`, the delta is exact: every change since the last
    /// `schedule` call on this instance is listed.
    pub delta: Option<&'a RoundDelta>,
    /// The cluster **as of this round**. Under a cluster event timeline
    /// (node joins/drains, capacity changes — see
    /// [`crate::cluster::events`]) this changes between rounds, so
    /// schedulers must not cache node inventories across calls.
    pub cluster: &'a ClusterSpec,
}

/// Cumulative solver-internal counters a scheduler may expose for
/// telemetry ([`Scheduler::solver_stats`]). Deterministic for a fixed
/// seed — no wall-clock fields — so they travel in canonical sweep
/// artifacts and per-round telemetry streams. Hadar reports its
/// [`hadar::HadarStats`] through this; baselines report nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolverStats {
    /// DP memoisation hits (including the replay pass's revisits).
    pub memo_hits: u64,
    /// DP memoisation misses.
    pub memo_misses: u64,
    /// Rounds solved by the exact select/skip DP.
    pub dp_rounds: u64,
    /// Rounds solved by the payoff-density greedy fallback.
    pub greedy_rounds: u64,
    /// Rounds whose plan differed from the previous round's.
    pub rounds_with_change: u64,
    /// `FIND_ALLOC` invocations (Hadar's candidate-generation subroutine;
    /// speculative scores and commit-time rescores both count).
    pub find_alloc_calls: u64,
    /// Candidate allocations scored across all `FIND_ALLOC` calls —
    /// packed, pure-spread, and mixed-spread candidates together.
    pub candidates_scored: u64,
    /// Speculatively scored jobs whose winning candidate touched a GPU
    /// type dirtied by an earlier commit and had to be rescored serially.
    pub rescore_conflicts: u64,
}

/// A round-based cluster scheduler.
pub trait Scheduler {
    /// Stable scheduler name (CLI surface, result records).
    fn name(&self) -> &'static str;

    /// Decide the allocations for this round. Implementations must respect
    /// capacity (1d) and all-or-nothing (1e); the engine enforces both.
    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan;

    /// Whether the engine may preempt running jobs between rounds (YARN-CS
    /// says no).
    fn preemptive(&self) -> bool {
        true
    }

    /// The engine force-preempted this job (its node drained or shrank in
    /// a cluster event). Schedulers that pin allocations across rounds
    /// must drop theirs here — the placement no longer exists, and the
    /// job is back in the waiting set. Stateless schedulers ignore this.
    fn preempt(&mut self, _job: JobId) {}

    /// The job finished: drop any per-job state (type-order caches,
    /// attained-service counters, pinned allocations). Both round engines
    /// call this exactly once per completion, so per-job caches stay
    /// bounded by the *live* job count on long traces instead of growing
    /// with every job ever admitted. Stateless schedulers ignore this.
    fn job_completed(&mut self, _job: JobId) {}

    /// Cumulative solver-internal counters since construction, if the
    /// scheduler tracks any. The engines snapshot this per round for
    /// telemetry and once per run for sweep artifacts; the default is
    /// "nothing to report".
    fn solver_stats(&self) -> Option<SolverStats> {
        None
    }

    /// Fold a round boundary's [`RoundDelta`] into cross-round state
    /// *before* [`Scheduler::schedule`] runs. The engines call this once
    /// per scheduled round with the exact diff since the previous call
    /// (idle-skipped boundaries are merged in). The default adapter does
    /// nothing — delta-unaware schedulers (Gavel, Tiresias, YARN-CS, the
    /// frozen references) keep deriving everything from `ctx.active` /
    /// `ctx.queue` and behave identically. Delta-aware schedulers
    /// (Hadar) use it to prime/drop per-job caches incrementally instead
    /// of re-deriving them from the full list. Must be a pure cache
    /// fold: plans and [`SolverStats`] have to come out bit-identical
    /// whether or not it is called (the `prop_delta` suite pins this).
    fn observe_delta(&mut self, _delta: &RoundDelta, _queue: &JobQueue) {}
}

/// Construct a scheduler by name (CLI surface).
///
/// `hadare` (and its partial-node variant `hadare-shared`, which plans
/// per-`(node, pool)` sub-gangs so parents can share big nodes) is
/// deliberately *not* constructible here: it schedules forked copies onto
/// gang slots through the Job Tracker, which the generic round engine
/// cannot drive — run it via [`crate::sim::hadare_engine`] or the `expt`
/// sweep runner (which routes both names there automatically). Unknown
/// names get an error listing the known schedulers.
pub fn by_name(name: &str) -> Result<Box<dyn Scheduler>, String> {
    match name.to_ascii_lowercase().as_str() {
        "hadar" => Ok(Box::new(hadar::Hadar::new())),
        "gavel" => Ok(Box::new(gavel::Gavel::new())),
        "tiresias" => Ok(Box::new(tiresias::Tiresias::new())),
        "yarn-cs" | "yarn" => Ok(Box::new(yarn_cs::YarnCs::new())),
        "hadare" | "hadare-shared" => Err(
            "hadare/hadare-shared schedule forked job copies onto gang \
             slots and require the forking engine; run them via \
             sim::hadare_engine::run_with_gang or the expt sweep runner"
                .into(),
        ),
        other => Err(format!(
            "unknown scheduler '{other}' (known: yarn-cs, tiresias, gavel, \
             hadar, hadare, hadare-shared)"
        )),
    }
}

/// Whether `name` names any scheduler — including `hadare` and
/// `hadare-shared`, which only the forking engine can run (see
/// [`by_name`]). Lets spec parsers reject typos before a sweep starts
/// burning CPU.
pub fn is_known(name: &str) -> bool {
    matches!(
        name.to_ascii_lowercase().as_str(),
        "hadar"
            | "gavel"
            | "tiresias"
            | "yarn-cs"
            | "yarn"
            | "hadare"
            | "hadare-shared"
    )
}

/// All baseline names, in the paper's comparison order.
pub const SCHEDULER_NAMES: [&str; 4] = ["yarn-cs", "tiresias", "gavel", "hadar"];

/// Parse a `HADAR_PLAN_THREADS`-style override. `None`, empty, garbage
/// and `0` all mean "no override" (the zero case so exporting
/// `HADAR_PLAN_THREADS=0` behaves like unsetting it).
fn threads_from(raw: Option<&str>) -> Option<usize> {
    raw.and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
}

/// Resolve a plan-worker setting ([`hadare::GangConfig::plan_threads`],
/// [`hadar::HadarConfig::plan_threads`]) to a concrete worker count: an
/// explicit positive value wins; `0` falls back to the
/// `HADAR_PLAN_THREADS` environment variable, then to
/// `min(4, available_parallelism)`. Called once at planner construction
/// so a round never re-reads the environment. Shared by the Hadar and
/// HadarE planners and `sched::bench`; thread count is a pure throughput
/// dial — plans and stats are bit-identical at any value.
pub fn resolve_plan_threads(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    if let Some(n) =
        // lint: allow(env-read, reason = "the config layer itself: the one sanctioned HADAR_PLAN_THREADS read, passed down as an explicit count")
        threads_from(std::env::var("HADAR_PLAN_THREADS").ok().as_deref())
    {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_override_parsing() {
        assert_eq!(threads_from(None), None);
        assert_eq!(threads_from(Some("")), None);
        assert_eq!(threads_from(Some("banana")), None);
        assert_eq!(threads_from(Some("0")), None, "0 = unset");
        assert_eq!(threads_from(Some("4")), Some(4));
        assert_eq!(threads_from(Some(" 8 ")), Some(8));
        // Explicit config always beats the fallbacks.
        assert_eq!(resolve_plan_threads(3), 3);
        assert!(resolve_plan_threads(0) >= 1);
    }
}
