//! Schedulers: the paper's contribution (Hadar, HadarE) and its three
//! baselines (Gavel, Tiresias, YARN-CS), behind one trait so the
//! discrete-time simulator (§IV) and the physical-cluster emulation (§VI)
//! drive them identically.

pub mod alloc;
pub mod gavel;
pub mod hadar;
pub mod hadare;
pub mod price;
pub mod tiresias;
pub mod yarn_cs;

pub use alloc::{JobAllocation, RoundPlan};

use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::JobId;
use crate::jobs::queue::JobQueue;

/// Everything a scheduler sees in one round.
pub struct RoundCtx<'a> {
    /// Round number (0-based).
    pub round: u64,
    /// Virtual time at round start (seconds).
    pub now: f64,
    /// Slot length `L` (seconds).
    pub slot_secs: f64,
    /// Horizon `T` for the utility lower bound in Eq. (7).
    pub horizon: f64,
    pub queue: &'a JobQueue,
    /// Arrived, incomplete jobs (waiting set `Q`).
    pub active: &'a [JobId],
    pub cluster: &'a ClusterSpec,
}

/// A round-based cluster scheduler.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Decide the allocations for this round. Implementations must respect
    /// capacity (1d) and all-or-nothing (1e); the engine enforces both.
    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan;

    /// Whether the engine may preempt running jobs between rounds (YARN-CS
    /// says no).
    fn preemptive(&self) -> bool {
        true
    }
}

/// Construct a scheduler by name (CLI surface).
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name.to_ascii_lowercase().as_str() {
        "hadar" => Some(Box::new(hadar::Hadar::new())),
        "gavel" => Some(Box::new(gavel::Gavel::new())),
        "tiresias" => Some(Box::new(tiresias::Tiresias::new())),
        "yarn-cs" | "yarn" => Some(Box::new(yarn_cs::YarnCs::new())),
        _ => None,
    }
}

/// All baseline names, in the paper's comparison order.
pub const SCHEDULER_NAMES: [&str; 4] = ["yarn-cs", "tiresias", "gavel", "hadar"];
