//! **Tiresias** baseline [Gu et al., NSDI'19] — heterogeneity-*unaware*
//! two-queue discretized LAS (least attained service), Promote disabled,
//! as configured in the paper's §IV-B comparison.
//!
//! Priority: jobs with attained GPU-service below the queue threshold sit
//! in the high-priority queue; within a queue, FIFO by arrival. Gangs are
//! placed on a single GPU type (Tiresias targets homogeneous clusters; on
//! a heterogeneous one it simply treats any type as "a GPU", picking the
//! pool with most free devices — it never mixes types for one gang and
//! never *chooses* by throughput, which is exactly the unawareness the
//! paper contrasts with).

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::{RoundCtx, Scheduler};
use std::collections::BTreeMap;

/// The Tiresias baseline (see module docs).
pub struct Tiresias {
    /// Attained service in GPU-seconds.
    attained: BTreeMap<JobId, f64>,
    /// Queue-0/1 threshold in GPU-seconds.
    pub threshold: f64,
}

impl Default for Tiresias {
    fn default() -> Self {
        Self::new()
    }
}

impl Tiresias {
    /// Fresh scheduler with the one-hour queue threshold.
    pub fn new() -> Self {
        Tiresias {
            attained: BTreeMap::new(),
            // One hour of single-GPU service — the two-queue knee.
            threshold: 3600.0,
        }
    }

    /// Called by the engine after each round with the GPU-seconds each
    /// scheduled job consumed.
    pub fn record_service(&mut self, job: JobId, gpu_seconds: f64) {
        *self.attained.entry(job).or_insert(0.0) += gpu_seconds;
    }

    fn queue_of(&self, job: JobId) -> usize {
        if self.attained.get(&job).copied().unwrap_or(0.0) < self.threshold {
            0
        } else {
            1
        }
    }

    /// Place on the single type with the most free GPUs (type-blind).
    fn place(state: &ClusterState, w: usize, types: &[GpuType])
             -> Option<JobAllocation> {
        let mut best: Option<(usize, GpuType)> = None;
        for &r in types {
            let free = state.free_of_type(r);
            if free >= w && best.map_or(true, |(bf, _)| free > bf) {
                best = Some((free, r));
            }
        }
        let (_, r) = best?;
        let mut slots: Vec<(usize, usize)> = (0..state.n_nodes())
            .map(|h| (h, state.free(h, r)))
            .filter(|&(_, f)| f > 0)
            .collect();
        slots.sort_by(|a, b| b.1.cmp(&a.1));
        let mut alloc = JobAllocation::new();
        let mut need = w;
        for (h, free) in slots {
            if need == 0 {
                break;
            }
            let take = free.min(need);
            alloc.add(h, r, take);
            need -= take;
        }
        (need == 0).then_some(alloc)
    }
}

impl Scheduler for Tiresias {
    fn name(&self) -> &'static str {
        "tiresias"
    }

    /// Completion: drop the job's attained-service counter — LAS never
    /// consults finished jobs, and on long traces the map would otherwise
    /// grow with every job ever admitted.
    fn job_completed(&mut self, job: JobId) {
        self.attained.remove(&job);
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        let mut jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        // (queue, arrival) order: discretized 2-queue LAS, Promote off.
        jobs.sort_by(|a, b| {
            let qa = self.queue_of(a.id);
            let qb = self.queue_of(b.id);
            qa.cmp(&qb)
                // total_cmp: a NaN arrival must not panic the round.
                .then(a.arrival.total_cmp(&b.arrival))
                .then(a.id.cmp(&b.id))
        });

        let types = ctx.cluster.gpu_types();
        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();
        for job in jobs {
            if state.is_full() {
                break;
            }
            if let Some(alloc) =
                Self::place(&state, job.gpus_requested.max(1), &types)
            {
                for a in alloc.assignments(job.id) {
                    state.allocate(a);
                }
                plan.insert(job.id, alloc);
            }
        }
        // Account service now (slot-granular LAS).
        let slot = ctx.slot_secs;
        for id in plan.scheduled_jobs() {
            let gpus = plan.get(id).unwrap().total_gpus() as f64;
            self.record_service(id, gpus * slot);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    fn mk_job(id: u64, w: usize, arrival: f64) -> Job {
        let mut j = Job::new(id, DlModel::Lstm, arrival, w, 10, 100);
        j.set_throughput(GpuType::V100, 60.0);
        j.set_throughput(GpuType::P100, 40.0);
        j.set_throughput(GpuType::K80, 15.0);
        j
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            delta: None,
            cluster,
        }
    }

    #[test]
    fn single_type_gangs_only() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 4, 0.0)).unwrap(); // no type has 4
        let active = vec![JobId(1)];
        let mut t = Tiresias::new();
        let plan = t.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none());
    }

    #[test]
    fn las_prioritises_low_attained_service() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 3, 0.0)).unwrap();
        queue.admit(mk_job(2, 3, 5.0)).unwrap(); // later arrival
        let active = vec![JobId(1), JobId(2)];
        let mut t = Tiresias::new();
        // J1 has consumed a lot of service -> demoted to queue 1.
        t.record_service(JobId(1), 10_000.0);
        let plan = t.schedule(&ctx(&queue, &active, &cluster));
        // Only P100 can host a 3-gang; J2 (queue 0) must get it.
        assert!(plan.get(JobId(2)).is_some());
        assert!(plan.get(JobId(1)).is_none());
    }

    #[test]
    fn fifo_within_queue() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 3, 10.0)).unwrap();
        queue.admit(mk_job(2, 3, 0.0)).unwrap(); // earlier
        let active = vec![JobId(1), JobId(2)];
        let mut t = Tiresias::new();
        let plan = t.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(2)).is_some());
        assert!(plan.get(JobId(1)).is_none());
    }

    #[test]
    fn job_completed_drops_attained_service() {
        let mut t = Tiresias::new();
        t.record_service(JobId(1), 100.0);
        t.record_service(JobId(2), 50.0);
        t.job_completed(JobId(1));
        assert_eq!(t.attained.len(), 1);
        assert!(t.attained.contains_key(&JobId(2)));
    }

    #[test]
    fn service_recorded_per_round() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 2, 0.0)).unwrap();
        let active = vec![JobId(1)];
        let mut t = Tiresias::new();
        let _ = t.schedule(&ctx(&queue, &active, &cluster));
        assert!((t.attained[&JobId(1)] - 2.0 * 360.0).abs() < 1e-9);
    }

    #[test]
    fn nan_arrival_does_not_panic_the_fifo_sort() {
        // NaN-comparator regression: the FIFO tie-break used
        // partial_cmp().unwrap(), which panicked the round as soon as one
        // job carried a NaN arrival. total_cmp must survive it and still
        // place jobs.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 2, f64::NAN)).unwrap();
        queue.admit(mk_job(2, 2, 0.0)).unwrap();
        let active = vec![JobId(1), JobId(2)];
        let mut t = Tiresias::new();
        let plan = t.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(2)).is_some(), "well-formed job still runs");
    }
}
