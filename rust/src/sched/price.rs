//! The dual price function — Eqs. (5)-(7) of the paper.
//!
//! `k_h^r(γ) = U_min^r * (U_max^r / U_min^r)^(γ / c_h^r)`
//!
//! The price for a (node, GPU-type) pool starts at `U_min^r` (low enough to
//! admit any job) and rises exponentially to `U_max^r` as the pool fills
//! (high enough that no job's payoff stays positive), which is what gives
//! Theorem 2 its `2α` competitive ratio with
//! `α = max_r(1, ln(U_max^r / U_min^r))`.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::Job;
use std::collections::BTreeMap;

/// Per-GPU-type utility bounds (Eqs. (6)-(7)).
#[derive(Clone, Debug)]
pub struct PriceBounds {
    /// `U_max^r`: best-case per-unit utility per type (Eq. 6).
    pub u_max: BTreeMap<GpuType, f64>,
    /// `U_min^r`: admission floor per type (Eq. 7, scaled by `1/4η`).
    pub u_min: BTreeMap<GpuType, f64>,
}

impl PriceBounds {
    /// Compute the bounds from the current workload (paper: "U_max and
    /// U_min are calculated based on the cluster's workload").
    ///
    /// * `U_max^r = max_j U_j(t_j^min) / w_j^r`  — best-case per-unit value;
    ///   `w_j^r` is the gang size when run on type r (all `W_j` here).
    /// * `U_min^r = (1/4η) * min_j U_j(T - a_j) / (t_j^max Σ_r w_j^r)` —
    ///   the smallest utility a job may achieve (ending at horizon `T`),
    ///   discounted by the scale factor η (Theorem 2: D_0 ≤ ½ OPT).
    pub fn from_jobs(jobs: &[&Job], gpu_types: &[GpuType], horizon: f64,
                     eta: f64) -> Self {
        let mut u_max = BTreeMap::new();
        let mut u_min = BTreeMap::new();
        for &r in gpu_types {
            let mut hi: f64 = 0.0;
            let mut lo = f64::INFINITY;
            for job in jobs {
                if job.throughput_on(r) <= 0.0 {
                    continue;
                }
                let w = job.gpus_requested.max(1) as f64;
                hi = hi.max(job.utility(job.t_min()) / w);
                let min_duration = (horizon - job.arrival).max(job.t_min());
                let t_max = job.t_max();
                let denom = t_max * (gpu_types.len() as f64) * w;
                if denom > 0.0 {
                    lo = lo.min(job.utility(min_duration) / denom
                                / (4.0 * eta));
                }
            }
            if !hi.is_finite() || hi <= 0.0 {
                hi = 1.0;
            }
            if !lo.is_finite() || lo <= 0.0 {
                lo = hi * 1e-4;
            }
            // Guarantee U_min < U_max so α ≥ 1 and the exponent is sane.
            if lo >= hi {
                lo = hi * 0.5;
            }
            u_max.insert(r, hi);
            u_min.insert(r, lo);
        }
        PriceBounds { u_max, u_min }
    }

    /// `α = max_r(1, ln(U_max^r / U_min^r))` (Theorem 2).
    pub fn alpha(&self) -> f64 {
        self.u_max
            .iter()
            .map(|(r, &hi)| (hi / self.u_min[r]).ln())
            .fold(1.0_f64, f64::max)
    }
}

/// Live prices `k_h^r(t)`, updated as allocations accumulate in a round.
#[derive(Clone, Debug)]
pub struct PriceTable {
    bounds: PriceBounds,
}

impl PriceTable {
    /// Price table over the given bounds.
    pub fn new(bounds: PriceBounds) -> Self {
        PriceTable { bounds }
    }

    /// The bounds this table prices with.
    pub fn bounds(&self) -> &PriceBounds {
        &self.bounds
    }

    /// Eq. (5): price of one type-r GPU on node h given the *current*
    /// allocation state. `gamma_extra` lets the DP price a hypothetical
    /// allocation without mutating the state.
    pub fn price(&self, state: &ClusterState, node: usize, gpu: GpuType,
                 gamma_extra: usize) -> f64 {
        let cap = state.capacity(node, gpu);
        if cap == 0 {
            return f64::INFINITY;
        }
        let gamma = (state.allocated(node, gpu) + gamma_extra) as f64;
        let frac = (gamma / cap as f64).min(1.0);
        let hi = self.bounds.u_max.get(&gpu).copied().unwrap_or(1.0);
        let lo = self.bounds.u_min.get(&gpu).copied().unwrap_or(1e-4);
        lo * (hi / lo).powf(frac)
    }

    /// Marginal cost of taking `count` GPUs of (node, type): the sum of the
    /// per-unit prices as γ steps up — the discrete form of the
    /// differential allocation-cost relationship (Definition 2).
    ///
    /// §Perf: evaluated in closed form. With `r = (hi/lo)^(1/c)` the sum
    /// `Σ_{i=0}^{count-1} lo·r^(γ+i)` is the geometric series
    /// `lo·r^γ·(r^count - 1)/(r - 1)` — one `powf` instead of `count`.
    pub fn marginal_cost(&self, state: &ClusterState, node: usize,
                         gpu: GpuType, count: usize) -> f64 {
        if count == 0 {
            return 0.0;
        }
        let cap = state.capacity(node, gpu);
        if cap == 0 {
            return f64::INFINITY;
        }
        let gamma = state.allocated(node, gpu) as f64;
        let hi = self.bounds.u_max.get(&gpu).copied().unwrap_or(1.0);
        let lo = self.bounds.u_min.get(&gpu).copied().unwrap_or(1e-4);
        let r = (hi / lo).powf(1.0 / cap as f64);
        if (r - 1.0).abs() < 1e-12 {
            return lo * count as f64;
        }
        lo * r.powf(gamma) * (r.powf(count as f64) - 1.0) / (r - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::cluster::state::Assignment;
    use crate::jobs::job::JobId;
    use crate::jobs::model::DlModel;

    fn mk_job(id: u64) -> Job {
        let mut j = Job::new(id, DlModel::ResNet18, 0.0, 2, 4, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        j
    }

    fn bounds(jobs: &[&Job]) -> PriceBounds {
        PriceBounds::from_jobs(
            jobs,
            &[GpuType::V100, GpuType::P100, GpuType::K80],
            10_000.0,
            1.0,
        )
    }

    #[test]
    fn bounds_ordering() {
        let j = mk_job(1);
        let b = bounds(&[&j]);
        for r in [GpuType::V100, GpuType::P100, GpuType::K80] {
            assert!(b.u_min[&r] > 0.0);
            assert!(b.u_min[&r] < b.u_max[&r]);
        }
        assert!(b.alpha() >= 1.0);
    }

    #[test]
    fn price_starts_at_umin_and_caps_at_umax() {
        let j = mk_job(1);
        let b = bounds(&[&j]);
        let table = PriceTable::new(b.clone());
        let spec = ClusterSpec::motivational();
        let mut state = ClusterState::new(&spec);
        // Empty pool: price == U_min.
        let p0 = table.price(&state, 0, GpuType::V100, 0);
        assert!((p0 - b.u_min[&GpuType::V100]).abs() / p0 < 1e-9);
        // Full pool: price == U_max.
        state.allocate(Assignment {
            job: JobId(9),
            node: 0,
            gpu: GpuType::V100,
            count: 2,
        });
        let pfull = table.price(&state, 0, GpuType::V100, 0);
        assert!((pfull - b.u_max[&GpuType::V100]).abs() / pfull < 1e-9);
    }

    #[test]
    fn price_is_monotone_in_gamma() {
        let j = mk_job(1);
        let table = PriceTable::new(bounds(&[&j]));
        let spec = ClusterSpec::motivational();
        let state = ClusterState::new(&spec);
        let mut last = 0.0;
        for extra in 0..=3 {
            let p = table.price(&state, 1, GpuType::P100, extra);
            assert!(p > last);
            last = p;
        }
    }

    #[test]
    fn marginal_cost_sums_unit_prices() {
        let j = mk_job(1);
        let table = PriceTable::new(bounds(&[&j]));
        let spec = ClusterSpec::motivational();
        let state = ClusterState::new(&spec);
        let c2 = table.marginal_cost(&state, 1, GpuType::P100, 2);
        let p0 = table.price(&state, 1, GpuType::P100, 0);
        let p1 = table.price(&state, 1, GpuType::P100, 1);
        assert!((c2 - (p0 + p1)).abs() < 1e-12);
    }

    #[test]
    fn missing_capacity_prices_infinite() {
        let j = mk_job(1);
        let table = PriceTable::new(bounds(&[&j]));
        let spec = ClusterSpec::motivational();
        let state = ClusterState::new(&spec);
        assert!(table.price(&state, 0, GpuType::K80, 0).is_infinite());
    }

    #[test]
    fn eta_scales_umin_down() {
        let j = mk_job(1);
        let b1 = PriceBounds::from_jobs(&[&j], &[GpuType::V100], 1000.0, 1.0);
        let b4 = PriceBounds::from_jobs(&[&j], &[GpuType::V100], 1000.0, 4.0);
        assert!(b4.u_min[&GpuType::V100] < b1.u_min[&GpuType::V100]);
        assert!(b4.alpha() > b1.alpha());
    }
}
