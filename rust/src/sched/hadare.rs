//! **HadarE** (paper §V) — Hadar enhanced with job forking.
//!
//! Every unfinished parent job has `n` forked copies (for an `n`-node
//! cluster); each round HadarE assigns **gang slots** to copies so that no
//! *node* idles while any parent has work left (Theorem 3 / its
//! corollary; see the shared-mode caveat below for why conservation is
//! per node, not per slot). What a slot is depends on
//! [`GangConfig::share_nodes`]:
//!
//! * `share_nodes = false` (**whole-node compatibility mode**, the
//!   default): one slot per node; a copy scheduled on node `h` occupies
//!   **every GPU of `h`** — the per-pool counts come from the node spec
//!   ([`Node::gang`]), so on a multi-GPU cluster (`sim60`'s 15 × 4-GPU
//!   nodes) a round-0 plan covers all 60 GPUs, not 15.
//! * `share_nodes = true` (**partial-node / per-pool mode**): one slot
//!   per `(node, pool)` — a copy occupies one GPU pool of its host, so
//!   two or more parents can share a big node in the same round. On an
//!   8-GPU two-pool node, whole-node gangs let one parent monopolise the
//!   node while other parents queue — exactly the fragmentation-driven
//!   under-utilization Hadar/HadarE exist to eliminate (PAPER.md §V,
//!   Theorem 3); per-pool slots hand each pool to a different parent.
//!   On clusters whose nodes carry a single pool (every paper preset:
//!   `aws5`, `testbed5`, `sim60`, `scaled:NxG`) the two modes coincide
//!   slot-for-slot and produce identical plans.
//!
//!   Caveat: the one-copy-per-parent-per-*node* rule still applies, so
//!   with fewer active parents than pools per node some pools idle (a
//!   lone surviving parent holds at most one pool of each node, where a
//!   whole-node gang would hold them all). Work conservation in shared
//!   mode is therefore per *node*, not per slot; idle pools book no
//!   GPU-seconds, so CRU (busy/allocated) is unaffected, but the
//!   single-parent tail of a trace can drain slower than under
//!   whole-node gangs. Same-parent multi-pool sub-gangs are the
//!   ROADMAP's named follow-up.
//!
//! Scheduling reuses Hadar's machinery over the copy queue with two extra
//! constraints:
//!
//! * at most one copy of a given parent per **node** (copies exist to run
//!   on *separate* machines — two pools of one node never host two copies
//!   of the same parent, that would consolidate a model with itself);
//! * work-conservation: after the payoff-driven pass, any still-idle slot
//!   is given a copy of the parent with the most remaining work that is
//!   not yet on that slot's node.
//!
//! Parents are planned only once they have **arrived** (`job.arrival <=
//! ctx.now`): the forking engine registers every parent with the tracker
//! up front, so the planner filters by arrival rather than training jobs
//! before they exist.
//!
//! ## Gang throughput
//!
//! A gang's rate — [`gang_throughput`] for a whole node,
//! [`pool_throughput`] for one pool, [`alloc_throughput`] for whatever a
//! plan actually booked — follows the same rules Hadar applies to its
//! gangs:
//!
//! * **bottleneck (Eq. 1b)** — every GPU in the gang advances at the
//!   slowest *usable* type's pace; a node carrying any type the job
//!   cannot run on (zero/NaN throughput) is unusable as a whole;
//! * **`min_efficiency`** — same semantics as
//!   [`crate::sched::hadar::HadarConfig::min_efficiency`]: a bottleneck
//!   below that fraction of the job's best single-GPU throughput rejects
//!   the node outright;
//! * **sub-linear scaling** — each GPU beyond the first contributes only
//!   [`GangConfig::marginal_efficiency`] of a full GPU (intra-node
//!   data-parallel sync overhead, the within-node analogue of Hadar's
//!   `comm_factor`), so a 4×K80 node is *not* naively 4× a 1×K80 node.
//!
//! On single-GPU nodes the gang rate degenerates to the per-GPU
//! throughput exactly, which is why the pre-rework planner — frozen as
//! [`crate::sched::reference::RefHadarE`] — is pinned plan-for-plan to
//! this one on `aws5`/`testbed5` by `rust/tests/prop_equivalence.rs`.
//!
//! ## Warm start and the round carry-over
//!
//! The forking engine keeps a per-`(node, pool)` → copy binding map
//! across rounds (restart-overhead accounting). Since the streaming-scale
//! rework that map is also handed *into* the planner as a [`PrevRound`]
//! ([`HadarE::plan_round_with`]), which buys two things:
//!
//! * **Switch-cost-aware payoffs.** A slot whose loaded model is a
//!   different parent only trains `slot_secs − restart_overhead` seconds
//!   after the engine charges the model (re)load, so the planner scores
//!   and burns candidates by `x · eff_secs` instead of raw `x` — the
//!   restart-overhead model the engine charges is now the one the
//!   planner optimises against, and a parent keeps its loaded gang
//!   unless moving genuinely pays. One documented asymmetry: a pool with
//!   *no* binding is treated as penalty-free even though the engine
//!   charges its first model load. Charging it would deduct the same
//!   constant from every still-unloaded slot (it carries no information
//!   about *which* parent should win one), and leaving it out is what
//!   makes an **empty carry-over degrade bit-identically** to the
//!   historical planner: with no bindings at all the scores fall back to
//!   raw `x` and the burns to `x · slot_secs`, exactly the pre-rework
//!   formulas (pinned by `prop_hadare_empty_carry_over_degrades_to_plan_round`).
//! * **A per-parent gang-row cache.** A parent's throughput row over the
//!   slot inventory depends only on (job, slots), so rows are cached
//!   across rounds keyed by parent id and recomputed lazily, only for
//!   parents the placement passes actually examine. The cache is
//!   invalidated wholesale whenever the slot inventory changes (node
//!   join/leave/capacity event, mode flip) — detected by an FNV-1a
//!   signature over the inventory — and a parent's row is dropped on its
//!   completion ([`HadarE::job_completed`]). In the streaming regime
//!   (jobs ≫ slots, copy budget small) pass 0 fills the whole inventory
//!   from a prefix of the priority order, so a round touches O(slots)
//!   rows instead of re-scoring every live parent: that is the
//!   sublinear-decision-time claim `sched::bench`'s `warm_*` rows
//!   measure. [`WarmStats`] counts rounds/computed/reused/invalidations
//!   deterministically; the same numbers feed the gated `obs` counters
//!   `hadare.warm_rows_*`.
//!
//! [`HadarE::plan_round_cold`] is the reference path: a full-matrix
//! recompute with the *same* carry-over payoff model, against which the
//! warm path is pinned plan-for-plan by
//! `prop_hadare_warm_start_equals_cold_replanning` and timed by the
//! bench. Any divergence is a bug, never a perf trade.
//!
//! ## Sharded rounds
//!
//! The cold path's two superlinear stages — the gang-matrix build and
//! the candidate sort — are sharded across a small owned worker pool
//! (`std::thread::scope`, the same no-new-deps idiom as
//! [`crate::expt::runner`]). Determinism is structural, not incidental:
//! matrix cells are pure functions of (job, slot) written into disjoint
//! chunks, and the sort runs as per-chunk stable sorts over *contiguous*
//! chunks followed by a k-way merge that breaks ties toward the earlier
//! chunk — which reproduces exactly the original-index order of a serial
//! stable sort. Plans are therefore **bit-identical at any thread
//! count** (pinned by `rust/tests/hadare_stream.rs` at 1/2/8 workers).
//! The worker count comes from [`GangConfig::plan_threads`] via
//! [`crate::sched::resolve_plan_threads`]; tiny inputs stay serial.
//!
//! §Perf: `plan_round` follows the PR-3 zero-clone idiom — the per-round
//! `BTreeMap`s (`node_load`, `copies_used`, `placed_on`) are flat
//! `Vec`-indexed tables, the gang-throughput matrix is computed once per
//! (parent, node) pair, and placement is a method instead of a
//! seven-argument closure. `sched::bench` (`fork_*` cases) times it
//! against the frozen reference; the `warm_*`/`shard_*` cases time the
//! warm-start and sharded paths against cold single-threaded replanning.
//!
//! The engines call [`HadarE::plan_round_with`] with the tracker state
//! and their binding carry-over; step division + aggregation +
//! consolidation happen in the engine through the
//! [`crate::forking::JobTracker`].

use crate::cluster::gpu::GpuType;
use crate::cluster::node::Node;
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::jobs::queue::JobQueue;
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::RoundCtx;
use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};

/// Knobs of the gang throughput/placement model (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct GangConfig {
    /// Fraction of a full GPU each GPU beyond the first contributes to
    /// the gang rate: `rate = x_min · (1 + marginal_efficiency·(n−1))`.
    /// `1.0` = perfectly linear scaling; the default models the intra-node
    /// gradient-sync overhead of data-parallel training.
    pub marginal_efficiency: f64,
    /// Reject gangs whose bottleneck throughput is below this fraction of
    /// the job's best single-GPU throughput — identical semantics to
    /// [`crate::sched::hadar::HadarConfig::min_efficiency`].
    pub min_efficiency: f64,
    /// Partial-node mode: plan per-`(node, pool)` sub-gangs so several
    /// parents can share a big node. `false` (the default) is the
    /// whole-node compatibility mode, pinned plan-for-plan to
    /// [`crate::sched::reference::RefHadarE`] on single-GPU clusters by
    /// `rust/tests/prop_equivalence.rs`.
    pub share_nodes: bool,
    /// Worker threads for the sharded gang-matrix build and candidate
    /// sort. `0` (the default) resolves at planner construction via
    /// [`crate::sched::resolve_plan_threads`]: the `HADAR_PLAN_THREADS`
    /// variable if set to a positive integer, else
    /// `min(4, available_parallelism)`. Plans are **bit-identical at any
    /// thread count** (deterministic merge order, pinned by
    /// `rust/tests/hadare_stream.rs`), so this is a latency knob, never
    /// a semantics knob.
    pub plan_threads: usize,
}

impl Default for GangConfig {
    fn default() -> Self {
        GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
            share_nodes: false,
            plan_threads: 0,
        }
    }
}

impl GangConfig {
    /// The partial-node (per-pool) configuration with the default
    /// throughput knobs — what the `hadare-shared` sweep scheduler runs.
    pub fn shared() -> Self {
        GangConfig {
            share_nodes: true,
            ..GangConfig::default()
        }
    }
}

/// Below this many gang-matrix cells (parents × slots) the sharded build
/// runs serially — thread spawn/join overhead would dominate.
const SHARD_MIN_CELLS: usize = 1 << 14;
/// Below this many candidates the sort runs serially, for the same
/// reason.
const SHARD_MIN_CANDS: usize = 1 << 14;

/// Shared tail of the gang rate model, so the three public rating
/// functions cannot drift apart: a bottleneck of `x_min` it/s over
/// `n_gpus` GPUs — empty gangs and zero/NaN/infinite bottlenecks are
/// unusable, the `min_efficiency` floor rejects wasteful placements, and
/// each GPU beyond the first contributes `marginal_efficiency` of a full
/// one.
fn scaled_rate(job: &Job, x_min: f64, n_gpus: usize,
               cfg: &GangConfig) -> f64 {
    // NaN fails the `>` too: a malformed row makes the gang unusable
    // rather than poisoning the plan.
    if n_gpus == 0 || !(x_min > 0.0) || !x_min.is_finite() {
        return 0.0;
    }
    if x_min < cfg.min_efficiency * job.max_throughput() {
        return 0.0;
    }
    x_min * (1.0 + cfg.marginal_efficiency * (n_gpus - 1) as f64)
}

/// Iterations/second of `job` when one forked copy occupies the whole of
/// `node` (see the module docs for the model). Returns `0.0` when the
/// node is unusable for the job: no GPUs, any pool with zero/NaN
/// throughput (bottleneck all-or-nothing), or a bottleneck below the
/// `min_efficiency` floor.
pub fn gang_throughput(job: &Job, node: &Node, cfg: &GangConfig) -> f64 {
    let mut n_gpus = 0usize;
    let mut x_min = f64::INFINITY;
    for (g, c) in node.gang() {
        let x = job.throughput_on(g);
        // The early return (not `min`, which would discard a NaN) makes
        // any unusable pool poison the whole node.
        if !(x > 0.0) {
            return 0.0;
        }
        x_min = x_min.min(x);
        n_gpus += c;
    }
    scaled_rate(job, x_min, n_gpus, cfg)
}

/// Iterations/second of `job` when one forked copy occupies a single
/// `count`-GPU pool of type `gpu` — the per-pool slot of partial-node
/// mode. Same model as [`gang_throughput`] with a one-type gang: no
/// bottleneck across pools (the copy touches only this one), the
/// `min_efficiency` floor, and sub-linear multi-GPU scaling. Returns
/// `0.0` for an empty pool or a zero/NaN throughput row.
pub fn pool_throughput(job: &Job, gpu: GpuType, count: usize,
                       cfg: &GangConfig) -> f64 {
    scaled_rate(job, job.throughput_on(gpu), count, cfg)
}

/// Iterations/second of `job` on whatever sub-gang `alloc` actually
/// booked: the bottleneck rule across the allocation's pools, the
/// `min_efficiency` floor, and sub-linear scaling over its total GPU
/// count. For a whole-node allocation this equals [`gang_throughput`] of
/// the host; for a per-pool allocation it equals [`pool_throughput`] of
/// that pool. The forking engine rates every scheduled copy through this,
/// so its accounting is mode-agnostic.
pub fn alloc_throughput(job: &Job, alloc: &JobAllocation,
                        cfg: &GangConfig) -> f64 {
    let mut n_gpus = 0usize;
    let mut x_min = f64::INFINITY;
    for (&(_, g), &c) in alloc.slots.iter() {
        let x = job.throughput_on(g);
        if !(x > 0.0) {
            return 0.0;
        }
        x_min = x_min.min(x);
        n_gpus += c;
    }
    scaled_rate(job, x_min, n_gpus, cfg)
}

/// The previous round's `(node, pool)` → parent bindings plus the
/// restart-overhead charge — the engine's carry-over, resolved to
/// **parent** ids, that lets the planner model the switch costs it
/// induces (module docs, "Warm start"). Bindings may be stale: entries
/// for nodes that have since left the cluster are simply never matched
/// by a live slot and are ignored (churn safety, pinned by
/// `rust/tests/hadare_stream.rs`).
#[derive(Clone, Debug, Default)]
pub struct PrevRound {
    /// Parent most recently trained on each `(node id, pool type)`.
    bindings: BTreeMap<(usize, GpuType), JobId>,
    /// Seconds a gang loses to a model (re)load when it switches parents
    /// — the planner-side mirror of
    /// [`crate::sim::engine::SimConfig::restart_overhead`].
    pub restart_overhead: f64,
}

impl PrevRound {
    /// An empty carry-over with the given restart overhead.
    pub fn new(restart_overhead: f64) -> Self {
        PrevRound {
            bindings: BTreeMap::new(),
            restart_overhead,
        }
    }

    /// The no-carry-over value: no bindings, zero overhead. A planner
    /// handed this plans **bit-identically** to the historical
    /// carry-over-blind `plan_round`.
    pub fn empty() -> Self {
        PrevRound::default()
    }

    /// Record that `(node, pool)` most recently trained `parent`.
    pub fn bind(&mut self, node: usize, pool: GpuType, parent: JobId) {
        self.bindings.insert((node, pool), parent);
    }

    /// Whether the carry-over holds no bindings at all.
    pub fn is_empty(&self) -> bool {
        self.bindings.is_empty()
    }

    /// Number of bound `(node, pool)` pairs.
    pub fn len(&self) -> usize {
        self.bindings.len()
    }

    /// Build a carry-over from a round's plan: every pool each scheduled
    /// copy booked is bound to the copy's *parent* (resolved through the
    /// tracker). Convenience for benches/tests; the engine builds its
    /// carry-over from its own persistent binding map instead, which
    /// also remembers idle-node bindings from earlier rounds.
    pub fn from_plan(plan: &RoundPlan, tracker: &JobTracker,
                     restart_overhead: f64) -> Self {
        let mut prev = PrevRound::new(restart_overhead);
        for (&copy, alloc) in &plan.allocations {
            let parent = tracker.resolve(copy);
            for (&(node, g), _) in alloc.slots.iter() {
                prev.bind(node, g, parent);
            }
        }
        prev
    }
}

/// What the carry-over says about one gang slot: nothing bound, one
/// parent's model loaded on every bound pool, or a mix (a whole-node
/// slot whose pools last trained different parents — any copy placed
/// there reloads at least one pool, so it pays the switch cost).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotBind {
    /// No pool of the slot has a recorded binding.
    Free,
    /// Every bound pool of the slot last trained this parent.
    One(JobId),
    /// Bound pools disagree about the loaded parent.
    Mixed,
}

/// Resolve each slot's [`SlotBind`] from the carry-over. A per-pool slot
/// consults its own `(node, pool)` key; a whole-node slot consults every
/// pool of its host's gang. Bindings for `(node, pool)` pairs absent
/// from the inventory are never looked up, which is what drops stale
/// entries for departed nodes.
fn slot_binds(slots: &[GangSlot], prev: &PrevRound) -> Vec<SlotBind> {
    fn note(bind: &mut SlotBind, parent: JobId) {
        match *bind {
            SlotBind::Free => *bind = SlotBind::One(parent),
            SlotBind::One(q) if q != parent => *bind = SlotBind::Mixed,
            _ => {}
        }
    }
    slots
        .iter()
        .map(|s| {
            let mut bind = SlotBind::Free;
            match s.pool {
                Some((g, _)) => {
                    if let Some(&p) = prev.bindings.get(&(s.node.id, g)) {
                        note(&mut bind, p);
                    }
                }
                None => {
                    for (g, _) in s.node.gang() {
                        if let Some(&p) =
                            prev.bindings.get(&(s.node.id, g))
                        {
                            note(&mut bind, p);
                        }
                    }
                }
            }
            bind
        })
        .collect()
}

/// Effective training seconds of a slot for `parent` under the
/// carry-over: a slot whose loaded model is a *different* parent (or a
/// mix) loses `overhead` seconds to the reload, matching the engine's
/// any-pool-differs charge. An unbound slot is not penalised here — see
/// the module docs for why that asymmetry is deliberate.
#[inline]
fn eff_secs(bind: SlotBind, parent: JobId, slot_secs: f64,
            overhead: f64) -> f64 {
    let switch = match bind {
        SlotBind::Free => false,
        SlotBind::One(p) => p != parent,
        SlotBind::Mixed => true,
    };
    if switch {
        (slot_secs - overhead).max(0.0)
    } else {
        slot_secs
    }
}

/// Deterministic warm-start cache statistics, updated on every
/// [`HadarE::plan_round_with`] call regardless of the `obs` gate (they
/// are plain counters, never timers, so maintaining them cannot perturb
/// plans). The same deltas feed the gated `hadare.warm_rows_*` obs
/// counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStats {
    /// Rounds planned through the warm path.
    pub rounds: u64,
    /// Gang rows computed from scratch (cache misses).
    pub rows_computed: u64,
    /// Gang rows served from the cache.
    pub rows_reused: u64,
    /// Whole-cache clears forced by a slot-inventory change (node
    /// join/leave/capacity event, gang-mode flip).
    pub invalidations: u64,
}

/// The HadarE gang planner (see module docs): whole-node slots by
/// default, per-`(node, pool)` slots under [`GangConfig::share_nodes`];
/// warm-started from the engine's binding carry-over and sharded across
/// [`GangConfig::plan_threads`] workers.
pub struct HadarE {
    /// Copies per job (usually = node count; Theorem 3's maximum).
    pub copies: u64,
    /// Gang throughput model (bottleneck + sub-linear scaling) and the
    /// whole-node vs per-pool placement mode.
    pub gang: GangConfig,
    /// Warm-start cache statistics (deterministic, see [`WarmStats`]).
    pub stats: WarmStats,
    /// Worker count resolved from `gang.plan_threads` at construction.
    threads: usize,
    /// Cached gang rows keyed by parent id, valid for `rows_sig`'s slot
    /// inventory. Jobs are immutable while live (the queue only mutates
    /// rows at admission), so a row only goes stale when the inventory
    /// changes or the parent completes.
    rows: BTreeMap<JobId, Vec<f64>>,
    /// FNV-1a signature of the slot inventory `rows` was built against;
    /// `0` is the initial no-cache sentinel.
    rows_sig: u64,
}

/// One placeable gang slot: a whole node (compatibility mode) or a
/// single GPU pool of it (partial-node mode).
struct GangSlot<'a> {
    /// Index into the planner's node inventory — the at-most-one-copy-
    /// per-parent-per-**node** exclusion is keyed by this, not by slot.
    hi: usize,
    /// The host node.
    node: &'a Node,
    /// `Some((type, count))` books that pool only; `None` books the
    /// node's whole gang.
    pool: Option<(GpuType, usize)>,
}

/// The allocation one copy books when placed on `slot`: the slot's pool,
/// or the host's whole gang in compatibility mode.
fn slot_alloc(slot: &GangSlot) -> JobAllocation {
    let mut alloc = JobAllocation::new();
    match slot.pool {
        Some((g, c)) => alloc.add(slot.node.id, g, c),
        None => {
            for (g, c) in slot.node.gang() {
                alloc.add(slot.node.id, g, c);
            }
        }
    }
    alloc
}

/// Per-round placement tables, flat `Vec`s indexed by parent/slot/node
/// *position* (node ids need not be contiguous under cluster events).
/// This is the zero-clone replacement for the three `BTreeMap`s the
/// pre-rework planner probed per candidate. The cold reference path uses
/// these dense tables; the warm path replaces `placed` with a sparse set
/// (a round touches O(slots) placements, so a dense `n_p × n_h` bitmap
/// would dominate the warm cost at streaming scale).
struct Tables {
    /// Slot at index `si` already hosts a copy this round.
    slot_busy: Vec<bool>,
    /// Copies handed out so far per parent index.
    copies_used: Vec<u64>,
    /// `placed[pi * n_nodes + hi]`: parent `pi` already has a copy on
    /// node `hi` (on *any* of its pools).
    placed: Vec<bool>,
    /// Row stride of `placed`.
    n_nodes: usize,
}

impl Tables {
    fn new(n_parents: usize, n_nodes: usize, n_slots: usize) -> Self {
        Tables {
            slot_busy: vec![false; n_slots],
            copies_used: vec![0; n_parents],
            placed: vec![false; n_parents * n_nodes],
            n_nodes,
        }
    }

    /// Place the next copy of `pid` on `slot`, occupying its pool (or the
    /// host's whole gang in compatibility mode).
    fn place(&mut self, plan: &mut RoundPlan, tracker: &JobTracker,
             pid: JobId, pi: usize, si: usize, slot: &GangSlot) {
        let i = self.copies_used[pi] + 1;
        let copy = tracker.ids.copy_id(pid, i);
        plan.insert(copy, slot_alloc(slot));
        self.slot_busy[si] = true;
        self.copies_used[pi] = i;
        self.placed[pi * self.n_nodes + slot.hi] = true;
    }
}

/// Parents with work left that have *arrived*, by remaining steps (desc;
/// `total_cmp` so a degenerate row cannot panic the round, stable sort
/// keeps id order on ties). The engine registers every parent with the
/// tracker up front, so arrival gates here — a parent with `arrival >
/// now` must not train before it exists.
///
/// When the caller supplies the waiting set (`ctx.active`, the queue's
/// persistent delta-maintained index, id-ordered exactly like
/// [`JobTracker::parents`]), candidates come from it in O(active)
/// instead of scanning every parent ever registered — the HadarE-side
/// half of the delta round pipeline. Both paths apply the same
/// unfinished + arrived filters, so they select the identical parent
/// set whenever `ctx.active` covers the arrived, incomplete parents
/// (pinned by `rust/tests/prop_delta.rs`). An empty `ctx.active` falls
/// back to the full tracker scan (one-shot contexts, the frozen
/// reference tests).
fn sorted_parents(ctx: &RoundCtx, tracker: &JobTracker)
                  -> Vec<(JobId, f64)> {
    let arrived = |id: JobId| {
        ctx.queue
            .get(id)
            .map_or(false, |j| j.arrival <= ctx.now)
    };
    let mut parents: Vec<(JobId, f64)> = if ctx.active.is_empty() {
        tracker
            .parents()
            .filter(|(_, p)| !p.is_complete())
            .filter(|&(&id, _)| arrived(id))
            .map(|(&id, p)| (id, p.remaining()))
            .collect()
    } else {
        ctx.active
            .iter()
            .filter(|&&id| arrived(id))
            .filter_map(|&id| {
                tracker
                    .parent(id)
                    .filter(|p| !p.is_complete())
                    .map(|p| (id, p.remaining()))
            })
            .collect()
    };
    parents.sort_by(|a, b| b.1.total_cmp(&a.1));
    parents
}

/// Slot inventory: one whole-node slot per node, or one slot per
/// (node, pool) in partial-node mode. Slots of one node are adjacent and
/// in pool (type) order, so single-pool clusters produce the identical
/// slot list in both modes.
fn build_slots<'a>(nodes: &[&'a Node], share_nodes: bool)
                   -> Vec<GangSlot<'a>> {
    let mut slots: Vec<GangSlot> = Vec::new();
    for (hi, &node) in nodes.iter().enumerate() {
        if share_nodes {
            for (g, c) in node.gang() {
                slots.push(GangSlot {
                    hi,
                    node,
                    pool: Some((g, c)),
                });
            }
        } else {
            slots.push(GangSlot {
                hi,
                node,
                pool: None,
            });
        }
    }
    slots
}

/// FNV-1a signature of everything a cached gang row depends on besides
/// the job itself: the gang mode, the slot count, and each slot's host
/// id plus booked `(type, count)` pools. Any cluster event that changes
/// the inventory (join, leave, capacity) changes this, which is the row
/// cache's whole invalidation story. Never returns `0` in practice (the
/// offset basis is folded in), so `0` doubles as the "no cache yet"
/// sentinel.
fn slots_sig(slots: &[GangSlot], share_nodes: bool) -> u64 {
    fn eat(h: u64, v: u64) -> u64 {
        (h ^ v).wrapping_mul(0x100000001b3)
    }
    let mut h = eat(0xcbf29ce484222325, share_nodes as u64);
    h = eat(h, slots.len() as u64);
    for s in slots {
        h = eat(h, s.node.id as u64);
        match s.pool {
            Some((g, c)) => {
                h = eat(h, 1);
                h = eat(h, g as u64);
                h = eat(h, c as u64);
            }
            None => {
                h = eat(h, 2);
                for (g, c) in s.node.gang() {
                    h = eat(h, g as u64);
                    h = eat(h, c as u64);
                }
            }
        }
    }
    h
}

/// Gang rate of `job` on one slot — the matrix cell.
fn slot_rate(job: &Job, slot: &GangSlot, cfg: &GangConfig) -> f64 {
    match slot.pool {
        Some((g, c)) => pool_throughput(job, g, c, cfg),
        None => gang_throughput(job, slot.node, cfg),
    }
}

/// One parent's gang row over the slot inventory; an unknown job id
/// yields an all-zero (never placeable) row, like the dense matrix.
fn row_for(job: Option<&Job>, slots: &[GangSlot],
           cfg: &GangConfig) -> Vec<f64> {
    match job {
        Some(j) => {
            slots.iter().map(|s| slot_rate(j, s, cfg)).collect()
        }
        None => vec![0.0; slots.len()],
    }
}

/// Fetch-or-compute one parent's cached gang row, counting the hit or
/// miss. Split out as a free function (not a method) so callers can hold
/// `&mut` borrows of the cache and the stats while the planner's other
/// fields stay readable.
fn ensure_row<'m>(rows: &'m mut BTreeMap<JobId, Vec<f64>>,
                  stats: &mut WarmStats, pid: JobId, queue: &JobQueue,
                  slots: &[GangSlot], cfg: &GangConfig) -> &'m [f64] {
    use std::collections::btree_map::Entry;
    match rows.entry(pid) {
        Entry::Occupied(e) => {
            stats.rows_reused += 1;
            e.into_mut()
        }
        Entry::Vacant(v) => {
            stats.rows_computed += 1;
            v.insert(row_for(queue.get(pid), slots, cfg))
        }
    }
}

/// Build the full gang matrix (row-major `[pi * n_s + si]`, `0.0` marks
/// an unusable pair), sharded over contiguous parent chunks. Every cell
/// is a pure function of (job, slot) written into a disjoint chunk, so
/// the result is bit-identical to the serial build at any worker count.
/// Small inputs stay serial ([`SHARD_MIN_CELLS`]).
fn fill_matrix(parents: &[(JobId, f64)], slots: &[GangSlot],
               queue: &JobQueue, cfg: &GangConfig,
               threads: usize) -> Vec<f64> {
    let n_s = slots.len();
    let mut xg = vec![0.0f64; parents.len() * n_s];
    let fill = |chunk: &[(JobId, f64)], out: &mut [f64]| {
        for (i, &(pid, _)) in chunk.iter().enumerate() {
            if let Some(job) = queue.get(pid) {
                for (si, slot) in slots.iter().enumerate() {
                    out[i * n_s + si] = slot_rate(job, slot, cfg);
                }
            }
        }
    };
    if threads <= 1
        || parents.len() < 2
        || parents.len() * n_s < SHARD_MIN_CELLS
    {
        fill(parents, &mut xg);
        return xg;
    }
    let per = (parents.len() + threads - 1) / threads;
    let fill = &fill;
    std::thread::scope(|scope| {
        for (chunk, out) in
            parents.chunks(per).zip(xg.chunks_mut(per * n_s))
        {
            scope.spawn(move || fill(chunk, out));
        }
    });
    xg
}

/// Sort candidates by burn, descending — serially below
/// [`SHARD_MIN_CANDS`], else as per-chunk stable sorts over *contiguous*
/// chunks followed by a k-way merge. The merge only lets a later chunk's
/// head win on strictly-greater burn (`total_cmp == Greater`), so ties
/// resolve toward the earlier chunk — and since chunks are contiguous,
/// "earlier chunk, then within-chunk stable order" is exactly the
/// original-index tie order a serial stable sort produces. The sharded
/// result is therefore bit-identical to the serial one at any worker
/// count (unit-tested below).
fn sort_candidates(cands: &mut Vec<(f64, u32, u32)>, threads: usize) {
    if threads <= 1 || cands.len() < SHARD_MIN_CANDS {
        cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        return;
    }
    let per = (cands.len() + threads - 1) / threads;
    std::thread::scope(|scope| {
        for chunk in cands.chunks_mut(per) {
            scope.spawn(move || {
                chunk.sort_by(|a, b| b.0.total_cmp(&a.0));
            });
        }
    });
    let chunks: Vec<&[(f64, u32, u32)]> = cands.chunks(per).collect();
    let mut idx = vec![0usize; chunks.len()];
    let mut out = Vec::with_capacity(cands.len());
    loop {
        let mut best: Option<usize> = None;
        for (c, chunk) in chunks.iter().enumerate() {
            if idx[c] >= chunk.len() {
                continue;
            }
            match best {
                None => best = Some(c),
                Some(b) => {
                    if chunk[idx[c]]
                        .0
                        .total_cmp(&chunks[b][idx[b]].0)
                        == Ordering::Greater
                    {
                        best = Some(c);
                    }
                }
            }
        }
        let Some(b) = best else { break };
        out.push(chunks[b][idx[b]]);
        idx[b] += 1;
    }
    *cands = out;
}

impl HadarE {
    /// Planner with a per-parent copy budget and the default
    /// [`GangConfig`].
    pub fn new(copies: u64) -> Self {
        HadarE::with_gang(copies, GangConfig::default())
    }

    /// Planner with explicit gang-model knobs. The sharding worker count
    /// is resolved here, once, from `gang.plan_threads`
    /// environment override ([`crate::sched::resolve_plan_threads`]).
    pub fn with_gang(copies: u64, gang: GangConfig) -> Self {
        HadarE {
            copies,
            gang,
            stats: WarmStats::default(),
            threads: crate::sched::resolve_plan_threads(gang.plan_threads),
            rows: BTreeMap::new(),
            rows_sig: 0,
        }
    }

    /// The worker count this planner shards rounds across (resolved from
    /// [`GangConfig::plan_threads`] at construction).
    pub fn plan_threads(&self) -> usize {
        self.threads
    }

    /// Completion notification from the forking engine — the counterpart
    /// of [`crate::sched::Scheduler::job_completed`] for the gang
    /// planner: drops the parent's cached gang row, keeping the warm
    /// cache bounded by the *live* parent count on long traces.
    pub fn job_completed(&mut self, parent: JobId) {
        self.rows.remove(&parent);
    }

    /// Assign gang slots to parent jobs for this round, with no
    /// carry-over — exactly [`Self::plan_round_with`] under
    /// [`PrevRound::empty`], and bit-identical to the historical
    /// carry-over-blind planner.
    ///
    /// Returns a plan keyed by *copy id*: copy `i` of parent `p` on slot
    /// `s` means `s`'s host trains `p`'s model this slot on the slot's
    /// GPUs — **all** of the node's GPUs in whole-node mode, one pool of
    /// them under [`GangConfig::share_nodes`].
    pub fn plan_round(&mut self, ctx: &RoundCtx, tracker: &JobTracker)
                      -> RoundPlan {
        self.plan_round_with(ctx, tracker, &PrevRound::empty())
    }

    /// Warm-start round planning: the hot path the engines call. Same
    /// three passes as the cold reference (fairness, payoff-greedy, work
    /// conservation) over the same priority order, but parent gang rows
    /// come from the cross-round cache (computed lazily, only for
    /// parents a pass actually examines), candidate generation is
    /// restricted to slots still free after the fairness pass, and
    /// payoffs are carry-over-aware (`x · eff_secs`, see [`PrevRound`]).
    /// Produces plans **bit-identical** to
    /// [`Self::plan_round_cold`] on the same inputs — pinned by
    /// `rust/tests/prop_equivalence.rs` — while touching O(slots) rows
    /// per round in the streaming regime.
    pub fn plan_round_with(&mut self, ctx: &RoundCtx,
                           tracker: &JobTracker, prev: &PrevRound)
                           -> RoundPlan {
        let _span = crate::obs::trace::span("hadare.plan_round");
        if crate::obs::enabled() {
            crate::obs::metrics::core().hadare_plan_rounds.add(1);
        }
        self.stats.rounds += 1;
        let before = self.stats;

        let parents = sorted_parents(ctx, tracker);
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }

        // Node inventory: every node with at least one GPU.
        let nodes: Vec<&Node> = ctx
            .cluster
            .nodes
            .iter()
            .filter(|n| n.total_gpus() > 0)
            .collect();
        if nodes.is_empty() {
            return plan;
        }
        let slots = build_slots(&nodes, self.gang.share_nodes);
        if slots.is_empty() {
            return plan;
        }

        // Row-cache validity: any slot-inventory change (cluster event,
        // mode flip) clears every cached row. A round delta with zero
        // cluster events guarantees the inventory is unchanged since the
        // previous round, so the cached signature stays valid without
        // recomputing the FNV fold over every slot — the delta-fed
        // invalidation path. Anything else (no delta, events > 0, no
        // cache yet) recomputes and compares as before, so a caller that
        // replans from the full list gets identical behaviour.
        let sig = match ctx.delta {
            Some(d) if d.events == 0 && self.rows_sig != 0 => self.rows_sig,
            _ => slots_sig(&slots, self.gang.share_nodes),
        };
        if sig != self.rows_sig {
            if self.rows_sig != 0 {
                self.stats.invalidations += 1;
            }
            self.rows.clear();
            self.rows_sig = sig;
        }

        let n_p = parents.len();
        let n_s = slots.len();
        let binds = slot_binds(&slots, prev);
        // With no bindings at all, score by raw throughput and burn by
        // the full slot — the historical formulas, bitwise. (Scaling
        // every score by the same slot length could collapse historical
        // near-ties; gating on an actual binding keeps the degradation
        // exact.)
        let scaled = binds.iter().any(|b| *b != SlotBind::Free);
        let slot_secs = ctx.slot_secs;
        let oh = prev.restart_overhead;
        let gang = self.gang;
        let copies = self.copies;
        let rows = &mut self.rows;
        let stats = &mut self.stats;

        let mut slot_busy = vec![false; n_s];
        let mut copies_used = vec![0u64; n_p];
        // Sparse placed-on-node set (see `Tables` docs).
        let mut placed: BTreeSet<(u32, u32)> = BTreeSet::new();
        let mut free = n_s;

        let _placement_span = crate::obs::trace::span("hadare.placement");

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free slot (longest-remaining parent picks first), scored
        // by carry-over-effective work `x · eff`. Ties keep the last
        // slot in inventory order (the historical `max_by` semantics).
        // Once the inventory is exhausted no later parent can place
        // either, so the scan stops — the examined parents are a prefix
        // of the priority order, which is what caps a streaming round at
        // O(slots) scored rows.
        for pi in 0..n_p {
            if free == 0 {
                break;
            }
            if copies_used[pi] >= copies {
                continue;
            }
            let pid = parents[pi].0;
            let row =
                ensure_row(rows, stats, pid, ctx.queue, &slots, &gang);
            let mut best: Option<(usize, f64)> = None;
            for si in 0..n_s {
                if slot_busy[si]
                    || placed
                        .contains(&(pi as u32, slots[si].hi as u32))
                {
                    continue;
                }
                let x = row[si];
                if !(x > 0.0) {
                    continue;
                }
                let score = if scaled {
                    x * eff_secs(binds[si], pid, slot_secs, oh)
                } else {
                    x
                };
                if score > 0.0
                    && best.map_or(true, |(_, bs)| {
                        score.total_cmp(&bs) != Ordering::Less
                    })
                {
                    best = Some((si, score));
                }
            }
            if let Some((si, _)) = best {
                let i = copies_used[pi] + 1;
                plan.insert(tracker.ids.copy_id(pid, i),
                            slot_alloc(&slots[si]));
                slot_busy[si] = true;
                copies_used[pi] = i;
                placed.insert((pi as u32, slots[si].hi as u32));
                free -= 1;
            }
        }

        if free > 0 {
            // Candidate (burn, parent, slot) tuples, restricted to the
            // pairs pass 1 could still take: slots free after pass 0 and
            // parents with budget left. The skip predicates only grow
            // during pass 1 (busy/budget/placed are never un-set), so
            // every pair excluded here would be skipped there too — the
            // filtered, stable-sorted subsequence reproduces the cold
            // planner's placements exactly.
            let free_slots: Vec<u32> = (0..n_s as u32)
                .filter(|&si| !slot_busy[si as usize])
                .collect();
            let mut cands: Vec<(f64, u32, u32)> = Vec::new();
            for pi in 0..n_p {
                if copies_used[pi] >= copies {
                    continue;
                }
                let (pid, remaining) = parents[pi];
                let row = ensure_row(rows, stats, pid, ctx.queue,
                                     &slots, &gang);
                for &si in &free_slots {
                    if placed.contains(
                        &(pi as u32, slots[si as usize].hi as u32))
                    {
                        continue;
                    }
                    let x = row[si as usize];
                    if x > 0.0 {
                        let eff = eff_secs(binds[si as usize], pid,
                                           slot_secs, oh);
                        cands.push((
                            (x * eff).min(remaining),
                            pi as u32,
                            si,
                        ));
                    }
                }
            }
            sort_candidates(&mut cands, self.threads);

            // Pass 1: payoff-greedy with the per-parent copy budget
            // (live re-checks identical to the cold path).
            for &(_, pi, si) in &cands {
                let (pi, si) = (pi as usize, si as usize);
                if slot_busy[si]
                    || copies_used[pi] >= copies
                    || placed
                        .contains(&(pi as u32, slots[si].hi as u32))
                {
                    continue;
                }
                let pid = parents[pi].0;
                let i = copies_used[pi] + 1;
                plan.insert(tracker.ids.copy_id(pid, i),
                            slot_alloc(&slots[si]));
                slot_busy[si] = true;
                copies_used[pi] = i;
                placed.insert((pi as u32, slots[si].hi as u32));
                free -= 1;
            }

            // Pass 2: work conservation, kept faithfully from the cold
            // path (pass 1's candidate set covers every usable pair, so
            // this fills nothing pass 1 could not — it guards the
            // Theorem-3 corollary against future pass-1 changes). Cells
            // are probed singly, without caching a full row: caching
            // here could pin O(parents) rows on a slot nobody can use.
            if free > 0 {
                for si in 0..n_s {
                    if slot_busy[si] {
                        continue;
                    }
                    for pi in 0..n_p {
                        if placed.contains(
                            &(pi as u32, slots[si].hi as u32))
                            || copies_used[pi] >= copies
                        {
                            continue;
                        }
                        let pid = parents[pi].0;
                        let x = match rows.get(&pid) {
                            Some(row) => row[si],
                            None => ctx
                                .queue
                                .get(pid)
                                .map_or(0.0, |j| {
                                    slot_rate(j, &slots[si], &gang)
                                }),
                        };
                        if x > 0.0 {
                            let i = copies_used[pi] + 1;
                            plan.insert(tracker.ids.copy_id(pid, i),
                                        slot_alloc(&slots[si]));
                            slot_busy[si] = true;
                            copies_used[pi] = i;
                            placed
                                .insert((pi as u32, slots[si].hi as u32));
                            free -= 1;
                            break;
                        }
                    }
                }
            }
        }

        if crate::obs::enabled() {
            let m = crate::obs::metrics::core();
            m.hadare_warm_rows_computed
                .add(self.stats.rows_computed - before.rows_computed);
            m.hadare_warm_rows_reused
                .add(self.stats.rows_reused - before.rows_reused);
            m.hadare_warm_invalidations
                .add(self.stats.invalidations - before.invalidations);
        }
        plan
    }

    /// Cold reference planning: recompute the full gang matrix (sharded,
    /// [`fill_matrix`]) and run the three passes over dense tables, with
    /// the *same* carry-over payoff model as the warm path. This is what
    /// the equivalence property tests pin [`Self::plan_round_with`]
    /// against and what `sched::bench`'s `warm_*` rows use as the
    /// cold-replanning baseline; it touches no planner state (`&self`).
    pub fn plan_round_cold(&self, ctx: &RoundCtx, tracker: &JobTracker,
                           prev: &PrevRound) -> RoundPlan {
        let _span = crate::obs::trace::span("hadare.plan_round_cold");
        let parents = sorted_parents(ctx, tracker);
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }
        let nodes: Vec<&Node> = ctx
            .cluster
            .nodes
            .iter()
            .filter(|n| n.total_gpus() > 0)
            .collect();
        if nodes.is_empty() {
            return plan;
        }
        let slots = build_slots(&nodes, self.gang.share_nodes);
        if slots.is_empty() {
            return plan;
        }

        let n_p = parents.len();
        let n_h = nodes.len();
        let n_s = slots.len();

        // Gang-throughput matrix, row-major [pi * n_s + si]; 0.0 marks an
        // unusable (parent, slot) pair. Computed once — the passes below
        // only do flat indexed reads.
        let matrix_span = crate::obs::trace::span("hadare.gang_matrix");
        let xg = fill_matrix(&parents, &slots, ctx.queue, &self.gang,
                             self.threads);
        drop(matrix_span);

        let binds = slot_binds(&slots, prev);
        let scaled = binds.iter().any(|b| *b != SlotBind::Free);
        let slot_secs = ctx.slot_secs;
        let oh = prev.restart_overhead;

        let mut t = Tables::new(n_p, n_h, n_s);
        let _placement_span = crate::obs::trace::span("hadare.placement");

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free slot (longest-remaining parent picks first). Without
        // this, one long job hogs every fast slot and serialises the rest,
        // which is exactly what HadarE exists to avoid (§V-A: copies of
        // *all* jobs run concurrently). Ties keep the last slot in
        // inventory order (the historical `max_by` semantics).
        for pi in 0..n_p {
            if t.copies_used[pi] >= self.copies {
                continue;
            }
            let pid = parents[pi].0;
            let mut best: Option<(usize, f64)> = None;
            for si in 0..n_s {
                if t.slot_busy[si] || t.placed[pi * n_h + slots[si].hi] {
                    continue;
                }
                let x = xg[pi * n_s + si];
                if !(x > 0.0) {
                    continue;
                }
                let score = if scaled {
                    x * eff_secs(binds[si], pid, slot_secs, oh)
                } else {
                    x
                };
                if score > 0.0
                    && best.map_or(true, |(_, bs)| {
                        score.total_cmp(&bs) != Ordering::Less
                    })
                {
                    best = Some((si, score));
                }
            }
            if let Some((si, _)) = best {
                t.place(&mut plan, tracker, pid, pi, si, &slots[si]);
            }
        }

        // Build all candidate (burn, parent idx, slot idx) tuples. Burn is
        // the throughput-weighted urgency — how much of the remaining work
        // this slot's gang can complete this round after any model reload
        // — the greedy core of Hadar's price argument specialised to gang
        // slots.
        let mut cands: Vec<(f64, u32, u32)> =
            Vec::with_capacity(n_p * n_s);
        for (pi, &(pid, remaining)) in parents.iter().enumerate() {
            for si in 0..n_s {
                let x = xg[pi * n_s + si];
                if x > 0.0 {
                    let eff = eff_secs(binds[si], pid, slot_secs, oh);
                    cands.push((
                        (x * eff).min(remaining),
                        pi as u32,
                        si as u32,
                    ));
                }
            }
        }
        sort_candidates(&mut cands, self.threads);

        // Pass 1: payoff-greedy with the per-parent copy budget.
        for &(_, pi, si) in &cands {
            let (pi, si) = (pi as usize, si as usize);
            if t.slot_busy[si]
                || t.copies_used[pi] >= self.copies
                || t.placed[pi * n_h + slots[si].hi]
            {
                continue;
            }
            t.place(&mut plan, tracker, parents[pi].0, pi, si, &slots[si]);
        }

        // Pass 2: work conservation — fill any idle slot with the parent
        // owning the most remaining work not already on that slot's node
        // (corollary to Theorem 3: no idle slot before the last round).
        for si in 0..n_s {
            if t.slot_busy[si] {
                continue;
            }
            for pi in 0..n_p {
                if t.placed[pi * n_h + slots[si].hi]
                    || t.copies_used[pi] >= self.copies
                {
                    continue;
                }
                if xg[pi * n_s + si] > 0.0 {
                    t.place(&mut plan, tracker, parents[pi].0, pi, si,
                            &slots[si]);
                    break;
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::forking::forker::ForkIds;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;
    use crate::jobs::throughput;
    use crate::trace::workload::cluster_gpu_pcie;
    use std::collections::BTreeMap;

    fn setup_on(cluster: ClusterSpec, n_parents: u64, copies: u64)
                -> (ClusterSpec, JobQueue, JobTracker) {
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..n_parents {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=copies)
                    .map(|i| ids.copy_id(j.id, i))
                    .collect::<Vec<_>>(),
            );
            queue.admit(j).unwrap();
        }
        (cluster, queue, tracker)
    }

    fn setup(n_parents: u64) -> (ClusterSpec, JobQueue, JobTracker) {
        setup_on(ClusterSpec::testbed5(), n_parents, 5)
    }

    fn ctx<'a>(queue: &'a JobQueue, cluster: &'a ClusterSpec)
               -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active: &[],
            delta: None,
            cluster,
        }
    }

    #[test]
    fn single_job_occupies_all_nodes() {
        // The paper's headline: one remaining job, five nodes, five copies
        // running concurrently (Hadar would use one node).
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        let nodes: std::collections::BTreeSet<usize> = plan
            .allocations
            .values()
            .flat_map(|a| a.nodes())
            .collect();
        assert_eq!(nodes.len(), 5, "all five nodes busy");
        // All copies resolve to the same parent.
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0));
        }
    }

    #[test]
    fn no_idle_node_with_multiple_jobs() {
        let (cluster, queue, tracker) = setup(3);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5, "5 nodes, 5 copies");
        // At most one copy of a parent per node; parents spread.
        let mut per_node: BTreeMap<usize, Vec<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            for n in a.nodes() {
                per_node.entry(n).or_default().push(tracker.resolve(*id));
            }
        }
        for (_, ps) in per_node {
            assert_eq!(ps.len(), 1);
        }
    }

    #[test]
    fn copy_budget_respected() {
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(2); // only 2 copies allowed
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 2);
    }

    #[test]
    fn finished_parents_release_all_nodes() {
        let (cluster, queue, mut tracker) = setup(2);
        // Parent 0 completes.
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1));
        }
        assert_eq!(plan.scheduled_jobs().len(), 5);
    }

    #[test]
    fn all_complete_yields_empty_plan() {
        let (cluster, queue, mut tracker) = setup(1);
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(plan.scheduled_jobs().is_empty());
    }

    #[test]
    fn sim60_round0_plan_occupies_all_60_gpus() {
        // The bugfix's acceptance criterion: on the 15-node × 4-GPU
        // simulated cluster, a round-0 plan with unfinished parents
        // covers every GPU, not one per node.
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::sim60(), 3, 15);
        let mut h = HadarE::new(15);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 60, "whole-node gangs cover 60 GPUs");
        assert_eq!(plan.scheduled_jobs().len(), 15, "one copy per node");
        for (_, alloc) in &plan.allocations {
            assert_eq!(alloc.total_gpus(), 4, "each copy takes a full node");
            assert_eq!(alloc.nodes().len(), 1, "a copy never spans nodes");
        }
    }

    #[test]
    fn big8_shared_round0_books_every_gpu_with_shared_nodes() {
        // The tentpole's planner-level acceptance: on the two-pool
        // 8-GPU-node preset with two active parents, per-pool slots book
        // all 32 GPUs and at least one node hosts copies of two parents
        // (whole-node gangs would hand each node to a single parent).
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::big8(), 2, 4);
        let mut h = HadarE::with_gang(4, GangConfig::shared());
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 32, "every GPU booked");
        assert_eq!(plan.scheduled_jobs().len(), 8, "one copy per pool");
        let mut parents_by_node: BTreeMap<usize,
            std::collections::BTreeSet<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            assert_eq!(a.nodes().len(), 1, "a copy never spans nodes");
            assert_eq!(a.gpu_types().len(), 1, "a copy takes one pool");
            assert_eq!(a.total_gpus(), 4, "a pool is 4 GPUs here");
            parents_by_node
                .entry(a.nodes()[0])
                .or_default()
                .insert(tracker.resolve(*id));
        }
        assert!(
            parents_by_node.values().any(|ps| ps.len() >= 2),
            "at least one big node is shared by two parents: {:?}",
            parents_by_node
        );
    }

    #[test]
    fn big8_whole_node_gangs_monopolise_nodes() {
        // Compatibility mode on the same preset: each copy takes all 8
        // GPUs of its host, so nodes are never shared — the fragmentation
        // the tentpole removes.
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::big8(), 2, 4);
        let mut h = HadarE::new(4);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 32);
        assert_eq!(plan.scheduled_jobs().len(), 4, "one copy per node");
        for (_, a) in &plan.allocations {
            assert_eq!(a.total_gpus(), 8, "whole-node gang");
        }
    }

    #[test]
    fn shared_mode_is_identical_on_single_pool_clusters() {
        // On clusters whose nodes carry one pool (every paper preset),
        // per-pool slots coincide with whole-node slots — the two modes
        // must plan identically.
        for cluster in [ClusterSpec::testbed5(), ClusterSpec::sim60()] {
            let copies = cluster.nodes.len() as u64;
            let (cluster, queue, tracker) =
                setup_on(cluster, 3, copies);
            let whole = HadarE::new(copies)
                .plan_round(&ctx(&queue, &cluster), &tracker);
            let shared = HadarE::with_gang(copies, GangConfig::shared())
                .plan_round(&ctx(&queue, &cluster), &tracker);
            assert_eq!(whole.allocations, shared.allocations,
                       "{}: modes diverged", cluster.name);
        }
    }

    #[test]
    fn unarrived_parents_are_not_planned() {
        // Arrival-handling regression (planner side): a parent with
        // arrival > now is filtered even though the tracker knows it.
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let arrival = if id == 0 { 0.0 } else { 500.0 };
            let mut j = Job::new(id, DlModel::MiMa, arrival, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j).unwrap();
        }
        let mut h = HadarE::new(5);
        // now = 0: only parent 0 exists.
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(!plan.scheduled_jobs().is_empty());
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0),
                       "unarrived parent must not train");
        }
        // now = 500: both planned.
        let mut c = ctx(&queue, &cluster);
        c.now = 500.0;
        let plan = h.plan_round(&c, &tracker);
        let parents: std::collections::BTreeSet<JobId> = plan
            .scheduled_jobs()
            .iter()
            .map(|&id| tracker.resolve(id))
            .collect();
        assert_eq!(parents.len(), 2, "both parents run once arrived");
    }

    #[test]
    fn pool_and_alloc_throughput_match_the_gang_model() {
        use crate::cluster::gpu::{GpuType, PcieGen};
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 1, 100);
        j.set_throughput(GpuType::K80, 10.0);
        j.set_throughput(GpuType::V100, 40.0);
        let cfg = GangConfig::default();
        // A 4-GPU pool scales sub-linearly like a 4-GPU single-type node.
        assert!((pool_throughput(&j, GpuType::K80, 4, &cfg) - 37.0).abs()
                < 1e-9);
        assert_eq!(pool_throughput(&j, GpuType::K80, 0, &cfg), 0.0);
        assert_eq!(pool_throughput(&j, GpuType::T4, 2, &cfg), 0.0,
                   "missing row is unusable");
        // min_efficiency floor applies per pool.
        let strict = GangConfig {
            min_efficiency: 0.5,
            ..GangConfig::default()
        };
        assert_eq!(pool_throughput(&j, GpuType::K80, 4, &strict), 0.0);
        assert!(pool_throughput(&j, GpuType::V100, 4, &strict) > 0.0);
        // alloc_throughput of a whole-node allocation equals
        // gang_throughput of the host; of a one-pool allocation, the
        // pool rate.
        let node = Node::new(
            0,
            "big",
            &[(GpuType::K80, 4), (GpuType::V100, 4)],
            PcieGen::Gen3,
        );
        let mut whole = JobAllocation::new();
        for (g, c) in node.gang() {
            whole.add(node.id, g, c);
        }
        assert!((alloc_throughput(&j, &whole, &cfg)
                 - gang_throughput(&j, &node, &cfg))
                    .abs()
                < 1e-12);
        let mut one_pool = JobAllocation::new();
        one_pool.add(node.id, GpuType::V100, 4);
        assert!((alloc_throughput(&j, &one_pool, &cfg)
                 - pool_throughput(&j, GpuType::V100, 4, &cfg))
                    .abs()
                < 1e-12);
    }

    #[test]
    fn gang_throughput_is_sublinear_and_bottlenecked() {
        use crate::cluster::gpu::{GpuType, PcieGen};
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 1, 100);
        j.set_throughput(GpuType::K80, 10.0);
        j.set_throughput(GpuType::V100, 40.0);
        let cfg = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
            ..GangConfig::default()
        };
        let one = Node::new(0, "k1", &[(GpuType::K80, 1)], PcieGen::Gen3);
        let four = Node::new(1, "k4", &[(GpuType::K80, 4)], PcieGen::Gen3);
        let x1 = gang_throughput(&j, &one, &cfg);
        let x4 = gang_throughput(&j, &four, &cfg);
        assert!((x1 - 10.0).abs() < 1e-12, "single GPU = per-GPU rate");
        assert!((x4 - 10.0 * 3.7).abs() < 1e-9, "4 GPUs at 0.9 marginal");
        assert!(x4 < 4.0 * x1, "not naively 4x");
        // Bottleneck all-or-nothing: a mixed node with one unusable type
        // is unusable as a whole.
        let mut k80_only = j.clone();
        k80_only.throughput.remove(&GpuType::V100);
        let mixed = Node::new(
            2,
            "mix",
            &[(GpuType::K80, 2), (GpuType::V100, 2)],
            PcieGen::Gen3,
        );
        assert_eq!(gang_throughput(&k80_only, &mixed, &cfg), 0.0);
        // min_efficiency floor rejects the slow node for a V100-anchored
        // job: 10 < 0.5 * 40.
        let strict = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.5,
            ..GangConfig::default()
        };
        assert_eq!(gang_throughput(&j, &four, &strict), 0.0);
    }

    #[test]
    fn nan_throughput_parent_is_never_scheduled() {
        // NaN-comparator regression (mirrors hadar.rs's
        // nan_and_zero_throughput_rows_are_never_scheduled): a parent
        // whose row is NaN must neither panic the round nor be placed;
        // well-formed parents still fill the cluster.
        use crate::cluster::gpu::GpuType;
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            if id == 0 {
                for g in GpuType::ALL {
                    j.set_throughput(g, f64::NAN);
                }
            }
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j).unwrap();
        }
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1),
                       "only the well-formed parent runs");
        }
    }

    #[test]
    fn sharded_candidate_sort_matches_serial_stable_sort() {
        // Many duplicated burn values force the tie path: the k-way
        // merge must reproduce the serial stable sort bit-for-bit,
        // including the original-index order among equals.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xCAFE);
        let n = SHARD_MIN_CANDS + 1234;
        let mut cands: Vec<(f64, u32, u32)> = (0..n)
            .map(|i| {
                // 16 distinct burn values over ~17k entries → ~1k-deep
                // tie classes.
                let burn = (rng.below(16) as f64) * 0.5;
                (burn, i as u32, (i % 97) as u32)
            })
            .collect();
        let mut serial = cands.clone();
        serial.sort_by(|a, b| b.0.total_cmp(&a.0));
        for threads in [2, 3, 8] {
            let mut sharded = cands.clone();
            sort_candidates(&mut sharded, threads);
            assert_eq!(sharded, serial, "threads={threads}");
        }
        // Below the size floor the serial path runs regardless.
        cands.truncate(100);
        let mut small = cands.clone();
        sort_candidates(&mut small, 8);
        cands.sort_by(|a, b| b.0.total_cmp(&a.0));
        assert_eq!(small, cands);
    }

    #[test]
    fn warm_plan_matches_cold_plan_with_carried_bindings() {
        // Smoke version of the prop test: two rounds on sim60, the
        // second with the first round's bindings carried over — warm and
        // cold paths must agree exactly, and the second warm round must
        // hit the row cache.
        let (cluster, queue, mut tracker) =
            setup_on(ClusterSpec::sim60(), 4, 15);
        let mut warm = HadarE::new(15);
        let c0 = ctx(&queue, &cluster);
        let p0 = warm.plan_round_with(&c0, &tracker, &PrevRound::empty());
        assert_eq!(
            p0.allocations,
            warm.plan_round_cold(&c0, &tracker, &PrevRound::empty())
                .allocations
        );
        let prev = PrevRound::from_plan(&p0, &tracker, 30.0);
        assert!(!prev.is_empty());
        assert_eq!(prev.len(), 15, "every (node, pool) bound");
        // Unequal progress so round 1's priority order shifts.
        for (i, (&copy, _)) in p0.allocations.iter().enumerate() {
            tracker.report_steps(copy, 10.0 * i as f64);
        }
        let mut c1 = ctx(&queue, &cluster);
        c1.now = 360.0;
        let reused_before = warm.stats.rows_reused;
        let pw = warm.plan_round_with(&c1, &tracker, &prev);
        let pc = warm.plan_round_cold(&c1, &tracker, &prev);
        assert_eq!(pw.allocations, pc.allocations,
                   "warm and cold diverged under carried bindings");
        assert!(warm.stats.rows_reused > reused_before,
                "second round must reuse cached rows");
        assert_eq!(warm.stats.invalidations, 0);
    }

    #[test]
    fn inventory_change_invalidates_row_cache() {
        let (mut cluster, queue, tracker) =
            setup_on(ClusterSpec::sim60(), 3, 15);
        let mut warm = HadarE::new(15);
        let _ = warm.plan_round(&ctx(&queue, &cluster), &tracker);
        let computed_round0 = warm.stats.rows_computed;
        assert!(computed_round0 > 0);
        let victim = cluster.nodes[0].id;
        cluster.remove_node(victim);
        let plan = warm.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(warm.stats.invalidations, 1,
                   "node removal must clear the row cache");
        assert!(warm.stats.rows_computed > computed_round0,
                "rows rebuilt against the new inventory");
        for (_, a) in &plan.allocations {
            assert!(!a.nodes().contains(&victim),
                    "no placement on the removed node");
        }
        // Completion drops the parent's row: the next round recomputes
        // only for live parents.
        warm.job_completed(JobId(0));
        assert!(!warm.rows.contains_key(&JobId(0)));
    }

    #[test]
    fn carried_bindings_keep_parents_on_their_loaded_gangs() {
        // The switch-cost model in action: two single-GPU nodes, fast
        // (V100, x=40) and slow (K80, x=10); two parents, each with its
        // model loaded on one node, and a restart overhead eating 90% of
        // the slot. Blind planning moves the longer job onto the fast
        // node (two reloads); carry-over-aware planning keeps both
        // parents where their models are loaded.
        use crate::cluster::gpu::{GpuType, PcieGen};
        let cluster = ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "fast", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "slow", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        );
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::K80, 10.0);
            tracker.register(j.id, j.total_iters(),
                             &[ids.copy_id(j.id, 1)]);
            queue.admit(j).unwrap();
        }
        // Parent 1 has less work left → parent 0 picks first.
        tracker.report_steps(ids.copy_id(JobId(1), 1), 500.0);
        let mut h = HadarE::new(1);
        let c = ctx(&queue, &cluster);

        // Blind: parent 0 (longest) takes the fast node.
        let blind = h.plan_round_with(&c, &tracker, &PrevRound::empty());
        let on = |plan: &RoundPlan, copy: JobId| {
            plan.allocations.get(&copy).unwrap().nodes()[0]
        };
        assert_eq!(on(&blind, ids.copy_id(JobId(0), 1)), 0);
        assert_eq!(on(&blind, ids.copy_id(JobId(1), 1)), 1);

        // Loaded models: parent 0 on the slow node, parent 1 on the
        // fast one. Overhead 324s of a 360s slot → switching to the fast
        // node only trains 36s: 40·36 < 10·360, staying wins.
        let mut prev = PrevRound::new(324.0);
        prev.bind(0, GpuType::V100, JobId(1));
        prev.bind(1, GpuType::K80, JobId(0));
        let warm = h.plan_round_with(&c, &tracker, &prev);
        assert_eq!(on(&warm, ids.copy_id(JobId(0), 1)), 1,
                   "parent 0 stays on its loaded slow node");
        assert_eq!(on(&warm, ids.copy_id(JobId(1), 1)), 0,
                   "parent 1 stays on its loaded fast node");
        // Cold reference agrees, of course.
        let cold = h.plan_round_cold(&c, &tracker, &prev);
        assert_eq!(warm.allocations, cold.allocations);
    }

    #[test]
    fn stale_bindings_for_absent_nodes_are_ignored() {
        // Churn safety at the planner level: bindings referencing nodes
        // that left the cluster (or never existed) change nothing.
        let (cluster, queue, tracker) = setup(2);
        let mut h = HadarE::new(5);
        let c = ctx(&queue, &cluster);
        let clean = h.plan_round_with(&c, &tracker, &PrevRound::empty());
        let mut stale = PrevRound::new(30.0);
        stale.bind(999, GpuType::V100, JobId(0));
        stale.bind(998, GpuType::K80, JobId(1));
        let with_stale = h.plan_round_with(&c, &tracker, &stale);
        assert_eq!(clean.allocations, with_stale.allocations,
                   "bindings to absent nodes must be inert");
        for (_, a) in &with_stale.allocations {
            assert!(a.nodes().iter().all(|&n| n < 900));
        }
    }
}
