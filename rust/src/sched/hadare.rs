//! **HadarE** (paper §V) — Hadar enhanced with job forking.
//!
//! Every unfinished parent job has `n` forked copies (for an `n`-node
//! cluster); each round HadarE assigns *whole nodes* to copies so that no
//! node idles while any parent has work left (Theorem 3 / its corollary).
//! A copy scheduled on node `h` occupies **every GPU of `h`** — the
//! per-pool counts come from the node spec ([`Node::gang`]), not from a
//! single representative slot, so on a multi-GPU cluster (`sim60`'s
//! 15 × 4-GPU nodes) a round-0 plan covers all 60 GPUs, not 15.
//!
//! Scheduling reuses Hadar's machinery over the copy queue with two extra
//! constraints:
//!
//! * at most one copy of a given parent per node (copies exist to run on
//!   *separate* nodes);
//! * work-conservation: after the payoff-driven pass, any still-idle node
//!   is given a copy of the parent with the most remaining work that is
//!   not yet on that node.
//!
//! ## Gang throughput
//!
//! A whole-node gang's rate ([`gang_throughput`]) follows the same rules
//! Hadar applies to its gangs:
//!
//! * **bottleneck (Eq. 1b)** — every GPU in the gang advances at the
//!   slowest *usable* type's pace; a node carrying any type the job
//!   cannot run on (zero/NaN throughput) is unusable as a whole;
//! * **`min_efficiency`** — same semantics as
//!   [`crate::sched::hadar::HadarConfig::min_efficiency`]: a bottleneck
//!   below that fraction of the job's best single-GPU throughput rejects
//!   the node outright;
//! * **sub-linear scaling** — each GPU beyond the first contributes only
//!   [`GangConfig::marginal_efficiency`] of a full GPU (intra-node
//!   data-parallel sync overhead, the within-node analogue of Hadar's
//!   `comm_factor`), so a 4×K80 node is *not* naively 4× a 1×K80 node.
//!
//! On single-GPU nodes the gang rate degenerates to the per-GPU
//! throughput exactly, which is why the pre-rework planner — frozen as
//! [`crate::sched::reference::RefHadarE`] — is pinned plan-for-plan to
//! this one on `aws5`/`testbed5` by `rust/tests/prop_equivalence.rs`.
//!
//! §Perf: `plan_round` follows the PR-3 zero-clone idiom — the per-round
//! `BTreeMap`s (`node_load`, `copies_used`, `placed_on`) are flat
//! `Vec`-indexed tables, the gang-throughput matrix is computed once per
//! (parent, node) pair, and placement is a method instead of a
//! seven-argument closure. `sched::bench` (`fork_*` cases) times it
//! against the frozen reference.
//!
//! The engines call [`HadarE::plan_round`] with the tracker state; step
//! division + aggregation + consolidation happen in the engine through the
//! [`crate::forking::JobTracker`].

use crate::cluster::node::Node;
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::RoundCtx;
use std::cmp::Ordering;

/// Knobs of the whole-node gang throughput model (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct GangConfig {
    /// Fraction of a full GPU each GPU beyond the first contributes to
    /// the gang rate: `rate = x_min · (1 + marginal_efficiency·(n−1))`.
    /// `1.0` = perfectly linear scaling; the default models the intra-node
    /// gradient-sync overhead of data-parallel training.
    pub marginal_efficiency: f64,
    /// Reject nodes whose bottleneck throughput is below this fraction of
    /// the job's best single-GPU throughput — identical semantics to
    /// [`crate::sched::hadar::HadarConfig::min_efficiency`].
    pub min_efficiency: f64,
}

impl Default for GangConfig {
    fn default() -> Self {
        GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
        }
    }
}

/// Iterations/second of `job` when one forked copy occupies the whole of
/// `node` (see the module docs for the model). Returns `0.0` when the
/// node is unusable for the job: no GPUs, any pool with zero/NaN
/// throughput (bottleneck all-or-nothing), or a bottleneck below the
/// `min_efficiency` floor.
pub fn gang_throughput(job: &Job, node: &Node, cfg: &GangConfig) -> f64 {
    let mut n_gpus = 0usize;
    let mut x_min = f64::INFINITY;
    for (g, c) in node.gang() {
        let x = job.throughput_on(g);
        // NaN fails the `>` too: a malformed row makes the node unusable
        // rather than poisoning the plan.
        if !(x > 0.0) {
            return 0.0;
        }
        x_min = x_min.min(x);
        n_gpus += c;
    }
    if n_gpus == 0 || !x_min.is_finite() {
        return 0.0;
    }
    if x_min < cfg.min_efficiency * job.max_throughput() {
        return 0.0;
    }
    x_min * (1.0 + cfg.marginal_efficiency * (n_gpus - 1) as f64)
}

/// The HadarE whole-node planner (see module docs).
pub struct HadarE {
    /// Copies per job (usually = node count; Theorem 3's maximum).
    pub copies: u64,
    /// Gang throughput model (bottleneck + sub-linear scaling).
    pub gang: GangConfig,
}

/// Per-round placement tables, flat `Vec`s indexed by parent/node
/// *position* (node ids need not be contiguous under cluster events).
/// This is the zero-clone replacement for the three `BTreeMap`s the
/// pre-rework planner probed per candidate.
struct Tables {
    /// Node at index `hi` already hosts a copy this round.
    node_busy: Vec<bool>,
    /// Copies handed out so far per parent index.
    copies_used: Vec<u64>,
    /// `placed[pi * n_nodes + hi]`: parent `pi` already has a copy on
    /// node `hi`.
    placed: Vec<bool>,
    /// Row stride of `placed`.
    n_nodes: usize,
}

impl Tables {
    fn new(n_parents: usize, n_nodes: usize) -> Self {
        Tables {
            node_busy: vec![false; n_nodes],
            copies_used: vec![0; n_parents],
            placed: vec![false; n_parents * n_nodes],
            n_nodes,
        }
    }

    /// Place the next copy of `pid` on `node`, occupying its whole gang.
    fn place(&mut self, plan: &mut RoundPlan, tracker: &JobTracker,
             pid: JobId, pi: usize, hi: usize, node: &Node) {
        let i = self.copies_used[pi] + 1;
        let copy = tracker.ids.copy_id(pid, i);
        let mut alloc = JobAllocation::new();
        for (g, c) in node.gang() {
            alloc.add(node.id, g, c);
        }
        plan.insert(copy, alloc);
        self.node_busy[hi] = true;
        self.copies_used[pi] = i;
        self.placed[pi * self.n_nodes + hi] = true;
    }
}

impl HadarE {
    /// Planner with a per-parent copy budget and the default
    /// [`GangConfig`].
    pub fn new(copies: u64) -> Self {
        HadarE {
            copies,
            gang: GangConfig::default(),
        }
    }

    /// Planner with explicit gang-model knobs.
    pub fn with_gang(copies: u64, gang: GangConfig) -> Self {
        HadarE { copies, gang }
    }

    /// Completion notification from the forking engine — the counterpart
    /// of [`crate::sched::Scheduler::job_completed`] for the whole-node
    /// planner. The planner keeps no per-parent caches today (every round
    /// is planned from the tracker's live state), so this is a no-op; it
    /// exists so both engines speak the same completion protocol and any
    /// future per-parent planner state has one place to be dropped.
    pub fn job_completed(&mut self, _parent: JobId) {}

    /// Assign nodes to parent jobs for this round.
    ///
    /// Returns a plan keyed by *copy id*: copy `i` of parent `p` on node
    /// `h` means node `h` trains `p`'s model this slot on **all** of its
    /// GPUs (whole-node gang).
    pub fn plan_round(&mut self, ctx: &RoundCtx, tracker: &JobTracker)
                      -> RoundPlan {
        // Parents with work left, by remaining steps (desc; total_cmp so
        // a degenerate row cannot panic the round, stable sort keeps id
        // order on ties).
        let mut parents: Vec<(JobId, f64)> = tracker
            .parents()
            .filter(|(_, p)| !p.is_complete())
            .map(|(&id, p)| (id, p.remaining()))
            .collect();
        parents.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }

        // Node inventory: every node with at least one GPU.
        let nodes: Vec<&Node> = ctx
            .cluster
            .nodes
            .iter()
            .filter(|n| n.total_gpus() > 0)
            .collect();
        if nodes.is_empty() {
            return plan;
        }

        let n_p = parents.len();
        let n_h = nodes.len();

        // Gang-throughput matrix, row-major [pi * n_h + hi]; 0.0 marks an
        // unusable (parent, node) pair. Computed once — the passes below
        // only do flat indexed reads.
        let mut xg = vec![0.0f64; n_p * n_h];
        for (pi, &(pid, _)) in parents.iter().enumerate() {
            if let Some(job) = ctx.queue.get(pid) {
                for (hi, &node) in nodes.iter().enumerate() {
                    xg[pi * n_h + hi] = gang_throughput(job, node, &self.gang);
                }
            }
        }

        let mut t = Tables::new(n_p, n_h);

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free node (longest-remaining parent picks first). Without
        // this, one long job hogs every fast node and serialises the rest,
        // which is exactly what HadarE exists to avoid (§V-A: copies of
        // *all* jobs run concurrently). Ties keep the last node in
        // inventory order (the historical `max_by` semantics).
        for pi in 0..n_p {
            if t.copies_used[pi] >= self.copies {
                continue;
            }
            let mut best: Option<(usize, f64)> = None;
            for hi in 0..n_h {
                if t.node_busy[hi] {
                    continue;
                }
                let x = xg[pi * n_h + hi];
                if x > 0.0
                    && best
                        .map_or(true, |(_, bx)| {
                            x.total_cmp(&bx) != Ordering::Less
                        })
                {
                    best = Some((hi, x));
                }
            }
            if let Some((hi, _)) = best {
                t.place(&mut plan, tracker, parents[pi].0, pi, hi,
                        nodes[hi]);
            }
        }

        // Build all candidate (burn, parent idx, node idx) tuples. Burn is
        // the throughput-weighted urgency — how much of the remaining work
        // this node's gang can complete this slot — the greedy core of
        // Hadar's price argument specialised to whole-node slots.
        let mut cands: Vec<(f64, u32, u32)> =
            Vec::with_capacity(n_p * n_h);
        for (pi, &(_, remaining)) in parents.iter().enumerate() {
            for hi in 0..n_h {
                let x = xg[pi * n_h + hi];
                if x > 0.0 {
                    let burn = (x * ctx.slot_secs).min(remaining);
                    cands.push((burn, pi as u32, hi as u32));
                }
            }
        }
        cands.sort_by(|a, b| b.0.total_cmp(&a.0));

        // Pass 1: payoff-greedy with the per-parent copy budget.
        for &(_, pi, hi) in &cands {
            let (pi, hi) = (pi as usize, hi as usize);
            if t.node_busy[hi]
                || t.copies_used[pi] >= self.copies
                || t.placed[pi * n_h + hi]
            {
                continue;
            }
            t.place(&mut plan, tracker, parents[pi].0, pi, hi, nodes[hi]);
        }

        // Pass 2: work conservation — fill any idle node with the parent
        // owning the most remaining work not already on that node
        // (corollary to Theorem 3: no idle node before the last round).
        for hi in 0..n_h {
            if t.node_busy[hi] {
                continue;
            }
            for pi in 0..n_p {
                if t.placed[pi * n_h + hi]
                    || t.copies_used[pi] >= self.copies
                {
                    continue;
                }
                if xg[pi * n_h + hi] > 0.0 {
                    t.place(&mut plan, tracker, parents[pi].0, pi, hi,
                            nodes[hi]);
                    break;
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::forking::forker::ForkIds;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;
    use crate::jobs::throughput;
    use crate::trace::workload::cluster_gpu_pcie;
    use std::collections::BTreeMap;

    fn setup_on(cluster: ClusterSpec, n_parents: u64, copies: u64)
                -> (ClusterSpec, JobQueue, JobTracker) {
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..n_parents {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=copies)
                    .map(|i| ids.copy_id(j.id, i))
                    .collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        (cluster, queue, tracker)
    }

    fn setup(n_parents: u64) -> (ClusterSpec, JobQueue, JobTracker) {
        setup_on(ClusterSpec::testbed5(), n_parents, 5)
    }

    fn ctx<'a>(queue: &'a JobQueue, cluster: &'a ClusterSpec)
               -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active: &[],
            cluster,
        }
    }

    #[test]
    fn single_job_occupies_all_nodes() {
        // The paper's headline: one remaining job, five nodes, five copies
        // running concurrently (Hadar would use one node).
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        let nodes: std::collections::BTreeSet<usize> = plan
            .allocations
            .values()
            .flat_map(|a| a.nodes())
            .collect();
        assert_eq!(nodes.len(), 5, "all five nodes busy");
        // All copies resolve to the same parent.
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0));
        }
    }

    #[test]
    fn no_idle_node_with_multiple_jobs() {
        let (cluster, queue, tracker) = setup(3);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5, "5 nodes, 5 copies");
        // At most one copy of a parent per node; parents spread.
        let mut per_node: BTreeMap<usize, Vec<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            for n in a.nodes() {
                per_node.entry(n).or_default().push(tracker.resolve(*id));
            }
        }
        for (_, ps) in per_node {
            assert_eq!(ps.len(), 1);
        }
    }

    #[test]
    fn copy_budget_respected() {
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(2); // only 2 copies allowed
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 2);
    }

    #[test]
    fn finished_parents_release_all_nodes() {
        let (cluster, queue, mut tracker) = setup(2);
        // Parent 0 completes.
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1));
        }
        assert_eq!(plan.scheduled_jobs().len(), 5);
    }

    #[test]
    fn all_complete_yields_empty_plan() {
        let (cluster, queue, mut tracker) = setup(1);
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(plan.scheduled_jobs().is_empty());
    }

    #[test]
    fn sim60_round0_plan_occupies_all_60_gpus() {
        // The bugfix's acceptance criterion: on the 15-node × 4-GPU
        // simulated cluster, a round-0 plan with unfinished parents
        // covers every GPU, not one per node.
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::sim60(), 3, 15);
        let mut h = HadarE::new(15);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 60, "whole-node gangs cover 60 GPUs");
        assert_eq!(plan.scheduled_jobs().len(), 15, "one copy per node");
        for (_, alloc) in &plan.allocations {
            assert_eq!(alloc.total_gpus(), 4, "each copy takes a full node");
            assert_eq!(alloc.nodes().len(), 1, "a copy never spans nodes");
        }
    }

    #[test]
    fn gang_throughput_is_sublinear_and_bottlenecked() {
        use crate::cluster::gpu::{GpuType, PcieGen};
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 1, 100);
        j.set_throughput(GpuType::K80, 10.0);
        j.set_throughput(GpuType::V100, 40.0);
        let cfg = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
        };
        let one = Node::new(0, "k1", &[(GpuType::K80, 1)], PcieGen::Gen3);
        let four = Node::new(1, "k4", &[(GpuType::K80, 4)], PcieGen::Gen3);
        let x1 = gang_throughput(&j, &one, &cfg);
        let x4 = gang_throughput(&j, &four, &cfg);
        assert!((x1 - 10.0).abs() < 1e-12, "single GPU = per-GPU rate");
        assert!((x4 - 10.0 * 3.7).abs() < 1e-9, "4 GPUs at 0.9 marginal");
        assert!(x4 < 4.0 * x1, "not naively 4x");
        // Bottleneck all-or-nothing: a mixed node with one unusable type
        // is unusable as a whole.
        let mut k80_only = j.clone();
        k80_only.throughput.remove(&GpuType::V100);
        let mixed = Node::new(
            2,
            "mix",
            &[(GpuType::K80, 2), (GpuType::V100, 2)],
            PcieGen::Gen3,
        );
        assert_eq!(gang_throughput(&k80_only, &mixed, &cfg), 0.0);
        // min_efficiency floor rejects the slow node for a V100-anchored
        // job: 10 < 0.5 * 40.
        let strict = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.5,
        };
        assert_eq!(gang_throughput(&j, &four, &strict), 0.0);
    }

    #[test]
    fn nan_throughput_parent_is_never_scheduled() {
        // NaN-comparator regression (mirrors hadar.rs's
        // nan_and_zero_throughput_rows_are_never_scheduled): a parent
        // whose row is NaN must neither panic the round nor be placed;
        // well-formed parents still fill the cluster.
        use crate::cluster::gpu::GpuType;
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            if id == 0 {
                for g in GpuType::ALL {
                    j.set_throughput(g, f64::NAN);
                }
            }
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1),
                       "only the well-formed parent runs");
        }
    }
}
