//! **HadarE** (paper §V) — Hadar enhanced with job forking.
//!
//! Every unfinished parent job has `n` forked copies (for an `n`-node
//! cluster); each round HadarE assigns *whole nodes* to copies so that no
//! node idles while any parent has work left (Theorem 3 / its corollary).
//! Scheduling itself reuses Hadar's machinery over the copy queue with two
//! extra constraints:
//!
//! * at most one copy of a given parent per node (copies exist to run on
//!   *separate* nodes);
//! * work-conservation: after the payoff-driven pass, any still-idle node
//!   is given a copy of the parent with the most remaining work that is
//!   not yet on that node.
//!
//! The engines call [`HadarE::plan_round`] with the tracker state; step
//! division + aggregation + consolidation happen in the engine through the
//! [`crate::forking::JobTracker`].

use crate::cluster::gpu::GpuType;
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::RoundCtx;
use std::collections::BTreeMap;

/// The HadarE whole-node planner (see module docs).
pub struct HadarE {
    /// Copies per job (usually = node count; Theorem 3's maximum).
    pub copies: u64,
}

impl HadarE {
    /// Planner with a per-parent copy budget.
    pub fn new(copies: u64) -> Self {
        HadarE { copies }
    }

    /// Completion notification from the forking engine — the counterpart
    /// of [`crate::sched::Scheduler::job_completed`] for the whole-node
    /// planner. The planner keeps no per-parent caches today (every round
    /// is planned from the tracker's live state), so this is a no-op; it
    /// exists so both engines speak the same completion protocol and any
    /// future per-parent planner state has one place to be dropped.
    pub fn job_completed(&mut self, _parent: JobId) {}

    /// Assign nodes to parent jobs for this round.
    ///
    /// Returns a plan keyed by *copy id*: copy `i` of parent `p` on node
    /// `h` means node `h` trains `p`'s model this slot. All single-GPU
    /// nodes (the paper's §VI clusters) — one copy occupies one node.
    pub fn plan_round(&mut self, ctx: &RoundCtx, tracker: &JobTracker)
                      -> RoundPlan {
        // Parents with work left, by remaining steps (desc).
        let mut parents: Vec<(JobId, f64)> = tracker
            .parents()
            .filter(|(_, p)| !p.is_complete())
            .map(|(&id, p)| (id, p.remaining()))
            .collect();
        parents.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }

        // Node inventory: (node id, gpu type) — single-GPU nodes.
        let nodes: Vec<(usize, GpuType)> = ctx
            .cluster
            .nodes
            .iter()
            .filter_map(|n| n.primary_gpu().map(|g| (n.id, g)))
            .collect();

        // Payoff of placing parent p on node (h, g): throughput-weighted
        // urgency — remaining work × speed, the greedy core of Hadar's
        // price argument specialised to whole-node slots.
        let job_of = |id: JobId| -> Option<&Job> { ctx.queue.get(id) };
        let mut node_load: BTreeMap<usize, bool> = BTreeMap::new();
        let mut copies_used: BTreeMap<JobId, u64> = BTreeMap::new();
        let mut placed_on: BTreeMap<(JobId, usize), bool> = BTreeMap::new();

        let place = |pid: JobId, h: usize, g: GpuType,
                         plan: &mut RoundPlan,
                         node_load: &mut BTreeMap<usize, bool>,
                         copies_used: &mut BTreeMap<JobId, u64>,
                         placed_on: &mut BTreeMap<(JobId, usize), bool>| {
            let i = copies_used.get(&pid).copied().unwrap_or(0) + 1;
            let copy = tracker.ids.copy_id(pid, i);
            let mut alloc = JobAllocation::new();
            alloc.add(h, g, 1);
            plan.insert(copy, alloc);
            node_load.insert(h, true);
            copies_used.insert(pid, i);
            placed_on.insert((pid, h), true);
        };

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free node (longest-remaining parent picks first). Without
        // this, one long job hogs every fast node and serialises the rest,
        // which is exactly what HadarE exists to avoid (§V-A: copies of
        // *all* jobs run concurrently).
        for &(pid, _) in &parents {
            if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                continue;
            }
            let best = nodes
                .iter()
                .filter(|&&(h, _)| !node_load.get(&h).unwrap_or(&false))
                .filter_map(|&(h, g)| {
                    job_of(pid).map(|j| (h, g, j.throughput_on(g)))
                })
                .filter(|&(_, _, x)| x > 0.0)
                .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
            if let Some((h, g, _)) = best {
                place(pid, h, g, &mut plan, &mut node_load,
                      &mut copies_used, &mut placed_on);
            }
        }

        // Build all candidate (score, parent, node, gpu) tuples.
        let mut cands: Vec<(f64, JobId, usize, GpuType)> = Vec::new();
        for &(pid, remaining) in &parents {
            if let Some(job) = job_of(pid) {
                for &(h, g) in &nodes {
                    let x = job.throughput_on(g);
                    if x > 0.0 {
                        // Urgency: how much of the remaining work this
                        // node can burn this slot.
                        let burn = (x * ctx.slot_secs).min(remaining);
                        cands.push((burn, pid, h, g));
                    }
                }
            }
        }
        cands.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

        // Pass 1: payoff-greedy with the per-parent copy budget.
        for &(_, pid, h, g) in &cands {
            if *node_load.get(&h).unwrap_or(&false) {
                continue;
            }
            if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                continue;
            }
            if placed_on.contains_key(&(pid, h)) {
                continue;
            }
            place(pid, h, g, &mut plan, &mut node_load, &mut copies_used,
                  &mut placed_on);
        }

        // Pass 2: work conservation — fill any idle node with the parent
        // owning the most remaining work not already on that node
        // (corollary to Theorem 3: no idle node before the last round).
        for &(h, g) in &nodes {
            if *node_load.get(&h).unwrap_or(&false) {
                continue;
            }
            for &(pid, _) in &parents {
                if placed_on.contains_key(&(pid, h)) {
                    continue;
                }
                if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                    continue;
                }
                let ok = job_of(pid)
                    .map(|j| j.throughput_on(g) > 0.0)
                    .unwrap_or(false);
                if ok {
                    let i = copies_used.get(&pid).copied().unwrap_or(0) + 1;
                    let copy = tracker.ids.copy_id(pid, i);
                    let mut alloc = JobAllocation::new();
                    alloc.add(h, g, 1);
                    plan.insert(copy, alloc);
                    node_load.insert(h, true);
                    copies_used.insert(pid, i);
                    placed_on.insert((pid, h), true);
                    break;
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::forking::forker::ForkIds;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;
    use crate::jobs::throughput;
    use crate::trace::workload::cluster_gpu_pcie;

    fn setup(n_parents: u64) -> (ClusterSpec, JobQueue, JobTracker) {
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..n_parents {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        (cluster, queue, tracker)
    }

    fn ctx<'a>(queue: &'a JobQueue, cluster: &'a ClusterSpec)
               -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active: &[],
            cluster,
        }
    }

    #[test]
    fn single_job_occupies_all_nodes() {
        // The paper's headline: one remaining job, five nodes, five copies
        // running concurrently (Hadar would use one node).
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        let nodes: std::collections::BTreeSet<usize> = plan
            .allocations
            .values()
            .flat_map(|a| a.nodes())
            .collect();
        assert_eq!(nodes.len(), 5, "all five nodes busy");
        // All copies resolve to the same parent.
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0));
        }
    }

    #[test]
    fn no_idle_node_with_multiple_jobs() {
        let (cluster, queue, tracker) = setup(3);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5, "5 nodes, 5 copies");
        // At most one copy of a parent per node; parents spread.
        let mut per_node: BTreeMap<usize, Vec<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            for n in a.nodes() {
                per_node.entry(n).or_default().push(tracker.resolve(*id));
            }
        }
        for (_, ps) in per_node {
            assert_eq!(ps.len(), 1);
        }
    }

    #[test]
    fn copy_budget_respected() {
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(2); // only 2 copies allowed
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 2);
    }

    #[test]
    fn finished_parents_release_all_nodes() {
        let (cluster, queue, mut tracker) = setup(2);
        // Parent 0 completes.
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1));
        }
        assert_eq!(plan.scheduled_jobs().len(), 5);
    }

    #[test]
    fn all_complete_yields_empty_plan() {
        let (cluster, queue, mut tracker) = setup(1);
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(plan.scheduled_jobs().is_empty());
    }
}
