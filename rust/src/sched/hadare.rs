//! **HadarE** (paper §V) — Hadar enhanced with job forking.
//!
//! Every unfinished parent job has `n` forked copies (for an `n`-node
//! cluster); each round HadarE assigns **gang slots** to copies so that no
//! *node* idles while any parent has work left (Theorem 3 / its
//! corollary; see the shared-mode caveat below for why conservation is
//! per node, not per slot). What a slot is depends on
//! [`GangConfig::share_nodes`]:
//!
//! * `share_nodes = false` (**whole-node compatibility mode**, the
//!   default): one slot per node; a copy scheduled on node `h` occupies
//!   **every GPU of `h`** — the per-pool counts come from the node spec
//!   ([`Node::gang`]), so on a multi-GPU cluster (`sim60`'s 15 × 4-GPU
//!   nodes) a round-0 plan covers all 60 GPUs, not 15.
//! * `share_nodes = true` (**partial-node / per-pool mode**): one slot
//!   per `(node, pool)` — a copy occupies one GPU pool of its host, so
//!   two or more parents can share a big node in the same round. On an
//!   8-GPU two-pool node, whole-node gangs let one parent monopolise the
//!   node while other parents queue — exactly the fragmentation-driven
//!   under-utilization Hadar/HadarE exist to eliminate (PAPER.md §V,
//!   Theorem 3); per-pool slots hand each pool to a different parent.
//!   On clusters whose nodes carry a single pool (every paper preset:
//!   `aws5`, `testbed5`, `sim60`, `scaled:NxG`) the two modes coincide
//!   slot-for-slot and produce identical plans.
//!
//!   Caveat: the one-copy-per-parent-per-*node* rule still applies, so
//!   with fewer active parents than pools per node some pools idle (a
//!   lone surviving parent holds at most one pool of each node, where a
//!   whole-node gang would hold them all). Work conservation in shared
//!   mode is therefore per *node*, not per slot; idle pools book no
//!   GPU-seconds, so CRU (busy/allocated) is unaffected, but the
//!   single-parent tail of a trace can drain slower than under
//!   whole-node gangs. Same-parent multi-pool sub-gangs are the
//!   ROADMAP's named follow-up.
//!
//! Scheduling reuses Hadar's machinery over the copy queue with two extra
//! constraints:
//!
//! * at most one copy of a given parent per **node** (copies exist to run
//!   on *separate* machines — two pools of one node never host two copies
//!   of the same parent, that would consolidate a model with itself);
//! * work-conservation: after the payoff-driven pass, any still-idle slot
//!   is given a copy of the parent with the most remaining work that is
//!   not yet on that slot's node.
//!
//! Parents are planned only once they have **arrived** (`job.arrival <=
//! ctx.now`): the forking engine registers every parent with the tracker
//! up front, so the planner filters by arrival rather than training jobs
//! before they exist.
//!
//! ## Gang throughput
//!
//! A gang's rate — [`gang_throughput`] for a whole node,
//! [`pool_throughput`] for one pool, [`alloc_throughput`] for whatever a
//! plan actually booked — follows the same rules Hadar applies to its
//! gangs:
//!
//! * **bottleneck (Eq. 1b)** — every GPU in the gang advances at the
//!   slowest *usable* type's pace; a node carrying any type the job
//!   cannot run on (zero/NaN throughput) is unusable as a whole;
//! * **`min_efficiency`** — same semantics as
//!   [`crate::sched::hadar::HadarConfig::min_efficiency`]: a bottleneck
//!   below that fraction of the job's best single-GPU throughput rejects
//!   the node outright;
//! * **sub-linear scaling** — each GPU beyond the first contributes only
//!   [`GangConfig::marginal_efficiency`] of a full GPU (intra-node
//!   data-parallel sync overhead, the within-node analogue of Hadar's
//!   `comm_factor`), so a 4×K80 node is *not* naively 4× a 1×K80 node.
//!
//! On single-GPU nodes the gang rate degenerates to the per-GPU
//! throughput exactly, which is why the pre-rework planner — frozen as
//! [`crate::sched::reference::RefHadarE`] — is pinned plan-for-plan to
//! this one on `aws5`/`testbed5` by `rust/tests/prop_equivalence.rs`.
//!
//! §Perf: `plan_round` follows the PR-3 zero-clone idiom — the per-round
//! `BTreeMap`s (`node_load`, `copies_used`, `placed_on`) are flat
//! `Vec`-indexed tables, the gang-throughput matrix is computed once per
//! (parent, node) pair, and placement is a method instead of a
//! seven-argument closure. `sched::bench` (`fork_*` cases) times it
//! against the frozen reference.
//!
//! The engines call [`HadarE::plan_round`] with the tracker state; step
//! division + aggregation + consolidation happen in the engine through the
//! [`crate::forking::JobTracker`].

use crate::cluster::gpu::GpuType;
use crate::cluster::node::Node;
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::RoundCtx;
use std::cmp::Ordering;

/// Knobs of the gang throughput/placement model (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct GangConfig {
    /// Fraction of a full GPU each GPU beyond the first contributes to
    /// the gang rate: `rate = x_min · (1 + marginal_efficiency·(n−1))`.
    /// `1.0` = perfectly linear scaling; the default models the intra-node
    /// gradient-sync overhead of data-parallel training.
    pub marginal_efficiency: f64,
    /// Reject gangs whose bottleneck throughput is below this fraction of
    /// the job's best single-GPU throughput — identical semantics to
    /// [`crate::sched::hadar::HadarConfig::min_efficiency`].
    pub min_efficiency: f64,
    /// Partial-node mode: plan per-`(node, pool)` sub-gangs so several
    /// parents can share a big node. `false` (the default) is the
    /// whole-node compatibility mode, pinned plan-for-plan to
    /// [`crate::sched::reference::RefHadarE`] on single-GPU clusters by
    /// `rust/tests/prop_equivalence.rs`.
    pub share_nodes: bool,
}

impl Default for GangConfig {
    fn default() -> Self {
        GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
            share_nodes: false,
        }
    }
}

impl GangConfig {
    /// The partial-node (per-pool) configuration with the default
    /// throughput knobs — what the `hadare-shared` sweep scheduler runs.
    pub fn shared() -> Self {
        GangConfig {
            share_nodes: true,
            ..GangConfig::default()
        }
    }
}

/// Shared tail of the gang rate model, so the three public rating
/// functions cannot drift apart: a bottleneck of `x_min` it/s over
/// `n_gpus` GPUs — empty gangs and zero/NaN/infinite bottlenecks are
/// unusable, the `min_efficiency` floor rejects wasteful placements, and
/// each GPU beyond the first contributes `marginal_efficiency` of a full
/// one.
fn scaled_rate(job: &Job, x_min: f64, n_gpus: usize,
               cfg: &GangConfig) -> f64 {
    // NaN fails the `>` too: a malformed row makes the gang unusable
    // rather than poisoning the plan.
    if n_gpus == 0 || !(x_min > 0.0) || !x_min.is_finite() {
        return 0.0;
    }
    if x_min < cfg.min_efficiency * job.max_throughput() {
        return 0.0;
    }
    x_min * (1.0 + cfg.marginal_efficiency * (n_gpus - 1) as f64)
}

/// Iterations/second of `job` when one forked copy occupies the whole of
/// `node` (see the module docs for the model). Returns `0.0` when the
/// node is unusable for the job: no GPUs, any pool with zero/NaN
/// throughput (bottleneck all-or-nothing), or a bottleneck below the
/// `min_efficiency` floor.
pub fn gang_throughput(job: &Job, node: &Node, cfg: &GangConfig) -> f64 {
    let mut n_gpus = 0usize;
    let mut x_min = f64::INFINITY;
    for (g, c) in node.gang() {
        let x = job.throughput_on(g);
        // The early return (not `min`, which would discard a NaN) makes
        // any unusable pool poison the whole node.
        if !(x > 0.0) {
            return 0.0;
        }
        x_min = x_min.min(x);
        n_gpus += c;
    }
    scaled_rate(job, x_min, n_gpus, cfg)
}

/// Iterations/second of `job` when one forked copy occupies a single
/// `count`-GPU pool of type `gpu` — the per-pool slot of partial-node
/// mode. Same model as [`gang_throughput`] with a one-type gang: no
/// bottleneck across pools (the copy touches only this one), the
/// `min_efficiency` floor, and sub-linear multi-GPU scaling. Returns
/// `0.0` for an empty pool or a zero/NaN throughput row.
pub fn pool_throughput(job: &Job, gpu: GpuType, count: usize,
                       cfg: &GangConfig) -> f64 {
    scaled_rate(job, job.throughput_on(gpu), count, cfg)
}

/// Iterations/second of `job` on whatever sub-gang `alloc` actually
/// booked: the bottleneck rule across the allocation's pools, the
/// `min_efficiency` floor, and sub-linear scaling over its total GPU
/// count. For a whole-node allocation this equals [`gang_throughput`] of
/// the host; for a per-pool allocation it equals [`pool_throughput`] of
/// that pool. The forking engine rates every scheduled copy through this,
/// so its accounting is mode-agnostic.
pub fn alloc_throughput(job: &Job, alloc: &JobAllocation,
                        cfg: &GangConfig) -> f64 {
    let mut n_gpus = 0usize;
    let mut x_min = f64::INFINITY;
    for (&(_, g), &c) in alloc.slots.iter() {
        let x = job.throughput_on(g);
        if !(x > 0.0) {
            return 0.0;
        }
        x_min = x_min.min(x);
        n_gpus += c;
    }
    scaled_rate(job, x_min, n_gpus, cfg)
}

/// The HadarE gang planner (see module docs): whole-node slots by
/// default, per-`(node, pool)` slots under [`GangConfig::share_nodes`].
pub struct HadarE {
    /// Copies per job (usually = node count; Theorem 3's maximum).
    pub copies: u64,
    /// Gang throughput model (bottleneck + sub-linear scaling) and the
    /// whole-node vs per-pool placement mode.
    pub gang: GangConfig,
}

/// One placeable gang slot: a whole node (compatibility mode) or a
/// single GPU pool of it (partial-node mode).
struct GangSlot<'a> {
    /// Index into the planner's node inventory — the at-most-one-copy-
    /// per-parent-per-**node** exclusion is keyed by this, not by slot.
    hi: usize,
    /// The host node.
    node: &'a Node,
    /// `Some((type, count))` books that pool only; `None` books the
    /// node's whole gang.
    pool: Option<(GpuType, usize)>,
}

/// Per-round placement tables, flat `Vec`s indexed by parent/slot/node
/// *position* (node ids need not be contiguous under cluster events).
/// This is the zero-clone replacement for the three `BTreeMap`s the
/// pre-rework planner probed per candidate.
struct Tables {
    /// Slot at index `si` already hosts a copy this round.
    slot_busy: Vec<bool>,
    /// Copies handed out so far per parent index.
    copies_used: Vec<u64>,
    /// `placed[pi * n_nodes + hi]`: parent `pi` already has a copy on
    /// node `hi` (on *any* of its pools).
    placed: Vec<bool>,
    /// Row stride of `placed`.
    n_nodes: usize,
}

impl Tables {
    fn new(n_parents: usize, n_nodes: usize, n_slots: usize) -> Self {
        Tables {
            slot_busy: vec![false; n_slots],
            copies_used: vec![0; n_parents],
            placed: vec![false; n_parents * n_nodes],
            n_nodes,
        }
    }

    /// Place the next copy of `pid` on `slot`, occupying its pool (or the
    /// host's whole gang in compatibility mode).
    fn place(&mut self, plan: &mut RoundPlan, tracker: &JobTracker,
             pid: JobId, pi: usize, si: usize, slot: &GangSlot) {
        let i = self.copies_used[pi] + 1;
        let copy = tracker.ids.copy_id(pid, i);
        let mut alloc = JobAllocation::new();
        match slot.pool {
            Some((g, c)) => alloc.add(slot.node.id, g, c),
            None => {
                for (g, c) in slot.node.gang() {
                    alloc.add(slot.node.id, g, c);
                }
            }
        }
        plan.insert(copy, alloc);
        self.slot_busy[si] = true;
        self.copies_used[pi] = i;
        self.placed[pi * self.n_nodes + slot.hi] = true;
    }
}

impl HadarE {
    /// Planner with a per-parent copy budget and the default
    /// [`GangConfig`].
    pub fn new(copies: u64) -> Self {
        HadarE {
            copies,
            gang: GangConfig::default(),
        }
    }

    /// Planner with explicit gang-model knobs.
    pub fn with_gang(copies: u64, gang: GangConfig) -> Self {
        HadarE { copies, gang }
    }

    /// Completion notification from the forking engine — the counterpart
    /// of [`crate::sched::Scheduler::job_completed`] for the whole-node
    /// planner. The planner keeps no per-parent caches today (every round
    /// is planned from the tracker's live state), so this is a no-op; it
    /// exists so both engines speak the same completion protocol and any
    /// future per-parent planner state has one place to be dropped.
    pub fn job_completed(&mut self, _parent: JobId) {}

    /// Assign gang slots to parent jobs for this round.
    ///
    /// Returns a plan keyed by *copy id*: copy `i` of parent `p` on slot
    /// `s` means `s`'s host trains `p`'s model this slot on the slot's
    /// GPUs — **all** of the node's GPUs in whole-node mode, one pool of
    /// them under [`GangConfig::share_nodes`].
    pub fn plan_round(&mut self, ctx: &RoundCtx, tracker: &JobTracker)
                      -> RoundPlan {
        let _span = crate::obs::trace::span("hadare.plan_round");
        if crate::obs::enabled() {
            crate::obs::metrics::core().hadare_plan_rounds.add(1);
        }
        // Parents with work left that have *arrived*, by remaining steps
        // (desc; total_cmp so a degenerate row cannot panic the round,
        // stable sort keeps id order on ties). The engine registers every
        // parent with the tracker up front, so arrival gates here — a
        // parent with `arrival > now` must not train before it exists.
        let mut parents: Vec<(JobId, f64)> = tracker
            .parents()
            .filter(|(_, p)| !p.is_complete())
            .filter(|&(&id, _)| {
                ctx.queue
                    .get(id)
                    .map_or(false, |j| j.arrival <= ctx.now)
            })
            .map(|(&id, p)| (id, p.remaining()))
            .collect();
        parents.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }

        // Node inventory: every node with at least one GPU.
        let nodes: Vec<&Node> = ctx
            .cluster
            .nodes
            .iter()
            .filter(|n| n.total_gpus() > 0)
            .collect();
        if nodes.is_empty() {
            return plan;
        }

        // Slot inventory: one whole-node slot per node, or one slot per
        // (node, pool) in partial-node mode. Slots of one node are
        // adjacent and in pool (type) order, so single-pool clusters
        // produce the identical slot list in both modes.
        let mut slots: Vec<GangSlot> = Vec::new();
        for (hi, &node) in nodes.iter().enumerate() {
            if self.gang.share_nodes {
                for (g, c) in node.gang() {
                    slots.push(GangSlot {
                        hi,
                        node,
                        pool: Some((g, c)),
                    });
                }
            } else {
                slots.push(GangSlot {
                    hi,
                    node,
                    pool: None,
                });
            }
        }
        if slots.is_empty() {
            return plan;
        }

        let n_p = parents.len();
        let n_h = nodes.len();
        let n_s = slots.len();

        // Gang-throughput matrix, row-major [pi * n_s + si]; 0.0 marks an
        // unusable (parent, slot) pair. Computed once — the passes below
        // only do flat indexed reads.
        let matrix_span = crate::obs::trace::span("hadare.gang_matrix");
        let mut xg = vec![0.0f64; n_p * n_s];
        for (pi, &(pid, _)) in parents.iter().enumerate() {
            if let Some(job) = ctx.queue.get(pid) {
                for (si, slot) in slots.iter().enumerate() {
                    xg[pi * n_s + si] = match slot.pool {
                        Some((g, c)) => {
                            pool_throughput(job, g, c, &self.gang)
                        }
                        None => gang_throughput(job, slot.node, &self.gang),
                    };
                }
            }
        }

        drop(matrix_span);

        let mut t = Tables::new(n_p, n_h, n_s);
        let _placement_span = crate::obs::trace::span("hadare.placement");

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free slot (longest-remaining parent picks first). Without
        // this, one long job hogs every fast slot and serialises the rest,
        // which is exactly what HadarE exists to avoid (§V-A: copies of
        // *all* jobs run concurrently). Ties keep the last slot in
        // inventory order (the historical `max_by` semantics).
        for pi in 0..n_p {
            if t.copies_used[pi] >= self.copies {
                continue;
            }
            let mut best: Option<(usize, f64)> = None;
            for si in 0..n_s {
                if t.slot_busy[si] || t.placed[pi * n_h + slots[si].hi] {
                    continue;
                }
                let x = xg[pi * n_s + si];
                if x > 0.0
                    && best
                        .map_or(true, |(_, bx)| {
                            x.total_cmp(&bx) != Ordering::Less
                        })
                {
                    best = Some((si, x));
                }
            }
            if let Some((si, _)) = best {
                t.place(&mut plan, tracker, parents[pi].0, pi, si,
                        &slots[si]);
            }
        }

        // Build all candidate (burn, parent idx, slot idx) tuples. Burn is
        // the throughput-weighted urgency — how much of the remaining work
        // this slot's gang can complete this round — the greedy core of
        // Hadar's price argument specialised to gang slots.
        let mut cands: Vec<(f64, u32, u32)> =
            Vec::with_capacity(n_p * n_s);
        for (pi, &(_, remaining)) in parents.iter().enumerate() {
            for si in 0..n_s {
                let x = xg[pi * n_s + si];
                if x > 0.0 {
                    let burn = (x * ctx.slot_secs).min(remaining);
                    cands.push((burn, pi as u32, si as u32));
                }
            }
        }
        cands.sort_by(|a, b| b.0.total_cmp(&a.0));

        // Pass 1: payoff-greedy with the per-parent copy budget.
        for &(_, pi, si) in &cands {
            let (pi, si) = (pi as usize, si as usize);
            if t.slot_busy[si]
                || t.copies_used[pi] >= self.copies
                || t.placed[pi * n_h + slots[si].hi]
            {
                continue;
            }
            t.place(&mut plan, tracker, parents[pi].0, pi, si, &slots[si]);
        }

        // Pass 2: work conservation — fill any idle slot with the parent
        // owning the most remaining work not already on that slot's node
        // (corollary to Theorem 3: no idle slot before the last round).
        for si in 0..n_s {
            if t.slot_busy[si] {
                continue;
            }
            for pi in 0..n_p {
                if t.placed[pi * n_h + slots[si].hi]
                    || t.copies_used[pi] >= self.copies
                {
                    continue;
                }
                if xg[pi * n_s + si] > 0.0 {
                    t.place(&mut plan, tracker, parents[pi].0, pi, si,
                            &slots[si]);
                    break;
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::forking::forker::ForkIds;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;
    use crate::jobs::throughput;
    use crate::trace::workload::cluster_gpu_pcie;
    use std::collections::BTreeMap;

    fn setup_on(cluster: ClusterSpec, n_parents: u64, copies: u64)
                -> (ClusterSpec, JobQueue, JobTracker) {
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..n_parents {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=copies)
                    .map(|i| ids.copy_id(j.id, i))
                    .collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        (cluster, queue, tracker)
    }

    fn setup(n_parents: u64) -> (ClusterSpec, JobQueue, JobTracker) {
        setup_on(ClusterSpec::testbed5(), n_parents, 5)
    }

    fn ctx<'a>(queue: &'a JobQueue, cluster: &'a ClusterSpec)
               -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active: &[],
            cluster,
        }
    }

    #[test]
    fn single_job_occupies_all_nodes() {
        // The paper's headline: one remaining job, five nodes, five copies
        // running concurrently (Hadar would use one node).
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        let nodes: std::collections::BTreeSet<usize> = plan
            .allocations
            .values()
            .flat_map(|a| a.nodes())
            .collect();
        assert_eq!(nodes.len(), 5, "all five nodes busy");
        // All copies resolve to the same parent.
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0));
        }
    }

    #[test]
    fn no_idle_node_with_multiple_jobs() {
        let (cluster, queue, tracker) = setup(3);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5, "5 nodes, 5 copies");
        // At most one copy of a parent per node; parents spread.
        let mut per_node: BTreeMap<usize, Vec<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            for n in a.nodes() {
                per_node.entry(n).or_default().push(tracker.resolve(*id));
            }
        }
        for (_, ps) in per_node {
            assert_eq!(ps.len(), 1);
        }
    }

    #[test]
    fn copy_budget_respected() {
        let (cluster, queue, tracker) = setup(1);
        let mut h = HadarE::new(2); // only 2 copies allowed
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 2);
    }

    #[test]
    fn finished_parents_release_all_nodes() {
        let (cluster, queue, mut tracker) = setup(2);
        // Parent 0 completes.
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1));
        }
        assert_eq!(plan.scheduled_jobs().len(), 5);
    }

    #[test]
    fn all_complete_yields_empty_plan() {
        let (cluster, queue, mut tracker) = setup(1);
        tracker.report_steps(JobId(0), 1e9);
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(plan.scheduled_jobs().is_empty());
    }

    #[test]
    fn sim60_round0_plan_occupies_all_60_gpus() {
        // The bugfix's acceptance criterion: on the 15-node × 4-GPU
        // simulated cluster, a round-0 plan with unfinished parents
        // covers every GPU, not one per node.
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::sim60(), 3, 15);
        let mut h = HadarE::new(15);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 60, "whole-node gangs cover 60 GPUs");
        assert_eq!(plan.scheduled_jobs().len(), 15, "one copy per node");
        for (_, alloc) in &plan.allocations {
            assert_eq!(alloc.total_gpus(), 4, "each copy takes a full node");
            assert_eq!(alloc.nodes().len(), 1, "a copy never spans nodes");
        }
    }

    #[test]
    fn big8_shared_round0_books_every_gpu_with_shared_nodes() {
        // The tentpole's planner-level acceptance: on the two-pool
        // 8-GPU-node preset with two active parents, per-pool slots book
        // all 32 GPUs and at least one node hosts copies of two parents
        // (whole-node gangs would hand each node to a single parent).
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::big8(), 2, 4);
        let mut h = HadarE::with_gang(4, GangConfig::shared());
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 32, "every GPU booked");
        assert_eq!(plan.scheduled_jobs().len(), 8, "one copy per pool");
        let mut parents_by_node: BTreeMap<usize,
            std::collections::BTreeSet<JobId>> = BTreeMap::new();
        for (id, a) in &plan.allocations {
            assert_eq!(a.nodes().len(), 1, "a copy never spans nodes");
            assert_eq!(a.gpu_types().len(), 1, "a copy takes one pool");
            assert_eq!(a.total_gpus(), 4, "a pool is 4 GPUs here");
            parents_by_node
                .entry(a.nodes()[0])
                .or_default()
                .insert(tracker.resolve(*id));
        }
        assert!(
            parents_by_node.values().any(|ps| ps.len() >= 2),
            "at least one big node is shared by two parents: {:?}",
            parents_by_node
        );
    }

    #[test]
    fn big8_whole_node_gangs_monopolise_nodes() {
        // Compatibility mode on the same preset: each copy takes all 8
        // GPUs of its host, so nodes are never shared — the fragmentation
        // the tentpole removes.
        let (cluster, queue, tracker) =
            setup_on(ClusterSpec::big8(), 2, 4);
        let mut h = HadarE::new(4);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.total_gpus(), 32);
        assert_eq!(plan.scheduled_jobs().len(), 4, "one copy per node");
        for (_, a) in &plan.allocations {
            assert_eq!(a.total_gpus(), 8, "whole-node gang");
        }
    }

    #[test]
    fn shared_mode_is_identical_on_single_pool_clusters() {
        // On clusters whose nodes carry one pool (every paper preset),
        // per-pool slots coincide with whole-node slots — the two modes
        // must plan identically.
        for cluster in [ClusterSpec::testbed5(), ClusterSpec::sim60()] {
            let copies = cluster.nodes.len() as u64;
            let (cluster, queue, tracker) =
                setup_on(cluster, 3, copies);
            let whole = HadarE::new(copies)
                .plan_round(&ctx(&queue, &cluster), &tracker);
            let shared = HadarE::with_gang(copies, GangConfig::shared())
                .plan_round(&ctx(&queue, &cluster), &tracker);
            assert_eq!(whole.allocations, shared.allocations,
                       "{}: modes diverged", cluster.name);
        }
    }

    #[test]
    fn unarrived_parents_are_not_planned() {
        // Arrival-handling regression (planner side): a parent with
        // arrival > now is filtered even though the tracker knows it.
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let arrival = if id == 0 { 0.0 } else { 500.0 };
            let mut j = Job::new(id, DlModel::MiMa, arrival, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        let mut h = HadarE::new(5);
        // now = 0: only parent 0 exists.
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert!(!plan.scheduled_jobs().is_empty());
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(0),
                       "unarrived parent must not train");
        }
        // now = 500: both planned.
        let mut c = ctx(&queue, &cluster);
        c.now = 500.0;
        let plan = h.plan_round(&c, &tracker);
        let parents: std::collections::BTreeSet<JobId> = plan
            .scheduled_jobs()
            .iter()
            .map(|&id| tracker.resolve(id))
            .collect();
        assert_eq!(parents.len(), 2, "both parents run once arrived");
    }

    #[test]
    fn pool_and_alloc_throughput_match_the_gang_model() {
        use crate::cluster::gpu::{GpuType, PcieGen};
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 1, 100);
        j.set_throughput(GpuType::K80, 10.0);
        j.set_throughput(GpuType::V100, 40.0);
        let cfg = GangConfig::default();
        // A 4-GPU pool scales sub-linearly like a 4-GPU single-type node.
        assert!((pool_throughput(&j, GpuType::K80, 4, &cfg) - 37.0).abs()
                < 1e-9);
        assert_eq!(pool_throughput(&j, GpuType::K80, 0, &cfg), 0.0);
        assert_eq!(pool_throughput(&j, GpuType::T4, 2, &cfg), 0.0,
                   "missing row is unusable");
        // min_efficiency floor applies per pool.
        let strict = GangConfig {
            min_efficiency: 0.5,
            ..GangConfig::default()
        };
        assert_eq!(pool_throughput(&j, GpuType::K80, 4, &strict), 0.0);
        assert!(pool_throughput(&j, GpuType::V100, 4, &strict) > 0.0);
        // alloc_throughput of a whole-node allocation equals
        // gang_throughput of the host; of a one-pool allocation, the
        // pool rate.
        let node = Node::new(
            0,
            "big",
            &[(GpuType::K80, 4), (GpuType::V100, 4)],
            PcieGen::Gen3,
        );
        let mut whole = JobAllocation::new();
        for (g, c) in node.gang() {
            whole.add(node.id, g, c);
        }
        assert!((alloc_throughput(&j, &whole, &cfg)
                 - gang_throughput(&j, &node, &cfg))
                    .abs()
                < 1e-12);
        let mut one_pool = JobAllocation::new();
        one_pool.add(node.id, GpuType::V100, 4);
        assert!((alloc_throughput(&j, &one_pool, &cfg)
                 - pool_throughput(&j, GpuType::V100, 4, &cfg))
                    .abs()
                < 1e-12);
    }

    #[test]
    fn gang_throughput_is_sublinear_and_bottlenecked() {
        use crate::cluster::gpu::{GpuType, PcieGen};
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 1, 100);
        j.set_throughput(GpuType::K80, 10.0);
        j.set_throughput(GpuType::V100, 40.0);
        let cfg = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.0,
            ..GangConfig::default()
        };
        let one = Node::new(0, "k1", &[(GpuType::K80, 1)], PcieGen::Gen3);
        let four = Node::new(1, "k4", &[(GpuType::K80, 4)], PcieGen::Gen3);
        let x1 = gang_throughput(&j, &one, &cfg);
        let x4 = gang_throughput(&j, &four, &cfg);
        assert!((x1 - 10.0).abs() < 1e-12, "single GPU = per-GPU rate");
        assert!((x4 - 10.0 * 3.7).abs() < 1e-9, "4 GPUs at 0.9 marginal");
        assert!(x4 < 4.0 * x1, "not naively 4x");
        // Bottleneck all-or-nothing: a mixed node with one unusable type
        // is unusable as a whole.
        let mut k80_only = j.clone();
        k80_only.throughput.remove(&GpuType::V100);
        let mixed = Node::new(
            2,
            "mix",
            &[(GpuType::K80, 2), (GpuType::V100, 2)],
            PcieGen::Gen3,
        );
        assert_eq!(gang_throughput(&k80_only, &mixed, &cfg), 0.0);
        // min_efficiency floor rejects the slow node for a V100-anchored
        // job: 10 < 0.5 * 40.
        let strict = GangConfig {
            marginal_efficiency: 0.9,
            min_efficiency: 0.5,
            ..GangConfig::default()
        };
        assert_eq!(gang_throughput(&j, &four, &strict), 0.0);
    }

    #[test]
    fn nan_throughput_parent_is_never_scheduled() {
        // NaN-comparator regression (mirrors hadar.rs's
        // nan_and_zero_throughput_rows_are_never_scheduled): a parent
        // whose row is NaN must neither panic the round nor be placed;
        // well-formed parents still fill the cluster.
        use crate::cluster::gpu::GpuType;
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        for id in 0..2u64 {
            let mut j = Job::new(id, DlModel::MiMa, 0.0, 1, 20, 100);
            j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
            if id == 0 {
                for g in GpuType::ALL {
                    j.set_throughput(g, f64::NAN);
                }
            }
            tracker.register(
                j.id,
                j.total_iters(),
                &(1..=5).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
            );
            queue.admit(j);
        }
        let mut h = HadarE::new(5);
        let plan = h.plan_round(&ctx(&queue, &cluster), &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 5);
        for id in plan.scheduled_jobs() {
            assert_eq!(tracker.resolve(id), JobId(1),
                       "only the well-formed parent runs");
        }
    }
}
