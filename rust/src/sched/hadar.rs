//! **Hadar** — the paper's task-level heterogeneity-aware scheduler
//! (Algorithms 1 and 2).
//!
//! Each round, Hadar prices every (node, GPU-type) pool with the
//! exponential dual price (Eq. 5, [`crate::sched::price`]) and solves
//! Eq. (8): choose a
//! subset of queued jobs and task-level allocations minimising priced
//! resource cost (equivalently maximising total payoff
//! `φ_j = U_j − Σ k·w`), subject to capacity (1d) and gang all-or-nothing
//! (1e).
//!
//! * `FIND_ALLOC` (Algorithm 2, lines 22-34) generates candidate
//!   allocations per job — **packed** (consolidated on one node) and
//!   **spread** (across nodes, with a communication cost), both pure-type
//!   and mixed-type (the task-level flexibility Gavel lacks) — and keeps
//!   the payoff-maximal feasible one (`μ_j > 0`).
//! * `DP_allocation` (lines 1-21) explores select/skip per job with
//!   memoisation on (job index, server-state digest). Beyond a configurable
//!   queue size the scheduler switches to the payoff-density greedy that
//!   the DP converges to — this is what keeps Fig. 5's scheduling times
//!   flat at thousands of jobs.
//! * Incremental mode (§IV-B "Scalability") keeps running jobs'
//!   allocations and only places newcomers, tracking how many rounds
//!   actually changed allocations (the paper reports ~30%).
//!
//! §Perf: the solver is zero-clone (see `docs/performance.md`). The DP
//! runs on one `&mut ClusterState` with allocate → recurse →
//! [`ClusterState::rewind`]; memo keys use the state's O(1) Zobrist
//! digest; memo values are `(gpus, payoff, take)` scalars with the winning
//! plan reconstructed by one replay pass instead of sub-plan `Vec`s cloned
//! at every hit; and `FIND_ALLOC` walks the state's incrementally
//! maintained free-slot index instead of rebuilding + sorting per-type
//! slot lists per call. The pre-optimisation solver is preserved verbatim
//! in [`crate::sched::reference`] — a property test
//! (`rust/tests/prop_equivalence.rs`) pins this implementation to it
//! plan-for-plan, and `benches/l3_sched_micro.rs` + `hadar bench` measure
//! the gap.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId};
use crate::obs;
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::price::{PriceBounds, PriceTable};
use crate::sched::{RoundCtx, Scheduler, SolverStats};
use std::collections::{BTreeMap, HashMap};

/// Tunables (ablated in `benches/ablation_*.rs`).
#[derive(Clone, Copy, Debug)]
pub struct HadarConfig {
    /// Eq. (7) scale factor `η` bounding the initial dual objective.
    pub eta: f64,
    /// Weight of the non-consolidated communication cost (Algorithm 2,
    /// line 27) as a fraction of job utility per extra node.
    pub comm_factor: f64,
    /// Queue size up to which the exact select/skip DP runs; larger queues
    /// use the payoff-density greedy.
    pub dp_job_cap: usize,
    /// Memoisation budget (entries) for the DP.
    pub dp_memo_cap: usize,
    /// Keep running jobs' allocations between rounds, scheduling only
    /// newcomers (the paper's scalability optimisation).
    pub incremental: bool,
    /// Discard candidate allocations whose bottleneck throughput is below
    /// this fraction of the job's best single-GPU throughput — a gang
    /// running at (say) <10% efficiency wastes every worker in it
    /// (Eq. 1b), so waiting a round beats taking the placement.
    pub min_efficiency: f64,
}

impl Default for HadarConfig {
    fn default() -> Self {
        HadarConfig {
            eta: 1.0,
            comm_factor: 0.05,
            dp_job_cap: 12,
            dp_memo_cap: 50_000,
            incremental: false,
            min_efficiency: 0.0,
        }
    }
}

/// Decision statistics (scalability + the "~30% of rounds change
/// allocations" observation).
#[derive(Clone, Copy, Debug, Default)]
pub struct HadarStats {
    /// Scheduling rounds served.
    pub rounds: u64,
    /// Rounds whose plan differed from the previous round's.
    pub rounds_with_change: u64,
    /// Rounds solved by the exact select/skip DP.
    pub dp_invocations: u64,
    /// Rounds solved by the payoff-density greedy (queue > `dp_job_cap`).
    pub greedy_invocations: u64,
    /// DP memo hits (includes the replay pass's revisits).
    pub memo_hits: u64,
    /// DP memo misses.
    pub memo_misses: u64,
}

/// One DP memo value: GPUs utilised and payoff from this subproblem on,
/// plus whether the select branch won (enough to replay the plan).
type DpEntry = (usize, f64, bool);

/// The Hadar scheduler (Algorithms 1 and 2; see module docs).
pub struct Hadar {
    /// Tunables (see [`HadarConfig`]).
    pub cfg: HadarConfig,
    /// FIND_ALLOC line 23: GPU types sorted by `X_j^r` once per job.
    type_order: BTreeMap<JobId, Vec<GpuType>>,
    prev_plan: RoundPlan,
    /// Decision statistics, updated every round.
    pub stats: HadarStats,
}

impl Default for Hadar {
    fn default() -> Self {
        Self::new()
    }
}

impl Hadar {
    /// Hadar with the paper-default [`HadarConfig`].
    pub fn new() -> Self {
        Hadar::with_config(HadarConfig::default())
    }

    /// Hadar with explicit tunables (the ablation benches use this).
    pub fn with_config(cfg: HadarConfig) -> Self {
        Hadar {
            cfg,
            type_order: BTreeMap::new(),
            prev_plan: RoundPlan::new(),
            stats: HadarStats::default(),
        }
    }

    /// Compute-or-get one job's descending-throughput type order. A free
    /// function over the cache field (rather than a `&mut self` method) so
    /// `find_alloc` can hold the returned slice while still reading other
    /// fields of `self`.
    fn cached_type_order<'a>(
        cache: &'a mut BTreeMap<JobId, Vec<GpuType>>,
        job: &Job,
    ) -> &'a [GpuType] {
        cache
            .entry(job.id)
            .or_insert_with(|| {
                let mut types: Vec<GpuType> = job
                    .throughput
                    .iter()
                    .filter(|(_, &x)| x > 0.0)
                    .map(|(&g, _)| g)
                    .collect();
                // total_cmp: NaN throughputs are filtered above, but a
                // total order keeps a malformed row from panicking
                // mid-round.
                types.sort_by(|a, b| {
                    job.throughput_on(*b).total_cmp(&job.throughput_on(*a))
                });
                types
            })
            .as_slice()
    }

    /// GPU types by descending job throughput (cached for the job's
    /// lifetime — the O(R·H log H) sort in Theorem 1 happens once; the
    /// engines drop the entry via [`Scheduler::job_completed`]). Hands out
    /// a borrow of the cached slice — no per-call clone.
    pub fn sorted_types(&mut self, job: &Job) -> &[GpuType] {
        Self::cached_type_order(&mut self.type_order, job)
    }

    /// Entries currently held by the per-job type-order cache (bounded-
    /// memory regression tests).
    pub fn type_cache_len(&self) -> usize {
        self.type_order.len()
    }

    /// Payoff of a candidate allocation: `U_j(est. completion) − priced
    /// cost − comm cost` (Algorithm 2 lines 26-29).
    fn payoff(job: &Job, alloc: &JobAllocation, cost: f64, comm: f64,
              now: f64, min_efficiency: f64) -> f64 {
        let x_min = alloc
            .gpu_types()
            .iter()
            .map(|&g| job.throughput_on(g))
            .fold(f64::INFINITY, f64::min);
        if !x_min.is_finite() || x_min <= 0.0 {
            return f64::NEG_INFINITY;
        }
        // Bottleneck-efficiency guard: a placement that runs the whole
        // gang at a tiny fraction of the job's best throughput burns
        // W_j GPUs for negligible progress — reject it outright.
        if x_min < min_efficiency * job.max_throughput() {
            return f64::NEG_INFINITY;
        }
        // Estimated completion if the job keeps this allocation: the
        // bottleneck rule (1b) — every worker advances at the slowest
        // device's pace.
        let rate = alloc.total_gpus() as f64 * x_min;
        let est_duration = (now - job.arrival) + job.remaining_iters() / rate;
        job.utility(est_duration.max(job.t_min())) - cost - comm
    }

    /// Algorithm 2's FIND_ALLOC: best feasible allocation of `W_j` GPUs
    /// given current prices/state, or None if no candidate has `μ_j > 0`.
    fn find_alloc(&mut self, job: &Job, state: &ClusterState,
                  prices: &PriceTable, now: f64)
                  -> Option<(JobAllocation, f64)> {
        let _span = obs::trace::span("hadar.find_alloc");
        let cfg = self.cfg;
        let w = job.gpus_requested.max(1);
        let types = Self::cached_type_order(&mut self.type_order, job);
        if types.is_empty() {
            return None;
        }
        let mut best: Option<(JobAllocation, f64)> = None;
        let mut consider = |alloc: JobAllocation, cost: f64, comm: f64| {
            if alloc.total_gpus() != w {
                return;
            }
            let p = Self::payoff(job, &alloc, cost, comm, now,
                                 cfg.min_efficiency);
            if p > 0.0 && best.as_ref().map_or(true, |(_, bp)| p > *bp) {
                best = Some((alloc, p));
            }
        };

        // --- packed candidates: all W_j workers on a single node, fastest
        // types first (Algorithm 2 line 24).
        for node in 0..state.n_nodes() {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in types {
                if need == 0 {
                    break;
                }
                let take = state.free(node, g).min(need);
                if take > 0 {
                    cost += prices.marginal_cost(state, node, g, take);
                    alloc.add(node, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                consider(alloc, cost, 0.0);
            }
        }

        // --- spread candidates (line 25), filled most-free-node first
        // from the state's per-type free-slot index (§Perf: no per-call
        // slot-list rebuild or sort). Two flavours:
        // (a) pure-type: all workers on the job's k-th fastest type.
        for &g in types {
            if state.free_of_type(g) < w {
                continue;
            }
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for (h, free) in state.free_slots_of_type(g) {
                if need == 0 {
                    break;
                }
                let take = free.min(need);
                cost += prices.marginal_cost(state, h, g, take);
                alloc.add(h, g, take);
                need -= take;
            }
            let nodes_used = alloc.nodes().len();
            let comm = Self::comm_cost(&cfg, job, nodes_used);
            consider(alloc, cost, comm);
        }

        // (b) mixed-type: greedy best-throughput-first over every free slot
        // — the task-level flexibility of §II-A (J1 on 2xV100 + 3xP100 +
        // 1xK80).
        {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in types {
                if need == 0 {
                    break;
                }
                for (h, free) in state.free_slots_of_type(g) {
                    if need == 0 {
                        break;
                    }
                    let take = free.min(need);
                    cost += prices.marginal_cost(state, h, g, take);
                    alloc.add(h, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                let nodes_used = alloc.nodes().len();
                let comm = Self::comm_cost(&cfg, job, nodes_used);
                consider(alloc, cost, comm);
            }
        }

        best
    }

    /// Non-consolidated communication cost (Algorithm 2 line 27): a
    /// utility-proportional penalty per extra node crossed.
    fn comm_cost(cfg: &HadarConfig, job: &Job, nodes_used: usize) -> f64 {
        if nodes_used <= 1 {
            return 0.0;
        }
        cfg.comm_factor * (nodes_used - 1) as f64 * job.utility(job.t_min())
    }

    /// Algorithm 2's DP: explore select/skip for each queued job on ONE
    /// mutable state (allocate → recurse → rewind), memoised on
    /// (job index, Zobrist digest); returns `(gpus, payoff, take)` for the
    /// subproblem starting at `idx`.
    ///
    /// Branches are compared **work-conservation first** (GPUs utilised),
    /// then by payoff. Comparing on payoff alone would let the skip branch
    /// starve slow jobs — utility is effective throughput, so handing a
    /// fast node to a faster job always "pays" more this round — whereas
    /// the paper's Hadar explicitly minimises the number of GPUs left
    /// unused (§IV-B) and resolves contention through the prices.
    fn dp(&mut self, idx: usize, jobs: &[&Job], state: &mut ClusterState,
          prices: &PriceTable, now: f64,
          memo: &mut HashMap<(usize, u64), DpEntry>) -> DpEntry {
        if idx >= jobs.len() || state.is_full() {
            return (0, 0.0, false);
        }
        let key = (idx, state.digest());
        if let Some(&hit) = memo.get(&key) {
            self.stats.memo_hits += 1;
            return hit;
        }
        self.stats.memo_misses += 1;

        // Skip branch (line 15).
        let skip = self.dp(idx + 1, jobs, state, prices, now, memo);
        let mut best = (skip.0, skip.1, false);

        // Select branch (line 14): only if FIND_ALLOC yields positive payoff.
        if let Some((alloc, payoff)) =
            self.find_alloc(jobs[idx], state, prices, now)
        {
            let mark = state.checkpoint();
            for a in alloc.assignments(jobs[idx].id) {
                state.allocate(a);
            }
            let (rest_gpus, rest_pay, _) =
                self.dp(idx + 1, jobs, state, prices, now, memo);
            state.rewind(mark);
            let gpus = rest_gpus + alloc.total_gpus();
            let pay = payoff + rest_pay;
            if gpus > best.0 || (gpus == best.0 && pay > best.1) {
                best = (gpus, pay, true);
            }
        }

        if memo.len() < self.cfg.dp_memo_cap {
            memo.insert(key, best);
        }
        best
    }

    /// Run the DP and materialise its plan by replaying the take/skip
    /// decisions from the memo (mostly hits; a capped-out memo just
    /// recomputes the missing subproblems). Replay re-derives each taken
    /// job's allocation with `find_alloc` — deterministic given the same
    /// state — and commits it, so the plan is rebuilt exactly once instead
    /// of sub-plan vectors being cloned at every memo store/hit.
    fn dp_plan(&mut self, jobs: &[&Job], state: &mut ClusterState,
               prices: &PriceTable, now: f64)
               -> Vec<(JobId, JobAllocation)> {
        let _span = obs::trace::span("hadar.dp");
        let mut memo: HashMap<(usize, u64), DpEntry> = HashMap::new();
        let mut plan = Vec::new();
        for idx in 0..jobs.len() {
            if state.is_full() {
                break;
            }
            let (_, _, take) =
                self.dp(idx, jobs, state, prices, now, &mut memo);
            if take {
                let (alloc, _) = self
                    .find_alloc(jobs[idx], state, prices, now)
                    .expect("take decision implies a feasible candidate");
                for a in alloc.assignments(jobs[idx].id) {
                    state.allocate(a);
                }
                plan.push((jobs[idx].id, alloc));
            }
        }
        plan
    }

    /// Large-queue path: payoff-density greedy (utility per requested GPU,
    /// recomputed against live prices), O(n log n + n·H·R).
    fn greedy(&mut self, jobs: &[&Job], state: &mut ClusterState,
              prices: &PriceTable, now: f64)
              -> Vec<(JobId, JobAllocation)> {
        let _span = obs::trace::span("hadar.greedy");
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            let da = jobs[a].utility(jobs[a].t_min())
                / jobs[a].gpus_requested.max(1) as f64;
            let db = jobs[b].utility(jobs[b].t_min())
                / jobs[b].gpus_requested.max(1) as f64;
            // total_cmp: a NaN density (e.g. a NaN job weight) must not
            // panic the round. Note total_cmp orders positive NaN above
            // +inf, so a NaN-density job sorts *first* here — harmless,
            // because payoff() rejects NaN payoffs (p > 0.0 is false) and
            // the job simply fails to place.
            db.total_cmp(&da)
        });
        let mut out = Vec::new();
        for i in order {
            if state.is_full() {
                break;
            }
            if let Some((alloc, _)) =
                self.find_alloc(jobs[i], state, prices, now)
            {
                for a in alloc.assignments(jobs[i].id) {
                    state.allocate(a);
                }
                out.push((jobs[i].id, alloc));
            }
        }
        out
    }

    /// Drop the per-job type cache for completed jobs (bounded memory).
    /// Called by the engines through [`Scheduler::job_completed`].
    pub fn forget_job(&mut self, id: JobId) {
        self.type_order.remove(&id);
    }

    /// Feed this round's [`HadarStats`] deltas into the global metrics
    /// registry. Gated on [`crate::obs::enabled`] so the disabled path is
    /// one atomic load.
    fn publish_stats_delta(&self, before: HadarStats) {
        if !obs::enabled() {
            return;
        }
        let m = obs::metrics::core();
        m.dp_memo_hits.add(self.stats.memo_hits - before.memo_hits);
        m.dp_memo_misses.add(self.stats.memo_misses - before.memo_misses);
        m.dp_rounds
            .add(self.stats.dp_invocations - before.dp_invocations);
        m.greedy_rounds
            .add(self.stats.greedy_invocations - before.greedy_invocations);
    }
}

impl Scheduler for Hadar {
    fn name(&self) -> &'static str {
        "hadar"
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        let _span = obs::trace::span("hadar.schedule");
        let stats_before = self.stats;
        self.stats.rounds += 1;
        let jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        if jobs.is_empty() {
            self.prev_plan = RoundPlan::new();
            return RoundPlan::new();
        }

        let gpu_types = ctx.cluster.gpu_types();
        let bounds =
            PriceBounds::from_jobs(&jobs, &gpu_types, ctx.horizon, self.cfg.eta);
        let prices = PriceTable::new(bounds);
        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();

        // Incremental mode: carry over running jobs' allocations when they
        // still fit; only the remainder is (re)scheduled.
        let mut pending: Vec<&Job> = Vec::new();
        if self.cfg.incremental {
            for job in &jobs {
                if let Some(prev) = self.prev_plan.get(job.id) {
                    let fits = prev.slots.iter().all(|(&(h, g), &c)| {
                        state.free(h, g) >= c
                    });
                    if fits {
                        for a in prev.assignments(job.id) {
                            state.allocate(a);
                        }
                        plan.insert(job.id, prev.clone());
                        continue;
                    }
                }
                pending.push(job);
            }
        } else {
            pending = jobs.clone();
        }

        // LPT-flavoured queue order: longest *total* best-case runtime
        // first, so FIND_ALLOC hands the fastest pools to the jobs that
        // gate the makespan. The key is static (t_j^min, not remaining
        // time) so the order — and therefore the job->node matching — is
        // stable across rounds: re-sorting on remaining time makes jobs
        // swap nodes mid-flight and pay checkpoint-restart every round.
        // total_cmp, not partial_cmp().unwrap(): a degenerate job (zero
        // throughput row -> infinite/NaN t_min) must not panic the round.
        pending.sort_by(|a, b| {
            b.t_min().total_cmp(&a.t_min()).then(a.id.cmp(&b.id))
        });

        let chosen: Vec<(JobId, JobAllocation)> =
            if pending.len() <= self.cfg.dp_job_cap {
                self.stats.dp_invocations += 1;
                self.dp_plan(&pending, &mut state, &prices, ctx.now)
            } else {
                self.stats.greedy_invocations += 1;
                self.greedy(&pending, &mut state, &prices, ctx.now)
            };
        for (id, alloc) in chosen {
            plan.insert(id, alloc);
        }

        // Change tracking (the paper's ~30% observation).
        let changed = jobs.iter().any(|j| {
            plan.get(j.id) != self.prev_plan.get(j.id)
        });
        if changed {
            self.stats.rounds_with_change += 1;
        }
        self.prev_plan = plan.clone();
        self.publish_stats_delta(stats_before);
        plan
    }

    /// Drain preemption: forget the job's previous allocation so
    /// incremental mode does not try to carry a placement onto hardware
    /// that left the cluster. The throughput-order cache stays — the job
    /// itself is unchanged and will be rescheduled.
    fn preempt(&mut self, job: JobId) {
        self.prev_plan.allocations.remove(&job);
    }

    /// Completion: drop the job's type-order cache entry and any previous
    /// allocation — neither is needed again, and on long traces the cache
    /// would otherwise grow with every job ever admitted.
    fn job_completed(&mut self, job: JobId) {
        self.forget_job(job);
        self.prev_plan.allocations.remove(&job);
    }

    /// Hadar's cumulative [`HadarStats`], mapped onto the generic
    /// telemetry shape — this is how memo efficiency reaches sweep
    /// artifacts and per-round telemetry instead of dying in-process.
    fn solver_stats(&self) -> Option<SolverStats> {
        Some(SolverStats {
            memo_hits: self.stats.memo_hits,
            memo_misses: self.stats.memo_misses,
            dp_rounds: self.stats.dp_invocations,
            greedy_rounds: self.stats.greedy_invocations,
            rounds_with_change: self.stats.rounds_with_change,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    /// The §II-A motivational jobs: J1 (3 GPUs, 80 epochs), J2 (2, 30),
    /// J3 (2, 50).
    fn motivational_jobs() -> JobQueue {
        let mut q = JobQueue::new();
        for (id, w, epochs) in [(1u64, 3usize, 80u64), (2, 2, 30), (3, 2, 50)] {
            let mut j = Job::new(id, DlModel::ResNet18, 0.0, w, epochs, 100);
            // Fig. 1's X-matrix flavour: V100 fastest, K80 slow.
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::P100, 25.0);
            j.set_throughput(GpuType::K80, 8.0);
            q.admit(j);
        }
        q
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            cluster,
        }
    }

    #[test]
    fn schedules_across_heterogeneous_types() {
        // The headline behaviour: with 2 V100 + 3 P100 + 1 K80 free, a
        // 3-GPU job CAN run (Gavel could not if no single type has 3).
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(1)).expect("J1 scheduled");
        assert_eq!(alloc.total_gpus(), 3);
    }

    #[test]
    fn respects_gang_all_or_nothing() {
        let cluster = ClusterSpec::motivational(); // 6 GPUs
        let mut queue = JobQueue::new();
        let mut j = Job::new(1, DlModel::ResNet18, 0.0, 9, 10, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        queue.admit(j);
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none(), "9 > 6 GPUs: must not run");
    }

    #[test]
    fn packs_cluster_with_multiple_jobs() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        // 6 GPUs, demands 3+2+2: at least two jobs (5 GPUs) run.
        assert!(plan.scheduled_jobs().len() >= 2);
        assert!(plan.total_gpus() >= 5);
        // Capacity respected per pool.
        let mut used: BTreeMap<(usize, GpuType), usize> = BTreeMap::new();
        for (_, alloc) in &plan.allocations {
            for (&k, &c) in &alloc.slots {
                *used.entry(k).or_insert(0) += c;
            }
        }
        let state = ClusterState::new(&cluster);
        for ((h, g), c) in used {
            assert!(c <= state.capacity(h, g));
        }
    }

    #[test]
    fn prefers_fast_types_when_free() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(2)]; // W=2, both V100 free
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(2)).unwrap();
        // Packed on the V100 node (fastest, zero comm cost) is optimal.
        assert_eq!(alloc.gpu_types(), vec![GpuType::V100]);
    }

    #[test]
    fn greedy_path_engages_beyond_cap() {
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..40u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            queue.admit(j);
        }
        let active: Vec<JobId> = (0..40).map(JobId).collect();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.stats.greedy_invocations, 1);
        assert_eq!(hadar.stats.dp_invocations, 0);
        // 60 GPUs, 40 single-GPU jobs: all should run.
        assert_eq!(plan.scheduled_jobs().len(), 40);
    }

    #[test]
    fn incremental_mode_keeps_running_allocations() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let plan1 = hadar.schedule(&ctx(&queue, &active, &cluster));
        let plan2 = hadar.schedule(&ctx(&queue, &active, &cluster));
        for id in plan1.scheduled_jobs() {
            assert_eq!(plan1.get(id), plan2.get(id), "{id} moved");
        }
        // Round 2 changed nothing.
        assert_eq!(hadar.stats.rounds_with_change, 1);
    }

    #[test]
    fn empty_queue_yields_empty_plan() {
        let cluster = ClusterSpec::motivational();
        let queue = JobQueue::new();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &[], &cluster));
        assert!(plan.scheduled_jobs().is_empty());
    }

    #[test]
    fn job_completed_drops_caches() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let _ = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.type_cache_len(), 3);
        hadar.job_completed(JobId(2));
        assert_eq!(hadar.type_cache_len(), 2);
        assert!(hadar.prev_plan.get(JobId(2)).is_none());
    }

    #[test]
    fn nan_weight_on_greedy_path_does_not_panic() {
        // Regression: the greedy ordering used partial_cmp().unwrap(),
        // which panicked the round as soon as one job's payoff density was
        // NaN (e.g. a NaN utility weight). total_cmp must survive it and
        // still schedule the well-formed jobs.
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..20u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            if id == 7 {
                j.weight = f64::NAN;
            }
            queue.admit(j);
        }
        let active: Vec<JobId> = (0..20).map(JobId).collect();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.stats.greedy_invocations, 1);
        assert!(plan.scheduled_jobs().len() >= 19);
    }

    #[test]
    fn nan_and_zero_throughput_rows_are_never_scheduled() {
        // A NaN throughput entry must be treated like "unusable type", and
        // an all-zero row like "cannot run anywhere" — no panic either way.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        let mut j_nan = Job::new(1, DlModel::Lstm, 0.0, 2, 2, 100);
        j_nan.set_throughput(GpuType::V100, f64::NAN);
        queue.admit(j_nan);
        let mut j_zero = Job::new(2, DlModel::Lstm, 0.0, 2, 2, 100);
        j_zero.set_throughput(GpuType::V100, 0.0);
        queue.admit(j_zero);
        let mut j_ok = Job::new(3, DlModel::Lstm, 0.0, 2, 2, 100);
        j_ok.set_throughput(GpuType::V100, 40.0);
        queue.admit(j_ok);
        let active = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none());
        assert!(plan.get(JobId(2)).is_none());
        assert!(plan.get(JobId(3)).is_some());
    }
}
