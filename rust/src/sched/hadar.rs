//! **Hadar** — the paper's task-level heterogeneity-aware scheduler
//! (Algorithms 1 and 2).
//!
//! Each round, Hadar prices every (node, GPU-type) pool with the
//! exponential dual price (Eq. 5, [`crate::sched::price`]) and solves
//! Eq. (8): choose a
//! subset of queued jobs and task-level allocations minimising priced
//! resource cost (equivalently maximising total payoff
//! `φ_j = U_j − Σ k·w`), subject to capacity (1d) and gang all-or-nothing
//! (1e).
//!
//! * `FIND_ALLOC` (Algorithm 2, lines 22-34) generates candidate
//!   allocations per job — **packed** (consolidated on one node) and
//!   **spread** (across nodes, with a communication cost), both pure-type
//!   and mixed-type (the task-level flexibility Gavel lacks) — and keeps
//!   the payoff-maximal feasible one (`μ_j > 0`).
//! * `DP_allocation` (lines 1-21) explores select/skip per job with
//!   memoisation on (job index, server-state digest). Beyond a configurable
//!   queue size the scheduler switches to the payoff-density greedy that
//!   the DP converges to — this is what keeps Fig. 5's scheduling times
//!   flat at thousands of jobs.
//! * Incremental mode (§IV-B "Scalability") keeps running jobs'
//!   allocations and only places newcomers, tracking how many rounds
//!   actually changed allocations (the paper reports ~30%).
//!
//! §Perf: the solver is zero-clone (see `docs/performance.md`). The DP
//! runs on one `&mut ClusterState` with allocate → recurse →
//! [`ClusterState::rewind`]; memo keys use the state's O(1) Zobrist
//! digest; memo values are `(gpus, payoff, take)` scalars with the winning
//! plan reconstructed by one replay pass instead of sub-plan `Vec`s cloned
//! at every hit; and `FIND_ALLOC` walks the state's incrementally
//! maintained free-slot index instead of rebuilding + sorting per-type
//! slot lists per call. The pre-optimisation solver is preserved verbatim
//! in [`crate::sched::reference`] — a property test
//! (`rust/tests/prop_equivalence.rs`) pins this implementation to it
//! plan-for-plan, and `benches/l3_sched_micro.rs` + `hadar bench` measure
//! the gap.
//!
//! §Streaming scale: the greedy path runs as **speculative parallel
//! scoring with a deterministic serial commit**. Candidate generation
//! (`FIND_ALLOC`) is a pure function of the job and an immutable state
//! snapshot, so batches of pending jobs are scored concurrently across
//! `HADAR_PLAN_THREADS` workers ([`HadarConfig::plan_threads`]), then
//! committed one by one in density order; a job is rescored only when an
//! earlier commit in its batch dirtied a GPU type it can use
//! (conflict-set invalidation at type granularity — `FIND_ALLOC`'s
//! entire cluster read set is the pools of the job's usable types). The
//! packed scan walks [`ClusterState::packed_candidates`] instead of
//! every node, a Σ-free bail rejects infeasible jobs in O(types), and
//! cross-round **no-candidate rows** (the Hadar-side mirror of HadarE's
//! warm-start rows, invalidated by per-type digests + a round
//! signature) let steady-state rounds skip rescoring jobs that had no
//! positive-payoff candidate last round. Batch sizing depends only on
//! commit outcomes, never on the worker count, so plans *and* counters
//! are bit-identical at any `plan_threads` (pinned by
//! `rust/tests/prop_equivalence.rs`).

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId};
use crate::obs;
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::price::{PriceBounds, PriceTable};
use crate::jobs::queue::JobQueue;
use crate::sched::{RoundCtx, RoundDelta, Scheduler, SolverStats};
use std::collections::{BTreeMap, HashMap};

/// Tunables (ablated in `benches/ablation_*.rs`).
#[derive(Clone, Copy, Debug)]
pub struct HadarConfig {
    /// Eq. (7) scale factor `η` bounding the initial dual objective.
    pub eta: f64,
    /// Weight of the non-consolidated communication cost (Algorithm 2,
    /// line 27) as a fraction of job utility per extra node.
    pub comm_factor: f64,
    /// Queue size up to which the exact select/skip DP runs; larger queues
    /// use the payoff-density greedy.
    pub dp_job_cap: usize,
    /// Memoisation budget (entries) for the DP.
    pub dp_memo_cap: usize,
    /// Keep running jobs' allocations between rounds, scheduling only
    /// newcomers (the paper's scalability optimisation).
    pub incremental: bool,
    /// Discard candidate allocations whose bottleneck throughput is below
    /// this fraction of the job's best single-GPU throughput — a gang
    /// running at (say) <10% efficiency wastes every worker in it
    /// (Eq. 1b), so waiting a round beats taking the placement.
    pub min_efficiency: f64,
    /// Speculative-scoring worker count for the greedy path. `0` defers
    /// to the `HADAR_PLAN_THREADS` environment variable (the same knob
    /// the HadarE planner shards on), then to
    /// `min(4, available_parallelism)` — resolved once at construction
    /// ([`crate::sched::resolve_plan_threads`]). Plans are
    /// bit-identical at any value.
    pub plan_threads: usize,
}

impl Default for HadarConfig {
    fn default() -> Self {
        HadarConfig {
            eta: 1.0,
            comm_factor: 0.05,
            dp_job_cap: 12,
            dp_memo_cap: 50_000,
            incremental: false,
            min_efficiency: 0.0,
            plan_threads: 0,
        }
    }
}

/// Decision statistics (scalability + the "~30% of rounds change
/// allocations" observation).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HadarStats {
    /// Scheduling rounds served.
    pub rounds: u64,
    /// Rounds whose plan differed from the previous round's.
    pub rounds_with_change: u64,
    /// Rounds solved by the exact select/skip DP.
    pub dp_invocations: u64,
    /// Rounds solved by the payoff-density greedy (queue > `dp_job_cap`).
    pub greedy_invocations: u64,
    /// DP memo hits (includes the replay pass's revisits).
    pub memo_hits: u64,
    /// DP memo misses.
    pub memo_misses: u64,
    /// `FIND_ALLOC` scoring passes: DP-path calls, speculative batch
    /// scores, Σ-free feasibility bails, and commit-time rescores.
    pub find_alloc_calls: u64,
    /// Candidate allocations payoff-scored across all passes (packed +
    /// pure-spread + mixed-spread).
    pub candidates_scored: u64,
    /// Batched jobs whose speculative score (or cached no-candidate row)
    /// was invalidated by an earlier commit dirtying one of their GPU
    /// types, forcing a serial rescore.
    pub rescore_conflicts: u64,
    /// Greedy decisions served from a still-valid cross-round
    /// no-candidate row instead of a scoring pass.
    pub none_row_hits: u64,
}

/// One DP memo value: GPUs utilised and payoff from this subproblem on,
/// plus whether the select branch won (enough to replay the plan).
type DpEntry = (usize, f64, bool);

/// Speculative batch policy: the starting batch size, the growth cap,
/// and the batch size below which scoring stays on the calling thread
/// (spawn/join overhead would dominate). All three are outcome-driven —
/// a pure function of commit results, never of the worker count — so
/// plans and counters are identical at any [`HadarConfig::plan_threads`].
const SPEC_BATCH_MIN: usize = 32;
/// Upper bound the conflict-free batch size doubles toward.
const SPEC_BATCH_MAX: usize = 4096;
/// Minimum jobs-to-score before `score_batch` spawns workers.
const SPEC_SPAWN_MIN: usize = 16;

/// Result of scoring one job's candidates ([`Hadar::score_alloc`]).
#[derive(Debug, Default)]
struct ScoreOutcome {
    /// Payoff-maximal feasible candidate with `μ_j > 0`, if any.
    best: Option<(JobAllocation, f64)>,
    /// Whether `W_j` GPUs could be assembled at all. `false` means the
    /// Σ-free bail fired — a `None` that needs no cross-round row, since
    /// re-deriving it costs O(types).
    assembled: bool,
    /// Candidates payoff-scored (the `hadar.candidates_scored` counter).
    candidates: u64,
}

/// Cross-round "FIND_ALLOC found no positive-payoff candidate" row —
/// the Hadar-side mirror of HadarE's warm-start row cache. A row is
/// reusable only when every input the scoring pass read is provably
/// unchanged (digest + signature match) and `now` has only advanced:
/// with fixed pools, prices, progress, and a non-negative weight, every
/// candidate's payoff is non-increasing in `now` (estimated completion
/// grows, utility shrinks, costs are fixed), so "no candidate with
/// `μ_j > 0`" stays true.
struct NoneRow {
    /// [`ClusterState::digest_of_types`] over the job's usable types at
    /// scoring time — the scoring pass's entire per-round cluster read
    /// set.
    type_digest: u64,
    /// [`round_signature`] at scoring time (capacity matrix + price
    /// bounds): node churn or a dual-price move invalidates every row.
    round_sig: u64,
    /// `job.progress` bits at scoring time (progress changes the
    /// remaining work and thereby every payoff).
    progress_bits: u64,
    /// `job.weight` bits at scoring time. Recording requires
    /// `weight >= 0.0` (NaN fails that) — the payoff-monotonicity
    /// argument above needs a non-negative weight.
    weight_bits: u64,
    /// Virtual time of the scoring pass; reuse requires `now >= this`.
    now: f64,
}

/// Formation-time classification of one batched pending job.
enum Spec {
    /// Σ free over the job's usable types < `W_j`: nothing can assemble,
    /// and free counts only shrink within a round, so no earlier commit
    /// needs re-checking — the decision is `None`, permanently.
    Infeasible,
    /// A still-valid [`NoneRow`] short-circuits the scoring pass.
    RowNone,
    /// Speculatively scored; the payload indexes the batch outcome
    /// table.
    Scored(u32),
}

/// Bitmask over GPU-type indices — the conflict-set representation. Two
/// jobs conflict exactly when their usable-type masks intersect, because
/// a scoring pass reads nothing outside its job's type pools.
#[inline]
fn type_mask(types: &[GpuType]) -> u32 {
    types.iter().fold(0u32, |m, &g| m | (1u32 << g as usize))
}

/// FNV-1a signature of everything a scoring pass reads besides per-type
/// allocation counts: the capacity matrix and the dual price bounds.
/// Folded into every [`NoneRow`] so rows are churn- and price-safe.
fn round_signature(state: &ClusterState, bounds: &PriceBounds) -> u64 {
    const P: u64 = 0x0000_0100_0000_01B3;
    let mut h = (0xCBF2_9CE4_8422_2325u64 ^ state.capacity_digest())
        .wrapping_mul(P);
    for (&g, &v) in &bounds.u_max {
        h = (h ^ g as u64).wrapping_mul(P);
        h = (h ^ v.to_bits()).wrapping_mul(P);
    }
    for (&g, &v) in &bounds.u_min {
        h = (h ^ g as u64).wrapping_mul(P);
        h = (h ^ v.to_bits()).wrapping_mul(P);
    }
    h
}

/// Score a batch of `(job, type-order)` pairs against one immutable
/// state snapshot, sharded over contiguous chunks of scoped workers
/// (the PR-7 `fill_matrix` recipe). Every outcome is a pure function of
/// its own pair, so the result is bit-identical to the serial loop at
/// any worker count; small batches stay serial ([`SPEC_SPAWN_MIN`]).
fn score_batch(cfg: &HadarConfig, items: &[(&Job, &[GpuType])],
               state: &ClusterState, prices: &PriceTable, now: f64,
               threads: usize) -> Vec<ScoreOutcome> {
    let mut out: Vec<ScoreOutcome> = Vec::new();
    out.resize_with(items.len(), ScoreOutcome::default);
    let score = |chunk: &[(&Job, &[GpuType])], res: &mut [ScoreOutcome]| {
        for (&(job, types), slot) in chunk.iter().zip(res.iter_mut()) {
            *slot = Hadar::score_alloc(cfg, job, types, state, prices, now);
        }
    };
    if threads <= 1 || items.len() < SPEC_SPAWN_MIN {
        score(items, &mut out);
        return out;
    }
    let per = (items.len() + threads - 1) / threads;
    let score = &score;
    std::thread::scope(|scope| {
        for (chunk, res) in items.chunks(per).zip(out.chunks_mut(per)) {
            scope.spawn(move || score(chunk, res));
        }
    });
    out
}

/// The Hadar scheduler (Algorithms 1 and 2; see module docs).
pub struct Hadar {
    /// Tunables (see [`HadarConfig`]).
    pub cfg: HadarConfig,
    /// FIND_ALLOC line 23: GPU types sorted by `X_j^r` once per job.
    type_order: BTreeMap<JobId, Vec<GpuType>>,
    prev_plan: RoundPlan,
    /// Cross-round no-candidate rows (greedy path), keyed by job and
    /// invalidated by signature — see [`NoneRow`].
    none_rows: HashMap<JobId, NoneRow>,
    /// Speculative-scoring worker count, resolved once at construction
    /// from [`HadarConfig::plan_threads`] / `HADAR_PLAN_THREADS`.
    threads: usize,
    /// Decision statistics, updated every round.
    pub stats: HadarStats,
}

impl Default for Hadar {
    fn default() -> Self {
        Self::new()
    }
}

impl Hadar {
    /// Hadar with the paper-default [`HadarConfig`].
    pub fn new() -> Self {
        Hadar::with_config(HadarConfig::default())
    }

    /// Hadar with explicit tunables (the ablation benches use this).
    pub fn with_config(cfg: HadarConfig) -> Self {
        Hadar {
            threads: crate::sched::resolve_plan_threads(
                cfg.plan_threads,
            ),
            cfg,
            type_order: BTreeMap::new(),
            prev_plan: RoundPlan::new(),
            none_rows: HashMap::new(),
            stats: HadarStats::default(),
        }
    }

    /// Compute-or-get one job's descending-throughput type order. A free
    /// function over the cache field (rather than a `&mut self` method) so
    /// `find_alloc` can hold the returned slice while still reading other
    /// fields of `self`.
    fn cached_type_order<'a>(
        cache: &'a mut BTreeMap<JobId, Vec<GpuType>>,
        job: &Job,
    ) -> &'a [GpuType] {
        cache
            .entry(job.id)
            .or_insert_with(|| {
                let mut types: Vec<GpuType> = job
                    .throughput
                    .iter()
                    .filter(|(_, &x)| x > 0.0)
                    .map(|(&g, _)| g)
                    .collect();
                // total_cmp: NaN throughputs are filtered above, but a
                // total order keeps a malformed row from panicking
                // mid-round.
                types.sort_by(|a, b| {
                    job.throughput_on(*b).total_cmp(&job.throughput_on(*a))
                });
                types
            })
            .as_slice()
    }

    /// GPU types by descending job throughput (cached for the job's
    /// lifetime — the O(R·H log H) sort in Theorem 1 happens once; the
    /// engines drop the entry via [`Scheduler::job_completed`]). Hands out
    /// a borrow of the cached slice — no per-call clone.
    pub fn sorted_types(&mut self, job: &Job) -> &[GpuType] {
        Self::cached_type_order(&mut self.type_order, job)
    }

    /// Entries currently held by the per-job type-order cache (bounded-
    /// memory regression tests).
    pub fn type_cache_len(&self) -> usize {
        self.type_order.len()
    }

    /// Payoff of a candidate allocation: `U_j(est. completion) − priced
    /// cost − comm cost` (Algorithm 2 lines 26-29).
    fn payoff(job: &Job, alloc: &JobAllocation, cost: f64, comm: f64,
              now: f64, min_efficiency: f64) -> f64 {
        let x_min = alloc
            .gpu_types()
            .iter()
            .map(|&g| job.throughput_on(g))
            .fold(f64::INFINITY, f64::min);
        if !x_min.is_finite() || x_min <= 0.0 {
            return f64::NEG_INFINITY;
        }
        // Bottleneck-efficiency guard: a placement that runs the whole
        // gang at a tiny fraction of the job's best throughput burns
        // W_j GPUs for negligible progress — reject it outright.
        if x_min < min_efficiency * job.max_throughput() {
            return f64::NEG_INFINITY;
        }
        // Estimated completion if the job keeps this allocation: the
        // bottleneck rule (1b) — every worker advances at the slowest
        // device's pace.
        let rate = alloc.total_gpus() as f64 * x_min;
        let est_duration = (now - job.arrival) + job.remaining_iters() / rate;
        job.utility(est_duration.max(job.t_min())) - cost - comm
    }

    /// Algorithm 2's FIND_ALLOC: best feasible allocation of `W_j` GPUs
    /// given current prices/state, or None if no candidate has `μ_j > 0`.
    /// A thin counting wrapper over [`Hadar::score_alloc`] — the DP path
    /// calls this; the greedy path drives `score_alloc` directly so
    /// speculative workers can score without `&mut self`.
    fn find_alloc(&mut self, job: &Job, state: &ClusterState,
                  prices: &PriceTable, now: f64)
                  -> Option<(JobAllocation, f64)> {
        let _span = obs::trace::span("hadar.find_alloc");
        let cfg = self.cfg;
        let types = Self::cached_type_order(&mut self.type_order, job);
        let o = Self::score_alloc(&cfg, job, types, state, prices, now);
        self.stats.find_alloc_calls += 1;
        self.stats.candidates_scored += o.candidates;
        o.best
    }

    /// Candidate generation as a pure read-only function of
    /// `(job, state, prices, now)` — exactly the historical `find_alloc`
    /// body, restructured for speculation:
    ///
    /// * a Σ-free **feasibility bail** rejects jobs whose usable types
    ///   cannot supply `W_j` GPUs in O(types), before any scan;
    /// * the **packed scan** walks
    ///   [`ClusterState::packed_candidates`] — the nodes that can still
    ///   contribute, in ascending id order (the historical visiting
    ///   order, so payoff ties break identically) — instead of every
    ///   node;
    /// * its cluster read set is exactly the pools of the job's usable
    ///   types, which is what makes type-granularity conflict sets sound.
    fn score_alloc(cfg: &HadarConfig, job: &Job, types: &[GpuType],
                   state: &ClusterState, prices: &PriceTable, now: f64)
                   -> ScoreOutcome {
        let w = job.gpus_requested.max(1);
        if types.is_empty() {
            return ScoreOutcome::default();
        }
        // Every candidate draws all W_j workers from the job's usable
        // types, so Σ_g free(g) < W_j means nothing can assemble.
        let avail: usize =
            types.iter().map(|&g| state.free_of_type(g)).sum();
        if avail < w {
            return ScoreOutcome::default();
        }
        // From here on the mixed-type spread always assembles (it drains
        // every free slot of every usable type until `need` hits 0), so
        // `assembled` is true even when no candidate's payoff clears 0.
        let mut candidates = 0u64;
        let mut best: Option<(JobAllocation, f64)> = None;
        let mut consider = |alloc: JobAllocation, cost: f64, comm: f64| {
            if alloc.total_gpus() != w {
                return;
            }
            candidates += 1;
            let p = Self::payoff(job, &alloc, cost, comm, now,
                                 cfg.min_efficiency);
            if p > 0.0 && best.as_ref().map_or(true, |(_, bp)| p > *bp) {
                best = Some((alloc, p));
            }
        };

        // --- packed candidates: all W_j workers on a single node, fastest
        // types first (Algorithm 2 line 24). Only nodes with free GPUs of
        // the job's types can assemble, and the index hands exactly those
        // out in ascending id order.
        for &node in &state.packed_candidates(types, w) {
            let node = node as usize;
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in types {
                if need == 0 {
                    break;
                }
                let take = state.free(node, g).min(need);
                if take > 0 {
                    cost += prices.marginal_cost(state, node, g, take);
                    alloc.add(node, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                consider(alloc, cost, 0.0);
            }
        }

        // --- spread candidates (line 25), filled most-free-node first
        // from the state's per-type free-slot index (§Perf: no per-call
        // slot-list rebuild or sort). Two flavours:
        // (a) pure-type: all workers on the job's k-th fastest type.
        for &g in types {
            if state.free_of_type(g) < w {
                continue;
            }
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for (h, free) in state.free_slots_of_type(g) {
                if need == 0 {
                    break;
                }
                let take = free.min(need);
                cost += prices.marginal_cost(state, h, g, take);
                alloc.add(h, g, take);
                need -= take;
            }
            let nodes_used = alloc.nodes().len();
            let comm = Self::comm_cost(cfg, job, nodes_used);
            consider(alloc, cost, comm);
        }

        // (b) mixed-type: greedy best-throughput-first over every free slot
        // — the task-level flexibility of §II-A (J1 on 2xV100 + 3xP100 +
        // 1xK80).
        {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in types {
                if need == 0 {
                    break;
                }
                for (h, free) in state.free_slots_of_type(g) {
                    if need == 0 {
                        break;
                    }
                    let take = free.min(need);
                    cost += prices.marginal_cost(state, h, g, take);
                    alloc.add(h, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                let nodes_used = alloc.nodes().len();
                let comm = Self::comm_cost(cfg, job, nodes_used);
                consider(alloc, cost, comm);
            }
        }

        ScoreOutcome { best, assembled: true, candidates }
    }

    /// Non-consolidated communication cost (Algorithm 2 line 27): a
    /// utility-proportional penalty per extra node crossed.
    fn comm_cost(cfg: &HadarConfig, job: &Job, nodes_used: usize) -> f64 {
        if nodes_used <= 1 {
            return 0.0;
        }
        cfg.comm_factor * (nodes_used - 1) as f64 * job.utility(job.t_min())
    }

    /// Algorithm 2's DP: explore select/skip for each queued job on ONE
    /// mutable state (allocate → recurse → rewind), memoised on
    /// (job index, Zobrist digest); returns `(gpus, payoff, take)` for the
    /// subproblem starting at `idx`.
    ///
    /// Branches are compared **work-conservation first** (GPUs utilised),
    /// then by payoff. Comparing on payoff alone would let the skip branch
    /// starve slow jobs — utility is effective throughput, so handing a
    /// fast node to a faster job always "pays" more this round — whereas
    /// the paper's Hadar explicitly minimises the number of GPUs left
    /// unused (§IV-B) and resolves contention through the prices.
    fn dp(&mut self, idx: usize, jobs: &[&Job], state: &mut ClusterState,
          prices: &PriceTable, now: f64,
          memo: &mut HashMap<(usize, u64), DpEntry>) -> DpEntry {
        if idx >= jobs.len() || state.is_full() {
            return (0, 0.0, false);
        }
        let key = (idx, state.digest());
        if let Some(&hit) = memo.get(&key) {
            self.stats.memo_hits += 1;
            return hit;
        }
        self.stats.memo_misses += 1;

        // Skip branch (line 15).
        let skip = self.dp(idx + 1, jobs, state, prices, now, memo);
        let mut best = (skip.0, skip.1, false);

        // Select branch (line 14): only if FIND_ALLOC yields positive payoff.
        if let Some((alloc, payoff)) =
            self.find_alloc(jobs[idx], state, prices, now)
        {
            let mark = state.checkpoint();
            for a in alloc.assignments(jobs[idx].id) {
                state.allocate(a);
            }
            let (rest_gpus, rest_pay, _) =
                self.dp(idx + 1, jobs, state, prices, now, memo);
            state.rewind(mark);
            let gpus = rest_gpus + alloc.total_gpus();
            let pay = payoff + rest_pay;
            if gpus > best.0 || (gpus == best.0 && pay > best.1) {
                best = (gpus, pay, true);
            }
        }

        if memo.len() < self.cfg.dp_memo_cap {
            memo.insert(key, best);
        }
        best
    }

    /// Run the DP and materialise its plan by replaying the take/skip
    /// decisions from the memo (mostly hits; a capped-out memo just
    /// recomputes the missing subproblems). Replay re-derives each taken
    /// job's allocation with `find_alloc` — deterministic given the same
    /// state — and commits it, so the plan is rebuilt exactly once instead
    /// of sub-plan vectors being cloned at every memo store/hit.
    fn dp_plan(&mut self, jobs: &[&Job], state: &mut ClusterState,
               prices: &PriceTable, now: f64)
               -> Vec<(JobId, JobAllocation)> {
        let _span = obs::trace::span("hadar.dp");
        let mut memo: HashMap<(usize, u64), DpEntry> = HashMap::new();
        let mut plan = Vec::new();
        for idx in 0..jobs.len() {
            if state.is_full() {
                break;
            }
            let (_, _, take) =
                self.dp(idx, jobs, state, prices, now, &mut memo);
            if take {
                let (alloc, _) = self
                    .find_alloc(jobs[idx], state, prices, now)
                    .expect("take decision implies a feasible candidate");
                for a in alloc.assignments(jobs[idx].id) {
                    state.allocate(a);
                }
                plan.push((jobs[idx].id, alloc));
            }
        }
        plan
    }

    /// Large-queue path: payoff-density greedy, run as speculative
    /// parallel scoring with a deterministic serial commit (module docs,
    /// §Streaming scale). The plan is identical to the frozen serial
    /// loop (`RefHadar`): batches are formed, committed, and grown by
    /// rules that never consult the worker count, and a speculative
    /// score is only trusted when no earlier commit touched the job's
    /// usable types — otherwise it is rescored against the live state,
    /// exactly as the serial loop would have scored it.
    fn greedy(&mut self, jobs: &[&Job], state: &mut ClusterState,
              prices: &PriceTable, now: f64, round_sig: u64)
              -> Vec<(JobId, JobAllocation)> {
        let _span = obs::trace::span("hadar.greedy");
        // Pass 0: cache every job's type order, so the batch loop below
        // holds one shared borrow of the cache while the stats and the
        // no-candidate rows stay mutable (disjoint fields).
        for job in jobs {
            Self::cached_type_order(&mut self.type_order, job);
        }
        let cfg = self.cfg;
        let threads = self.threads;
        let type_order = &self.type_order;
        let stats = &mut self.stats;
        let none_rows = &mut self.none_rows;

        // Decorate-sort by payoff density. The key is a per-job constant,
        // so sorting precomputed keys with the same stable sort +
        // total_cmp reproduces the historical comparator order exactly —
        // including NaN densities sorting first (harmless: payoff()
        // rejects NaN payoffs, `p > 0.0` is false, the job never places).
        let dens: Vec<f64> = jobs
            .iter()
            .map(|j| {
                j.utility(j.t_min()) / j.gpus_requested.max(1) as f64
            })
            .collect();
        let mut order: Vec<u32> = (0..jobs.len() as u32).collect();
        order.sort_by(|&a, &b| {
            dens[b as usize].total_cmp(&dens[a as usize])
        });

        let mut out = Vec::new();
        let mut k = SPEC_BATCH_MIN;
        let mut pos = 0usize;
        'stream: while pos < order.len() && !state.is_full() {
            let batch = &order[pos..(pos + k).min(order.len())];
            pos += batch.len();

            // Formation: classify each batched job against the current
            // state — bail, row hit, or speculative score.
            let mut specs: Vec<Spec> = Vec::with_capacity(batch.len());
            let mut to_score: Vec<(&Job, &[GpuType])> = Vec::new();
            for &ji in batch {
                let job = jobs[ji as usize];
                let types = type_order
                    .get(&job.id)
                    .expect("type order cached in pass 0")
                    .as_slice();
                let w = job.gpus_requested.max(1);
                let avail: usize =
                    types.iter().map(|&g| state.free_of_type(g)).sum();
                if avail < w {
                    stats.find_alloc_calls += 1;
                    specs.push(Spec::Infeasible);
                    continue;
                }
                let row_valid = none_rows.get(&job.id).map_or(false, |r| {
                    r.round_sig == round_sig
                        && r.progress_bits == job.progress.to_bits()
                        && r.weight_bits == job.weight.to_bits()
                        && now >= r.now
                        && r.type_digest == state.digest_of_types(types)
                });
                if row_valid {
                    specs.push(Spec::RowNone);
                } else {
                    specs.push(Spec::Scored(to_score.len() as u32));
                    to_score.push((job, types));
                }
            }
            let mut outcomes =
                score_batch(&cfg, &to_score, state, prices, now, threads);
            stats.find_alloc_calls += to_score.len() as u64;

            // Serial commit walk in density order. `dirty` accumulates
            // the GPU types touched by commits in this batch; a job
            // whose mask misses it is provably unaffected.
            let mut dirty = 0u32;
            let mut conflicted = false;
            for (&ji, spec) in batch.iter().zip(&specs) {
                if state.is_full() {
                    break 'stream; // the serial loop's is_full() break
                }
                let job = jobs[ji as usize];
                let types = type_order
                    .get(&job.id)
                    .expect("type order cached in pass 0")
                    .as_slice();
                let jmask = type_mask(types);
                let o = match spec {
                    // Infeasibility is monotone within a round (free
                    // counts only shrink), so it survives any commit.
                    Spec::Infeasible => continue,
                    Spec::RowNone if dirty & jmask == 0 => {
                        stats.none_row_hits += 1;
                        continue;
                    }
                    Spec::Scored(oi) if dirty & jmask == 0 => {
                        let o =
                            std::mem::take(&mut outcomes[*oi as usize]);
                        stats.candidates_scored += o.candidates;
                        o
                    }
                    // An earlier commit dirtied one of this job's types:
                    // the speculative score (or cached row) may no
                    // longer match the state — rescore serially.
                    _ => {
                        conflicted = true;
                        stats.rescore_conflicts += 1;
                        stats.find_alloc_calls += 1;
                        if let Spec::Scored(oi) = spec {
                            stats.candidates_scored +=
                                outcomes[*oi as usize].candidates;
                        }
                        let o = Self::score_alloc(&cfg, job, types, state,
                                                  prices, now);
                        stats.candidates_scored += o.candidates;
                        o
                    }
                };
                match o.best {
                    Some((alloc, _)) => {
                        for a in alloc.assignments(job.id) {
                            state.allocate(a);
                        }
                        dirty |= type_mask(&alloc.gpu_types());
                        none_rows.remove(&job.id);
                        out.push((job.id, alloc));
                    }
                    None if o.assembled && job.weight >= 0.0 => {
                        // Clean items scored against digests that still
                        // hold; rescored items against the live state —
                        // either way the *current* digest is the one the
                        // outcome was computed under.
                        none_rows.insert(job.id, NoneRow {
                            type_digest: state.digest_of_types(types),
                            round_sig,
                            progress_bits: job.progress.to_bits(),
                            weight_bits: job.weight.to_bits(),
                            now,
                        });
                    }
                    None => {}
                }
            }
            // Grow the batch while speculation holds; shrink to the
            // floor on any conflict. Outcome-driven only, so the batch
            // trajectory is identical at every worker count.
            k = if conflicted {
                SPEC_BATCH_MIN
            } else {
                (k * 2).min(SPEC_BATCH_MAX)
            };
        }
        out
    }

    /// Drop the per-job caches (type order, no-candidate row) for
    /// completed jobs (bounded memory). Called by the engines through
    /// [`Scheduler::job_completed`].
    pub fn forget_job(&mut self, id: JobId) {
        self.type_order.remove(&id);
        self.none_rows.remove(&id);
    }

    /// Feed this round's [`HadarStats`] deltas into the global metrics
    /// registry. Gated on [`crate::obs::enabled`] so the disabled path is
    /// one atomic load.
    fn publish_stats_delta(&self, before: HadarStats) {
        if !obs::enabled() {
            return;
        }
        let m = obs::metrics::core();
        m.dp_memo_hits.add(self.stats.memo_hits - before.memo_hits);
        m.dp_memo_misses.add(self.stats.memo_misses - before.memo_misses);
        m.dp_rounds
            .add(self.stats.dp_invocations - before.dp_invocations);
        m.greedy_rounds
            .add(self.stats.greedy_invocations - before.greedy_invocations);
        m.hadar_find_alloc_calls
            .add(self.stats.find_alloc_calls - before.find_alloc_calls);
        m.hadar_candidates_scored
            .add(self.stats.candidates_scored - before.candidates_scored);
        m.hadar_rescore_conflicts
            .add(self.stats.rescore_conflicts - before.rescore_conflicts);
        m.hadar_none_row_hits
            .add(self.stats.none_row_hits - before.none_row_hits);
    }
}

impl Scheduler for Hadar {
    fn name(&self) -> &'static str {
        "hadar"
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        let _span = obs::trace::span("hadar.schedule");
        let stats_before = self.stats;
        self.stats.rounds += 1;
        let jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        if jobs.is_empty() {
            self.prev_plan = RoundPlan::new();
            return RoundPlan::new();
        }

        let gpu_types = ctx.cluster.gpu_types();
        let bounds =
            PriceBounds::from_jobs(&jobs, &gpu_types, ctx.horizon, self.cfg.eta);
        let prices = PriceTable::new(bounds);
        let mut state = ClusterState::new(ctx.cluster);
        let round_sig = round_signature(&state, prices.bounds());
        let mut plan = RoundPlan::new();

        // Incremental mode: carry over running jobs' allocations when they
        // still fit; only the remainder is (re)scheduled.
        let mut pending: Vec<&Job> = Vec::new();
        if self.cfg.incremental {
            for job in &jobs {
                if let Some(prev) = self.prev_plan.get(job.id) {
                    let fits = prev.slots.iter().all(|(&(h, g), &c)| {
                        state.free(h, g) >= c
                    });
                    if fits {
                        for a in prev.assignments(job.id) {
                            state.allocate(a);
                        }
                        plan.insert(job.id, prev.clone());
                        continue;
                    }
                }
                pending.push(job);
            }
        } else {
            pending = jobs.clone();
        }

        let chosen: Vec<(JobId, JobAllocation)> =
            if pending.is_empty() || state.is_full() {
                // Nothing can place: the DP returns all-skip on a full
                // state and the greedy breaks before its first decision,
                // so skip the ordering and dispatch entirely — this is
                // what makes an incremental no-op round O(carried)
                // instead of O(pending log pending).
                Vec::new()
            } else {
                // LPT-flavoured queue order: longest *total* best-case
                // runtime first, so FIND_ALLOC hands the fastest pools
                // to the jobs that gate the makespan. The key is static
                // (t_j^min, not remaining time) so the order — and
                // therefore the job->node matching — is stable across
                // rounds: re-sorting on remaining time makes jobs swap
                // nodes mid-flight and pay checkpoint-restart every
                // round. Decorate-sorted: t_min is a per-job constant,
                // so precomputed keys reproduce the comparator order at
                // O(n) key computations. total_cmp, not
                // partial_cmp().unwrap(): a degenerate job (zero
                // throughput row -> infinite/NaN t_min) must not panic
                // the round.
                let mut keyed: Vec<(f64, &Job)> =
                    pending.iter().map(|j| (j.t_min(), *j)).collect();
                keyed.sort_by(|a, b| {
                    b.0.total_cmp(&a.0).then(a.1.id.cmp(&b.1.id))
                });
                let pending: Vec<&Job> =
                    keyed.into_iter().map(|(_, j)| j).collect();
                if pending.len() <= self.cfg.dp_job_cap {
                    self.stats.dp_invocations += 1;
                    self.dp_plan(&pending, &mut state, &prices, ctx.now)
                } else {
                    self.stats.greedy_invocations += 1;
                    self.greedy(&pending, &mut state, &prices, ctx.now,
                                round_sig)
                }
            };
        for (id, alloc) in chosen {
            plan.insert(id, alloc);
        }

        // Change tracking (the paper's ~30% observation).
        let changed = jobs.iter().any(|j| {
            plan.get(j.id) != self.prev_plan.get(j.id)
        });
        if changed {
            self.stats.rounds_with_change += 1;
        }
        self.prev_plan = plan.clone();
        self.publish_stats_delta(stats_before);
        plan
    }

    /// Drain preemption: forget the job's previous allocation so
    /// incremental mode does not try to carry a placement onto hardware
    /// that left the cluster. The throughput-order cache stays — the job
    /// itself is unchanged and will be rescheduled.
    fn preempt(&mut self, job: JobId) {
        self.prev_plan.allocations.remove(&job);
    }

    /// Completion: drop the job's type-order cache entry and any previous
    /// allocation — neither is needed again, and on long traces the cache
    /// would otherwise grow with every job ever admitted.
    fn job_completed(&mut self, job: JobId) {
        self.forget_job(job);
        self.prev_plan.allocations.remove(&job);
    }

    /// Fold the round boundary's diff into the cross-round caches:
    /// completions drop their type-order / `NoneRow` / carried-plan
    /// entries (idempotent with [`Scheduler::job_completed`], which the
    /// engines also call), and arrivals pre-compute their
    /// descending-throughput type order so `FIND_ALLOC` never derives it
    /// mid-round from the full list. A pure cache fold: none of these
    /// operations touch [`HadarStats`], so plans *and* solver stats stay
    /// bit-identical whether the engine feeds the delta or not (pinned
    /// by `rust/tests/prop_delta.rs`).
    fn observe_delta(&mut self, delta: &RoundDelta, queue: &JobQueue) {
        for &id in &delta.completions {
            self.forget_job(id);
            self.prev_plan.allocations.remove(&id);
        }
        for &id in &delta.arrivals {
            if let Some(job) = queue.get(id) {
                Self::cached_type_order(&mut self.type_order, job);
            }
        }
    }

    /// Hadar's cumulative [`HadarStats`], mapped onto the generic
    /// telemetry shape — this is how memo efficiency reaches sweep
    /// artifacts and per-round telemetry instead of dying in-process.
    fn solver_stats(&self) -> Option<SolverStats> {
        Some(SolverStats {
            memo_hits: self.stats.memo_hits,
            memo_misses: self.stats.memo_misses,
            dp_rounds: self.stats.dp_invocations,
            greedy_rounds: self.stats.greedy_invocations,
            rounds_with_change: self.stats.rounds_with_change,
            find_alloc_calls: self.stats.find_alloc_calls,
            candidates_scored: self.stats.candidates_scored,
            rescore_conflicts: self.stats.rescore_conflicts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    /// The §II-A motivational jobs: J1 (3 GPUs, 80 epochs), J2 (2, 30),
    /// J3 (2, 50).
    fn motivational_jobs() -> JobQueue {
        let mut q = JobQueue::new();
        for (id, w, epochs) in [(1u64, 3usize, 80u64), (2, 2, 30), (3, 2, 50)] {
            let mut j = Job::new(id, DlModel::ResNet18, 0.0, w, epochs, 100);
            // Fig. 1's X-matrix flavour: V100 fastest, K80 slow.
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::P100, 25.0);
            j.set_throughput(GpuType::K80, 8.0);
            q.admit(j).unwrap();
        }
        q
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            delta: None,
            cluster,
        }
    }

    #[test]
    fn schedules_across_heterogeneous_types() {
        // The headline behaviour: with 2 V100 + 3 P100 + 1 K80 free, a
        // 3-GPU job CAN run (Gavel could not if no single type has 3).
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(1)).expect("J1 scheduled");
        assert_eq!(alloc.total_gpus(), 3);
    }

    #[test]
    fn respects_gang_all_or_nothing() {
        let cluster = ClusterSpec::motivational(); // 6 GPUs
        let mut queue = JobQueue::new();
        let mut j = Job::new(1, DlModel::ResNet18, 0.0, 9, 10, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        queue.admit(j).unwrap();
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none(), "9 > 6 GPUs: must not run");
    }

    #[test]
    fn packs_cluster_with_multiple_jobs() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        // 6 GPUs, demands 3+2+2: at least two jobs (5 GPUs) run.
        assert!(plan.scheduled_jobs().len() >= 2);
        assert!(plan.total_gpus() >= 5);
        // Capacity respected per pool.
        let mut used: BTreeMap<(usize, GpuType), usize> = BTreeMap::new();
        for (_, alloc) in &plan.allocations {
            for (&k, &c) in &alloc.slots {
                *used.entry(k).or_insert(0) += c;
            }
        }
        let state = ClusterState::new(&cluster);
        for ((h, g), c) in used {
            assert!(c <= state.capacity(h, g));
        }
    }

    #[test]
    fn prefers_fast_types_when_free() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(2)]; // W=2, both V100 free
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(2)).unwrap();
        // Packed on the V100 node (fastest, zero comm cost) is optimal.
        assert_eq!(alloc.gpu_types(), vec![GpuType::V100]);
    }

    #[test]
    fn greedy_path_engages_beyond_cap() {
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..40u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            queue.admit(j).unwrap();
        }
        let active: Vec<JobId> = (0..40).map(JobId).collect();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.stats.greedy_invocations, 1);
        assert_eq!(hadar.stats.dp_invocations, 0);
        // 60 GPUs, 40 single-GPU jobs: all should run.
        assert_eq!(plan.scheduled_jobs().len(), 40);
    }

    #[test]
    fn incremental_mode_keeps_running_allocations() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let plan1 = hadar.schedule(&ctx(&queue, &active, &cluster));
        let plan2 = hadar.schedule(&ctx(&queue, &active, &cluster));
        for id in plan1.scheduled_jobs() {
            assert_eq!(plan1.get(id), plan2.get(id), "{id} moved");
        }
        // Round 2 changed nothing.
        assert_eq!(hadar.stats.rounds_with_change, 1);
    }

    #[test]
    fn empty_queue_yields_empty_plan() {
        let cluster = ClusterSpec::motivational();
        let queue = JobQueue::new();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &[], &cluster));
        assert!(plan.scheduled_jobs().is_empty());
    }

    #[test]
    fn job_completed_drops_caches() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let _ = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.type_cache_len(), 3);
        hadar.job_completed(JobId(2));
        assert_eq!(hadar.type_cache_len(), 2);
        assert!(hadar.prev_plan.get(JobId(2)).is_none());
    }

    #[test]
    fn nan_weight_on_greedy_path_does_not_panic() {
        // Regression: the greedy ordering used partial_cmp().unwrap(),
        // which panicked the round as soon as one job's payoff density was
        // NaN (e.g. a NaN utility weight). total_cmp must survive it and
        // still schedule the well-formed jobs.
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..20u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            if id == 7 {
                j.weight = f64::NAN;
            }
            queue.admit(j).unwrap();
        }
        let active: Vec<JobId> = (0..20).map(JobId).collect();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.stats.greedy_invocations, 1);
        assert!(plan.scheduled_jobs().len() >= 19);
    }

    #[test]
    fn nan_and_zero_throughput_rows_are_never_scheduled() {
        // A NaN throughput entry must be treated like "unusable type", and
        // an all-zero row like "cannot run anywhere" — no panic either way.
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        let mut j_nan = Job::new(1, DlModel::Lstm, 0.0, 2, 2, 100);
        j_nan.set_throughput(GpuType::V100, f64::NAN);
        queue.admit(j_nan).unwrap();
        let mut j_zero = Job::new(2, DlModel::Lstm, 0.0, 2, 2, 100);
        j_zero.set_throughput(GpuType::V100, 0.0);
        queue.admit(j_zero).unwrap();
        let mut j_ok = Job::new(3, DlModel::Lstm, 0.0, 2, 2, 100);
        j_ok.set_throughput(GpuType::V100, 40.0);
        queue.admit(j_ok).unwrap();
        let active = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none());
        assert!(plan.get(JobId(2)).is_none());
        assert!(plan.get(JobId(3)).is_some());
    }

    /// A greedy-regime queue with deterministic per-job variety (mixed
    /// widths, throughputs, arrival 0) — enough jobs that some place,
    /// some lose to capacity, and some are squeezed onto slow types.
    fn streaming_queue(n: u64) -> (JobQueue, Vec<JobId>) {
        let mut q = JobQueue::new();
        for id in 0..n {
            let w = [1usize, 1, 2, 2, 3, 4][(id % 6) as usize];
            let mut j =
                Job::new(id, DlModel::Lstm, 0.0, w, 2 + (id % 5), 100);
            j.set_throughput(GpuType::V100, 30.0 + (id % 17) as f64);
            j.set_throughput(GpuType::P100, 20.0 + (id % 11) as f64);
            if id % 4 != 0 {
                j.set_throughput(GpuType::K80, 5.0 + (id % 7) as f64);
            }
            q.admit(j).unwrap();
        }
        (q, (0..n).map(JobId).collect())
    }

    #[test]
    fn speculative_greedy_is_thread_count_invariant() {
        // Plans AND counters must be bit-identical at any worker count:
        // batch sizing is outcome-driven, speculative scores are pure,
        // and conflicts rescore against the live state. Two rounds so
        // cross-round no-candidate rows get exercised too.
        let cluster = ClusterSpec::sim60();
        let (queue, active) = streaming_queue(120);
        let mut runs = Vec::new();
        for threads in [1usize, 2, 8] {
            let mut hadar = Hadar::with_config(HadarConfig {
                plan_threads: threads,
                ..Default::default()
            });
            let p0 = hadar.schedule(&ctx(&queue, &active, &cluster));
            let p1 = hadar.schedule(&ctx(&queue, &active, &cluster));
            runs.push((p0, p1, hadar.stats));
        }
        for (p0, p1, stats) in &runs[1..] {
            assert_eq!(
                p0.allocations, runs[0].0.allocations,
                "round-0 plan differs across thread counts"
            );
            assert_eq!(
                p1.allocations, runs[0].1.allocations,
                "round-1 plan differs across thread counts"
            );
            assert_eq!(*stats, runs[0].2, "counters differ across threads");
        }
        assert_eq!(runs[0].2.greedy_invocations, 2);
        assert!(runs[0].2.find_alloc_calls > 0);
        assert!(runs[0].2.candidates_scored > 0);
    }

    #[test]
    fn infeasible_width_bails_without_scoring_candidates() {
        // Σ free over the job's usable types < W_j: the feasibility bail
        // must answer None in O(types), before any candidate is scored.
        let cluster = ClusterSpec::motivational(); // 6 GPUs total
        let mut queue = JobQueue::new();
        let mut j = Job::new(1, DlModel::Lstm, 0.0, 9, 4, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        queue.admit(j).unwrap();
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none());
        assert!(hadar.stats.find_alloc_calls >= 1);
        assert_eq!(
            hadar.stats.candidates_scored, 0,
            "bail fired: no candidate may be assembled, let alone scored"
        );
    }

    #[test]
    fn none_rows_skip_rescoring_in_steady_state() {
        // Every candidate is rejected by an impossible efficiency floor,
        // so round 0 records a no-candidate row per job; round 1 (same
        // state, prices, progress, now) must serve every decision from
        // the rows without a single new scoring pass.
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..40u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            queue.admit(j).unwrap();
        }
        let active: Vec<JobId> = (0..40).map(JobId).collect();
        let mut hadar = Hadar::with_config(HadarConfig {
            dp_job_cap: 0, // force the greedy path
            min_efficiency: 1.5, // x_min < 1.5 * max always: reject all
            ..Default::default()
        });
        let p0 = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(p0.scheduled_jobs().is_empty());
        let calls_after_r0 = hadar.stats.find_alloc_calls;
        assert_eq!(hadar.stats.none_row_hits, 0);

        let p1 = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(p1.scheduled_jobs().is_empty());
        assert_eq!(hadar.stats.none_row_hits, 40, "all 40 served by rows");
        assert_eq!(
            hadar.stats.find_alloc_calls, calls_after_r0,
            "steady-state round must not rescore anything"
        );
    }

    #[test]
    fn incremental_full_cluster_round_skips_dispatch() {
        // Round 0 fills all 60 GPUs; round 1 carries every allocation
        // over, leaving a full state — the dispatch (and its sort) must
        // be skipped entirely, reproducing round 0's plan with no second
        // greedy invocation.
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..80u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 4, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            queue.admit(j).unwrap();
        }
        let active: Vec<JobId> = (0..80).map(JobId).collect();
        let mut hadar = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let p0 = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(p0.total_gpus(), 60, "round 0 fills the cluster");
        let p1 = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(p0.allocations, p1.allocations);
        assert_eq!(
            hadar.stats.greedy_invocations, 1,
            "full-state round must skip the dispatch"
        );
    }
}
