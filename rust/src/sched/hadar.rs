//! **Hadar** — the paper's task-level heterogeneity-aware scheduler
//! (Algorithms 1 and 2).
//!
//! Each round, Hadar prices every (node, GPU-type) pool with the
//! exponential dual price (Eq. 5, [`crate::sched::price`]) and solves
//! Eq. (8): choose a
//! subset of queued jobs and task-level allocations minimising priced
//! resource cost (equivalently maximising total payoff
//! `φ_j = U_j − Σ k·w`), subject to capacity (1d) and gang all-or-nothing
//! (1e).
//!
//! * `FIND_ALLOC` (Algorithm 2, lines 22-34) generates candidate
//!   allocations per job — **packed** (consolidated on one node) and
//!   **spread** (across nodes, with a communication cost), both pure-type
//!   and mixed-type (the task-level flexibility Gavel lacks) — and keeps
//!   the payoff-maximal feasible one (`μ_j > 0`).
//! * `DP_allocation` (lines 1-21) explores select/skip per job with
//!   memoisation on (job index, server-state digest). Beyond a configurable
//!   queue size the scheduler switches to the payoff-density greedy that
//!   the DP converges to — this is what keeps Fig. 5's scheduling times
//!   flat at thousands of jobs.
//! * Incremental mode (§IV-B "Scalability") keeps running jobs'
//!   allocations and only places newcomers, tracking how many rounds
//!   actually changed allocations (the paper reports ~30%).

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::price::{PriceBounds, PriceTable};
use crate::sched::{RoundCtx, Scheduler};
use std::collections::{BTreeMap, HashMap};

/// Tunables (ablated in `benches/ablation_*.rs`).
#[derive(Clone, Copy, Debug)]
pub struct HadarConfig {
    /// Eq. (7) scale factor `η` bounding the initial dual objective.
    pub eta: f64,
    /// Weight of the non-consolidated communication cost (Algorithm 2,
    /// line 27) as a fraction of job utility per extra node.
    pub comm_factor: f64,
    /// Queue size up to which the exact select/skip DP runs; larger queues
    /// use the payoff-density greedy.
    pub dp_job_cap: usize,
    /// Memoisation budget (entries) for the DP.
    pub dp_memo_cap: usize,
    /// Keep running jobs' allocations between rounds, scheduling only
    /// newcomers (the paper's scalability optimisation).
    pub incremental: bool,
    /// Discard candidate allocations whose bottleneck throughput is below
    /// this fraction of the job's best single-GPU throughput — a gang
    /// running at (say) <10% efficiency wastes every worker in it
    /// (Eq. 1b), so waiting a round beats taking the placement.
    pub min_efficiency: f64,
}

impl Default for HadarConfig {
    fn default() -> Self {
        HadarConfig {
            eta: 1.0,
            comm_factor: 0.05,
            dp_job_cap: 12,
            dp_memo_cap: 50_000,
            incremental: false,
            min_efficiency: 0.0,
        }
    }
}

/// Decision statistics (scalability + the "~30% of rounds change
/// allocations" observation).
#[derive(Clone, Copy, Debug, Default)]
pub struct HadarStats {
    /// Scheduling rounds served.
    pub rounds: u64,
    /// Rounds whose plan differed from the previous round's.
    pub rounds_with_change: u64,
    /// Rounds solved by the exact select/skip DP.
    pub dp_invocations: u64,
    /// Rounds solved by the payoff-density greedy (queue > `dp_job_cap`).
    pub greedy_invocations: u64,
    /// DP memo hits.
    pub memo_hits: u64,
    /// DP memo misses.
    pub memo_misses: u64,
}

/// The Hadar scheduler (Algorithms 1 and 2; see module docs).
pub struct Hadar {
    /// Tunables (see [`HadarConfig`]).
    pub cfg: HadarConfig,
    /// FIND_ALLOC line 23: GPU types sorted by `X_j^r` once per job.
    type_order: BTreeMap<JobId, Vec<GpuType>>,
    prev_plan: RoundPlan,
    /// Decision statistics, updated every round.
    pub stats: HadarStats,
}

impl Default for Hadar {
    fn default() -> Self {
        Self::new()
    }
}

impl Hadar {
    /// Hadar with the paper-default [`HadarConfig`].
    pub fn new() -> Self {
        Hadar::with_config(HadarConfig::default())
    }

    /// Hadar with explicit tunables (the ablation benches use this).
    pub fn with_config(cfg: HadarConfig) -> Self {
        Hadar {
            cfg,
            type_order: BTreeMap::new(),
            prev_plan: RoundPlan::new(),
            stats: HadarStats::default(),
        }
    }

    /// GPU types by descending job throughput (cached for the job's
    /// lifetime — the O(R·H log H) sort in Theorem 1 happens once).
    fn sorted_types(&mut self, job: &Job) -> Vec<GpuType> {
        if let Some(t) = self.type_order.get(&job.id) {
            return t.clone();
        }
        let mut types: Vec<GpuType> = job
            .throughput
            .iter()
            .filter(|(_, &x)| x > 0.0)
            .map(|(&g, _)| g)
            .collect();
        types.sort_by(|a, b| {
            job.throughput_on(*b)
                .partial_cmp(&job.throughput_on(*a))
                .unwrap()
        });
        self.type_order.insert(job.id, types.clone());
        types
    }

    /// Payoff of a candidate allocation: `U_j(est. completion) − priced
    /// cost − comm cost` (Algorithm 2 lines 26-29).
    fn payoff(job: &Job, alloc: &JobAllocation, cost: f64, comm: f64,
              now: f64, min_efficiency: f64) -> f64 {
        let x_min = alloc
            .gpu_types()
            .iter()
            .map(|&g| job.throughput_on(g))
            .fold(f64::INFINITY, f64::min);
        if !x_min.is_finite() || x_min <= 0.0 {
            return f64::NEG_INFINITY;
        }
        // Bottleneck-efficiency guard: a placement that runs the whole
        // gang at a tiny fraction of the job's best throughput burns
        // W_j GPUs for negligible progress — reject it outright.
        if x_min < min_efficiency * job.max_throughput() {
            return f64::NEG_INFINITY;
        }
        // Estimated completion if the job keeps this allocation: the
        // bottleneck rule (1b) — every worker advances at the slowest
        // device's pace.
        let rate = alloc.total_gpus() as f64 * x_min;
        let est_duration = (now - job.arrival) + job.remaining_iters() / rate;
        job.utility(est_duration.max(job.t_min())) - cost - comm
    }

    /// Algorithm 2's FIND_ALLOC: best feasible allocation of `W_j` GPUs
    /// given current prices/state, or None if no candidate has `μ_j > 0`.
    fn find_alloc(&mut self, job: &Job, state: &ClusterState,
                  prices: &PriceTable, now: f64)
                  -> Option<(JobAllocation, f64)> {
        let w = job.gpus_requested.max(1);
        let types = self.sorted_types(job);
        if types.is_empty() {
            return None;
        }
        let mut best: Option<(JobAllocation, f64)> = None;
        let min_eff = self.cfg.min_efficiency;
        let mut consider = |alloc: JobAllocation, cost: f64, comm: f64| {
            if alloc.total_gpus() != w {
                return;
            }
            let p = Self::payoff(job, &alloc, cost, comm, now, min_eff);
            if p > 0.0 && best.as_ref().map_or(true, |(_, bp)| p > *bp) {
                best = Some((alloc, p));
            }
        };

        // §Perf: per-type free-slot lists (node, free) sorted by free desc,
        // built ONCE per FIND_ALLOC call and shared by the spread and mixed
        // candidate generators below.
        let per_type_slots: Vec<Vec<(usize, usize)>> = types
            .iter()
            .map(|&g| {
                let mut slots: Vec<(usize, usize)> = (0..state.n_nodes())
                    .map(|h| (h, state.free(h, g)))
                    .filter(|&(_, f)| f > 0)
                    .collect();
                slots.sort_by(|a, b| b.1.cmp(&a.1));
                slots
            })
            .collect();

        // --- packed candidates: all W_j workers on a single node, fastest
        // types first (Algorithm 2 line 24).
        for node in 0..state.n_nodes() {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in &types {
                if need == 0 {
                    break;
                }
                let take = state.free(node, g).min(need);
                if take > 0 {
                    cost += prices.marginal_cost(state, node, g, take);
                    alloc.add(node, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                consider(alloc, cost, 0.0);
            }
        }

        // --- spread candidates (line 25). Two flavours:
        // (a) pure-type: all workers on the job's k-th fastest type,
        // filled from nodes with most free first (fewest nodes used).
        for (ti, &g) in types.iter().enumerate() {
            if state.free_of_type(g) < w {
                continue;
            }
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &(h, free) in &per_type_slots[ti] {
                if need == 0 {
                    break;
                }
                let take = free.min(need);
                cost += prices.marginal_cost(state, h, g, take);
                alloc.add(h, g, take);
                need -= take;
            }
            let nodes_used = alloc.nodes().len();
            let comm = self.comm_cost(job, nodes_used, now);
            consider(alloc, cost, comm);
        }

        // (b) mixed-type: greedy best-throughput-first over every free slot
        // — the task-level flexibility of §II-A (J1 on 2xV100 + 3xP100 +
        // 1xK80).
        {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for (ti, &g) in types.iter().enumerate() {
                if need == 0 {
                    break;
                }
                for &(h, free) in &per_type_slots[ti] {
                    if need == 0 {
                        break;
                    }
                    let take = free.min(need);
                    cost += prices.marginal_cost(state, h, g, take);
                    alloc.add(h, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                let nodes_used = alloc.nodes().len();
                let comm = self.comm_cost(job, nodes_used, now);
                consider(alloc, cost, comm);
            }
        }

        best
    }

    /// Non-consolidated communication cost (Algorithm 2 line 27): a
    /// utility-proportional penalty per extra node crossed.
    fn comm_cost(&self, job: &Job, nodes_used: usize, _now: f64) -> f64 {
        if nodes_used <= 1 {
            return 0.0;
        }
        self.cfg.comm_factor * (nodes_used - 1) as f64
            * job.utility(job.t_min())
    }

    /// Digest of γ over all (node, type) pools — the DP memo key.
    #[inline]
    fn digest(state: &ClusterState) -> u64 {
        state.digest()
    }

    /// Algorithm 2's DP: explore select/skip for each queued job,
    /// memoised; returns the best sub-plan from `idx` on.
    ///
    /// Branches are compared **work-conservation first** (GPUs utilised),
    /// then by payoff. Comparing on payoff alone would let the skip branch
    /// starve slow jobs — utility is effective throughput, so handing a
    /// fast node to a faster job always "pays" more this round — whereas
    /// the paper's Hadar explicitly minimises the number of GPUs left
    /// unused (§IV-B) and resolves contention through the prices.
    #[allow(clippy::too_many_arguments)]
    fn dp(&mut self, idx: usize, jobs: &[&Job], state: &ClusterState,
          prices: &PriceTable, now: f64,
          memo: &mut HashMap<(usize, u64),
                             (usize, f64, Vec<(JobId, JobAllocation)>)>)
          -> (usize, f64, Vec<(JobId, JobAllocation)>) {
        if idx >= jobs.len() || state.is_full() {
            return (0, 0.0, Vec::new());
        }
        let key = (idx, Self::digest(state));
        if let Some(hit) = memo.get(&key) {
            self.stats.memo_hits += 1;
            return hit.clone();
        }
        self.stats.memo_misses += 1;

        // Skip branch (line 15).
        let mut best = self.dp(idx + 1, jobs, state, prices, now, memo);

        // Select branch (line 14): only if FIND_ALLOC yields positive payoff.
        if let Some((alloc, payoff)) =
            self.find_alloc(jobs[idx], state, prices, now)
        {
            let mut st = state.clone();
            for a in alloc.assignments(jobs[idx].id) {
                st.allocate(a);
            }
            let (rest_gpus, rest_pay, mut rest_plan) =
                self.dp(idx + 1, jobs, &st, prices, now, memo);
            let gpus = rest_gpus + alloc.total_gpus();
            let pay = payoff + rest_pay;
            if gpus > best.0 || (gpus == best.0 && pay > best.1) {
                rest_plan.push((jobs[idx].id, alloc));
                best = (gpus, pay, rest_plan);
            }
        }

        if memo.len() < self.cfg.dp_memo_cap {
            memo.insert(key, best.clone());
        }
        best
    }

    /// Large-queue path: payoff-density greedy (utility per requested GPU,
    /// recomputed against live prices), O(n log n + n·H·R).
    fn greedy(&mut self, jobs: &[&Job], state: &mut ClusterState,
              prices: &PriceTable, now: f64)
              -> Vec<(JobId, JobAllocation)> {
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            let da = jobs[a].utility(jobs[a].t_min())
                / jobs[a].gpus_requested.max(1) as f64;
            let db = jobs[b].utility(jobs[b].t_min())
                / jobs[b].gpus_requested.max(1) as f64;
            db.partial_cmp(&da).unwrap()
        });
        let mut out = Vec::new();
        for i in order {
            if state.is_full() {
                break;
            }
            if let Some((alloc, _)) =
                self.find_alloc(jobs[i], state, prices, now)
            {
                for a in alloc.assignments(jobs[i].id) {
                    state.allocate(a);
                }
                out.push((jobs[i].id, alloc));
            }
        }
        out
    }

    /// Drop the per-job type cache for completed jobs (bounded memory).
    pub fn forget_job(&mut self, id: JobId) {
        self.type_order.remove(&id);
    }
}

impl Scheduler for Hadar {
    fn name(&self) -> &'static str {
        "hadar"
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        self.stats.rounds += 1;
        let jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        if jobs.is_empty() {
            self.prev_plan = RoundPlan::new();
            return RoundPlan::new();
        }

        let gpu_types = ctx.cluster.gpu_types();
        let bounds =
            PriceBounds::from_jobs(&jobs, &gpu_types, ctx.horizon, self.cfg.eta);
        let prices = PriceTable::new(bounds);
        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();

        // Incremental mode: carry over running jobs' allocations when they
        // still fit; only the remainder is (re)scheduled.
        let mut pending: Vec<&Job> = Vec::new();
        if self.cfg.incremental {
            for job in &jobs {
                if let Some(prev) = self.prev_plan.get(job.id) {
                    let fits = prev.slots.iter().all(|(&(h, g), &c)| {
                        state.free(h, g) >= c
                    });
                    if fits {
                        for a in prev.assignments(job.id) {
                            state.allocate(a);
                        }
                        plan.insert(job.id, prev.clone());
                        continue;
                    }
                }
                pending.push(job);
            }
        } else {
            pending = jobs.clone();
        }

        // LPT-flavoured queue order: longest *total* best-case runtime
        // first, so FIND_ALLOC hands the fastest pools to the jobs that
        // gate the makespan. The key is static (t_j^min, not remaining
        // time) so the order — and therefore the job->node matching — is
        // stable across rounds: re-sorting on remaining time makes jobs
        // swap nodes mid-flight and pay checkpoint-restart every round.
        pending.sort_by(|a, b| {
            b.t_min()
                .partial_cmp(&a.t_min())
                .unwrap()
                .then(a.id.cmp(&b.id))
        });

        let chosen: Vec<(JobId, JobAllocation)> =
            if pending.len() <= self.cfg.dp_job_cap {
                self.stats.dp_invocations += 1;
                let mut memo = HashMap::new();
                let (_, _, sub) =
                    self.dp(0, &pending, &state, &prices, ctx.now, &mut memo);
                sub
            } else {
                self.stats.greedy_invocations += 1;
                self.greedy(&pending, &mut state, &prices, ctx.now)
            };
        for (id, alloc) in chosen {
            plan.insert(id, alloc);
        }

        // Change tracking (the paper's ~30% observation).
        let changed = jobs.iter().any(|j| {
            plan.get(j.id) != self.prev_plan.get(j.id)
        });
        if changed {
            self.stats.rounds_with_change += 1;
        }
        self.prev_plan = plan.clone();
        plan
    }

    /// Drain preemption: forget the job's previous allocation so
    /// incremental mode does not try to carry a placement onto hardware
    /// that left the cluster. The throughput-order cache stays — the job
    /// itself is unchanged and will be rescheduled.
    fn preempt(&mut self, job: JobId) {
        self.prev_plan.allocations.remove(&job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    /// The §II-A motivational jobs: J1 (3 GPUs, 80 epochs), J2 (2, 30),
    /// J3 (2, 50).
    fn motivational_jobs() -> JobQueue {
        let mut q = JobQueue::new();
        for (id, w, epochs) in [(1u64, 3usize, 80u64), (2, 2, 30), (3, 2, 50)] {
            let mut j = Job::new(id, DlModel::ResNet18, 0.0, w, epochs, 100);
            // Fig. 1's X-matrix flavour: V100 fastest, K80 slow.
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::P100, 25.0);
            j.set_throughput(GpuType::K80, 8.0);
            q.admit(j);
        }
        q
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            cluster,
        }
    }

    #[test]
    fn schedules_across_heterogeneous_types() {
        // The headline behaviour: with 2 V100 + 3 P100 + 1 K80 free, a
        // 3-GPU job CAN run (Gavel could not if no single type has 3).
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(1)).expect("J1 scheduled");
        assert_eq!(alloc.total_gpus(), 3);
    }

    #[test]
    fn respects_gang_all_or_nothing() {
        let cluster = ClusterSpec::motivational(); // 6 GPUs
        let mut queue = JobQueue::new();
        let mut j = Job::new(1, DlModel::ResNet18, 0.0, 9, 10, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        queue.admit(j);
        let active = vec![JobId(1)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_none(), "9 > 6 GPUs: must not run");
    }

    #[test]
    fn packs_cluster_with_multiple_jobs() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        // 6 GPUs, demands 3+2+2: at least two jobs (5 GPUs) run.
        assert!(plan.scheduled_jobs().len() >= 2);
        assert!(plan.total_gpus() >= 5);
        // Capacity respected per pool.
        let mut used: BTreeMap<(usize, GpuType), usize> = BTreeMap::new();
        for (_, alloc) in &plan.allocations {
            for (&k, &c) in &alloc.slots {
                *used.entry(k).or_insert(0) += c;
            }
        }
        let state = ClusterState::new(&cluster);
        for ((h, g), c) in used {
            assert!(c <= state.capacity(h, g));
        }
    }

    #[test]
    fn prefers_fast_types_when_free() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active = vec![JobId(2)]; // W=2, both V100 free
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(2)).unwrap();
        // Packed on the V100 node (fastest, zero comm cost) is optimal.
        assert_eq!(alloc.gpu_types(), vec![GpuType::V100]);
    }

    #[test]
    fn greedy_path_engages_beyond_cap() {
        let cluster = ClusterSpec::sim60();
        let mut queue = JobQueue::new();
        for id in 0..40u64 {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            queue.admit(j);
        }
        let active: Vec<JobId> = (0..40).map(JobId).collect();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(hadar.stats.greedy_invocations, 1);
        assert_eq!(hadar.stats.dp_invocations, 0);
        // 60 GPUs, 40 single-GPU jobs: all should run.
        assert_eq!(plan.scheduled_jobs().len(), 40);
    }

    #[test]
    fn incremental_mode_keeps_running_allocations() {
        let cluster = ClusterSpec::motivational();
        let queue = motivational_jobs();
        let active: Vec<JobId> = vec![JobId(1), JobId(2), JobId(3)];
        let mut hadar = Hadar::with_config(HadarConfig {
            incremental: true,
            ..Default::default()
        });
        let plan1 = hadar.schedule(&ctx(&queue, &active, &cluster));
        let plan2 = hadar.schedule(&ctx(&queue, &active, &cluster));
        for id in plan1.scheduled_jobs() {
            assert_eq!(plan1.get(id), plan2.get(id), "{id} moved");
        }
        // Round 2 changed nothing.
        assert_eq!(hadar.stats.rounds_with_change, 1);
    }

    #[test]
    fn empty_queue_yields_empty_plan() {
        let cluster = ClusterSpec::motivational();
        let queue = JobQueue::new();
        let mut hadar = Hadar::new();
        let plan = hadar.schedule(&ctx(&queue, &[], &cluster));
        assert!(plan.scheduled_jobs().is_empty());
    }
}
