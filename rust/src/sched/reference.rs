//! **Reference Hadar** — the pre-optimisation solver, frozen.
//!
//! This is the clone-based implementation of Algorithms 1-2 exactly as it
//! stood before the zero-clone rework of [`crate::sched::hadar`]:
//!
//! * every DP select branch **clones the whole [`ClusterState`]**;
//! * the memo stores and re-clones a full sub-plan
//!   `Vec<(JobId, JobAllocation)>` per entry;
//! * the memo key recomputes an **FNV digest over every (node, type)
//!   pool** at every DP node;
//! * `FIND_ALLOC` **rebuilds and re-sorts** per-type free-slot lists on
//!   every invocation.
//!
//! It exists for two jobs and must not be "improved":
//!
//! 1. **Equivalence oracle** — `rust/tests/prop_equivalence.rs` drives it
//!    and the optimised solver over seeded random (cluster, queue)
//!    scenarios (including incremental mode and drain preemption) and
//!    requires identical [`RoundPlan`]s round for round.
//! 2. **Baseline for the perf claim** — `benches/l3_sched_micro.rs` and
//!    `hadar bench --json` time it against the optimised solver; the
//!    before/after gap is the number `docs/performance.md` tracks. This
//!    now includes the streaming rows: [`RefHadar`] is the **frozen
//!    serial reference** the `hadar_stream_*` bench cases and the
//!    thread-count-invariance property pin the speculative sharded
//!    greedy against (above 200k jobs the bench skips this side — the
//!    per-call re-sorts preserved here would dominate the run).
//!
//! Deliberate deviations from the historical code: float comparators use
//! `total_cmp` instead of `partial_cmp().unwrap()` (so a degenerate input
//! fails a comparison test rather than panicking the oracle; ordering is
//! identical for non-NaN keys), and the digest is computed locally
//! because [`ClusterState`] now maintains a Zobrist digest instead of
//! offering an FNV rescan.
//!
//! Measurement caveat: this solver runs on the *current* [`ClusterState`],
//! so its `state.clone()` per select branch also copies the free-slot
//! bucket index, and its `allocate()` calls pay the Zobrist/bucket
//! maintenance the historical state did not have. `ref_ms` in
//! `BENCH_sched.json` therefore slightly *overstates* the historical
//! baseline's cost (the maintenance is small next to the clones, rescans,
//! and re-sorts this module preserves, but compare `speedup` with that
//! grain of salt — see `docs/performance.md`).
//!
//! This module also freezes [`RefHadarE`] — the pre-gang HadarE planner,
//! preserved when `sched::hadare` was generalised to whole-node gangs and
//! reworked onto flat tables. On single-GPU clusters (where "one GPU" and
//! "whole node" coincide) the reworked planner must match it plan for
//! plan; on multi-GPU clusters the divergence *is* the bugfix (the frozen
//! planner drives one GPU per node). Same two jobs as [`RefHadar`]:
//! equivalence oracle (`rust/tests/prop_equivalence.rs`) and perf
//! baseline (`sched::bench`'s `fork_*` cases).

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::hadar::HadarConfig;
use crate::sched::price::{PriceBounds, PriceTable};
use crate::sched::{RoundCtx, Scheduler};
use std::collections::{BTreeMap, HashMap};

/// The frozen pre-optimisation Hadar (see module docs).
pub struct RefHadar {
    /// Tunables — same knobs as the optimised solver.
    pub cfg: HadarConfig,
    type_order: BTreeMap<JobId, Vec<GpuType>>,
    prev_plan: RoundPlan,
}

impl Default for RefHadar {
    fn default() -> Self {
        Self::new()
    }
}

impl RefHadar {
    /// Reference solver with the paper-default [`HadarConfig`].
    pub fn new() -> Self {
        RefHadar::with_config(HadarConfig::default())
    }

    /// Reference solver with explicit tunables (must match the optimised
    /// instance it is compared against).
    pub fn with_config(cfg: HadarConfig) -> Self {
        RefHadar {
            cfg,
            type_order: BTreeMap::new(),
            prev_plan: RoundPlan::new(),
        }
    }

    /// Historical `sorted_types`: clones the cached Vec on every call.
    fn sorted_types(&mut self, job: &Job) -> Vec<GpuType> {
        if let Some(t) = self.type_order.get(&job.id) {
            return t.clone();
        }
        let mut types: Vec<GpuType> = job
            .throughput
            .iter()
            .filter(|(_, &x)| x > 0.0)
            .map(|(&g, _)| g)
            .collect();
        types.sort_by(|a, b| {
            job.throughput_on(*b).total_cmp(&job.throughput_on(*a))
        });
        self.type_order.insert(job.id, types.clone());
        types
    }

    fn payoff(job: &Job, alloc: &JobAllocation, cost: f64, comm: f64,
              now: f64, min_efficiency: f64) -> f64 {
        let x_min = alloc
            .gpu_types()
            .iter()
            .map(|&g| job.throughput_on(g))
            .fold(f64::INFINITY, f64::min);
        if !x_min.is_finite() || x_min <= 0.0 {
            return f64::NEG_INFINITY;
        }
        if x_min < min_efficiency * job.max_throughput() {
            return f64::NEG_INFINITY;
        }
        let rate = alloc.total_gpus() as f64 * x_min;
        let est_duration = (now - job.arrival) + job.remaining_iters() / rate;
        job.utility(est_duration.max(job.t_min())) - cost - comm
    }

    /// Historical FIND_ALLOC: rebuilds + sorts per-type slot lists on
    /// every call.
    fn find_alloc(&mut self, job: &Job, state: &ClusterState,
                  prices: &PriceTable, now: f64)
                  -> Option<(JobAllocation, f64)> {
        let w = job.gpus_requested.max(1);
        let types = self.sorted_types(job);
        if types.is_empty() {
            return None;
        }
        let mut best: Option<(JobAllocation, f64)> = None;
        let min_eff = self.cfg.min_efficiency;
        let mut consider = |alloc: JobAllocation, cost: f64, comm: f64| {
            if alloc.total_gpus() != w {
                return;
            }
            let p = Self::payoff(job, &alloc, cost, comm, now, min_eff);
            if p > 0.0 && best.as_ref().map_or(true, |(_, bp)| p > *bp) {
                best = Some((alloc, p));
            }
        };

        // Per-call (node, free) lists sorted by free desc — the rebuild
        // the optimised solver's slot index eliminates.
        let per_type_slots: Vec<Vec<(usize, usize)>> = types
            .iter()
            .map(|&g| {
                let mut slots: Vec<(usize, usize)> = (0..state.n_nodes())
                    .map(|h| (h, state.free(h, g)))
                    .filter(|&(_, f)| f > 0)
                    .collect();
                slots.sort_by(|a, b| b.1.cmp(&a.1));
                slots
            })
            .collect();

        // Packed candidates.
        for node in 0..state.n_nodes() {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &g in &types {
                if need == 0 {
                    break;
                }
                let take = state.free(node, g).min(need);
                if take > 0 {
                    cost += prices.marginal_cost(state, node, g, take);
                    alloc.add(node, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                consider(alloc, cost, 0.0);
            }
        }

        // Spread, pure-type.
        for (ti, &g) in types.iter().enumerate() {
            if state.free_of_type(g) < w {
                continue;
            }
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for &(h, free) in &per_type_slots[ti] {
                if need == 0 {
                    break;
                }
                let take = free.min(need);
                cost += prices.marginal_cost(state, h, g, take);
                alloc.add(h, g, take);
                need -= take;
            }
            let nodes_used = alloc.nodes().len();
            let comm = self.comm_cost(job, nodes_used);
            consider(alloc, cost, comm);
        }

        // Spread, mixed-type.
        {
            let mut alloc = JobAllocation::new();
            let mut cost = 0.0;
            let mut need = w;
            for (ti, &g) in types.iter().enumerate() {
                if need == 0 {
                    break;
                }
                for &(h, free) in &per_type_slots[ti] {
                    if need == 0 {
                        break;
                    }
                    let take = free.min(need);
                    cost += prices.marginal_cost(state, h, g, take);
                    alloc.add(h, g, take);
                    need -= take;
                }
            }
            if need == 0 {
                let nodes_used = alloc.nodes().len();
                let comm = self.comm_cost(job, nodes_used);
                consider(alloc, cost, comm);
            }
        }

        best
    }

    fn comm_cost(&self, job: &Job, nodes_used: usize) -> f64 {
        if nodes_used <= 1 {
            return 0.0;
        }
        self.cfg.comm_factor * (nodes_used - 1) as f64
            * job.utility(job.t_min())
    }

    /// Historical memo key: FNV-1a rescan over every (node, type) pool.
    fn fnv_digest(state: &ClusterState) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for node in 0..state.n_nodes() {
            for &g in &GpuType::ALL {
                h ^= state.allocated(node, g) as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }

    /// Historical DP: clones the state per select branch and a full
    /// sub-plan Vec per memo entry.
    #[allow(clippy::type_complexity)]
    fn dp(&mut self, idx: usize, jobs: &[&Job], state: &ClusterState,
          prices: &PriceTable, now: f64,
          memo: &mut HashMap<(usize, u64),
                             (usize, f64, Vec<(JobId, JobAllocation)>)>)
          -> (usize, f64, Vec<(JobId, JobAllocation)>) {
        if idx >= jobs.len() || state.is_full() {
            return (0, 0.0, Vec::new());
        }
        let key = (idx, Self::fnv_digest(state));
        if let Some(hit) = memo.get(&key) {
            return hit.clone();
        }

        // Skip branch.
        let mut best = self.dp(idx + 1, jobs, state, prices, now, memo);

        // Select branch.
        if let Some((alloc, payoff)) =
            self.find_alloc(jobs[idx], state, prices, now)
        {
            let mut st = state.clone();
            for a in alloc.assignments(jobs[idx].id) {
                st.allocate(a);
            }
            let (rest_gpus, rest_pay, mut rest_plan) =
                self.dp(idx + 1, jobs, &st, prices, now, memo);
            let gpus = rest_gpus + alloc.total_gpus();
            let pay = payoff + rest_pay;
            if gpus > best.0 || (gpus == best.0 && pay > best.1) {
                rest_plan.push((jobs[idx].id, alloc));
                best = (gpus, pay, rest_plan);
            }
        }

        if memo.len() < self.cfg.dp_memo_cap {
            memo.insert(key, best.clone());
        }
        best
    }

    /// Historical greedy (identical selection logic to the optimised one).
    fn greedy(&mut self, jobs: &[&Job], state: &mut ClusterState,
              prices: &PriceTable, now: f64)
              -> Vec<(JobId, JobAllocation)> {
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            let da = jobs[a].utility(jobs[a].t_min())
                / jobs[a].gpus_requested.max(1) as f64;
            let db = jobs[b].utility(jobs[b].t_min())
                / jobs[b].gpus_requested.max(1) as f64;
            db.total_cmp(&da)
        });
        let mut out = Vec::new();
        for i in order {
            if state.is_full() {
                break;
            }
            if let Some((alloc, _)) =
                self.find_alloc(jobs[i], state, prices, now)
            {
                for a in alloc.assignments(jobs[i].id) {
                    state.allocate(a);
                }
                out.push((jobs[i].id, alloc));
            }
        }
        out
    }
}

impl Scheduler for RefHadar {
    fn name(&self) -> &'static str {
        "hadar-ref"
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        let jobs: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete())
            .collect();
        if jobs.is_empty() {
            self.prev_plan = RoundPlan::new();
            return RoundPlan::new();
        }

        let gpu_types = ctx.cluster.gpu_types();
        let bounds =
            PriceBounds::from_jobs(&jobs, &gpu_types, ctx.horizon, self.cfg.eta);
        let prices = PriceTable::new(bounds);
        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();

        let mut pending: Vec<&Job> = Vec::new();
        if self.cfg.incremental {
            for job in &jobs {
                if let Some(prev) = self.prev_plan.get(job.id) {
                    let fits = prev.slots.iter().all(|(&(h, g), &c)| {
                        state.free(h, g) >= c
                    });
                    if fits {
                        for a in prev.assignments(job.id) {
                            state.allocate(a);
                        }
                        plan.insert(job.id, prev.clone());
                        continue;
                    }
                }
                pending.push(job);
            }
        } else {
            pending = jobs.clone();
        }

        pending.sort_by(|a, b| {
            b.t_min().total_cmp(&a.t_min()).then(a.id.cmp(&b.id))
        });

        let chosen: Vec<(JobId, JobAllocation)> =
            if pending.len() <= self.cfg.dp_job_cap {
                let mut memo = HashMap::new();
                let (_, _, sub) =
                    self.dp(0, &pending, &state, &prices, ctx.now, &mut memo);
                sub
            } else {
                self.greedy(&pending, &mut state, &prices, ctx.now)
            };
        for (id, alloc) in chosen {
            plan.insert(id, alloc);
        }

        self.prev_plan = plan.clone();
        plan
    }

    /// Drain preemption — identical contract to the optimised solver's.
    fn preempt(&mut self, job: JobId) {
        self.prev_plan.allocations.remove(&job);
    }
}

/// The frozen pre-gang HadarE planner (see module docs). One GPU slot per
/// node (`primary_gpu`), per-round `BTreeMap` tables, a seven-argument
/// placement closure — exactly as the planner stood before the whole-node
/// gang rework. Must not be "improved".
///
/// Deliberate deviation, as with [`RefHadar`]: float comparators use
/// `total_cmp` instead of the historical `partial_cmp().unwrap()`
/// (identical ordering for non-NaN keys; a malformed row fails an
/// equivalence case instead of panicking the oracle).
pub struct RefHadarE {
    /// Copies per job (usually = node count; Theorem 3's maximum).
    pub copies: u64,
}

impl RefHadarE {
    /// Reference planner with a per-parent copy budget.
    pub fn new(copies: u64) -> Self {
        RefHadarE { copies }
    }

    /// Historical `plan_round`: assigns one single-GPU slot per node via
    /// the same fairness / payoff-greedy / work-conservation passes as
    /// the live planner.
    pub fn plan_round(&mut self, ctx: &RoundCtx, tracker: &JobTracker)
                      -> RoundPlan {
        // Parents with work left, by remaining steps (desc).
        let mut parents: Vec<(JobId, f64)> = tracker
            .parents()
            .filter(|(_, p)| !p.is_complete())
            .map(|(&id, p)| (id, p.remaining()))
            .collect();
        parents.sort_by(|a, b| b.1.total_cmp(&a.1));
        let mut plan = RoundPlan::new();
        if parents.is_empty() {
            return plan;
        }

        // Node inventory: (node id, gpu type) — single-GPU nodes.
        let nodes: Vec<(usize, GpuType)> = ctx
            .cluster
            .nodes
            .iter()
            .filter_map(|n| n.primary_gpu().map(|g| (n.id, g)))
            .collect();

        let job_of = |id: JobId| -> Option<&Job> { ctx.queue.get(id) };
        let mut node_load: BTreeMap<usize, bool> = BTreeMap::new();
        let mut copies_used: BTreeMap<JobId, u64> = BTreeMap::new();
        let mut placed_on: BTreeMap<(JobId, usize), bool> = BTreeMap::new();

        let place = |pid: JobId, h: usize, g: GpuType,
                         plan: &mut RoundPlan,
                         node_load: &mut BTreeMap<usize, bool>,
                         copies_used: &mut BTreeMap<JobId, u64>,
                         placed_on: &mut BTreeMap<(JobId, usize), bool>| {
            let i = copies_used.get(&pid).copied().unwrap_or(0) + 1;
            let copy = tracker.ids.copy_id(pid, i);
            let mut alloc = JobAllocation::new();
            alloc.add(h, g, 1);
            plan.insert(copy, alloc);
            node_load.insert(h, true);
            copies_used.insert(pid, i);
            placed_on.insert((pid, h), true);
        };

        // Pass 0: fairness — every unfinished parent first gets its best
        // still-free node (longest-remaining parent picks first).
        for &(pid, _) in &parents {
            if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                continue;
            }
            let best = nodes
                .iter()
                .filter(|&&(h, _)| !node_load.get(&h).unwrap_or(&false))
                .filter_map(|&(h, g)| {
                    job_of(pid).map(|j| (h, g, j.throughput_on(g)))
                })
                .filter(|&(_, _, x)| x > 0.0)
                .max_by(|a, b| a.2.total_cmp(&b.2));
            if let Some((h, g, _)) = best {
                place(pid, h, g, &mut plan, &mut node_load,
                      &mut copies_used, &mut placed_on);
            }
        }

        // Build all candidate (score, parent, node, gpu) tuples.
        let mut cands: Vec<(f64, JobId, usize, GpuType)> = Vec::new();
        for &(pid, remaining) in &parents {
            if let Some(job) = job_of(pid) {
                for &(h, g) in &nodes {
                    let x = job.throughput_on(g);
                    if x > 0.0 {
                        let burn = (x * ctx.slot_secs).min(remaining);
                        cands.push((burn, pid, h, g));
                    }
                }
            }
        }
        cands.sort_by(|a, b| b.0.total_cmp(&a.0));

        // Pass 1: payoff-greedy with the per-parent copy budget.
        for &(_, pid, h, g) in &cands {
            if *node_load.get(&h).unwrap_or(&false) {
                continue;
            }
            if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                continue;
            }
            if placed_on.contains_key(&(pid, h)) {
                continue;
            }
            place(pid, h, g, &mut plan, &mut node_load, &mut copies_used,
                  &mut placed_on);
        }

        // Pass 2: work conservation — fill any idle node with the parent
        // owning the most remaining work not already on that node.
        for &(h, g) in &nodes {
            if *node_load.get(&h).unwrap_or(&false) {
                continue;
            }
            for &(pid, _) in &parents {
                if placed_on.contains_key(&(pid, h)) {
                    continue;
                }
                if copies_used.get(&pid).copied().unwrap_or(0) >= self.copies {
                    continue;
                }
                let ok = job_of(pid)
                    .map(|j| j.throughput_on(g) > 0.0)
                    .unwrap_or(false);
                if ok {
                    let i = copies_used.get(&pid).copied().unwrap_or(0) + 1;
                    let copy = tracker.ids.copy_id(pid, i);
                    let mut alloc = JobAllocation::new();
                    alloc.add(h, g, 1);
                    plan.insert(copy, alloc);
                    node_load.insert(h, true);
                    copies_used.insert(pid, i);
                    placed_on.insert((pid, h), true);
                    break;
                }
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    #[test]
    fn reference_schedules_the_motivational_job() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        let mut j = Job::new(1, DlModel::ResNet18, 0.0, 3, 80, 100);
        j.set_throughput(GpuType::V100, 40.0);
        j.set_throughput(GpuType::P100, 25.0);
        j.set_throughput(GpuType::K80, 8.0);
        queue.admit(j).unwrap();
        let active = vec![JobId(1)];
        let mut s = RefHadar::new();
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue: &queue,
            active: &active,
            delta: None,
            cluster: &cluster,
        };
        let plan = s.schedule(&ctx);
        assert_eq!(plan.get(JobId(1)).unwrap().total_gpus(), 3);
    }

    #[test]
    fn reference_hadare_drives_one_gpu_per_node() {
        // The frozen planner's defining (buggy-on-multi-GPU) behaviour:
        // on sim60 it books one GPU per node — 15, not 60. The live
        // planner's divergence here is the bugfix; equivalence is only
        // required on single-GPU clusters.
        use crate::forking::forker::ForkIds;
        use crate::jobs::throughput;
        use crate::trace::workload::cluster_gpu_pcie;
        let cluster = ClusterSpec::sim60();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut queue = JobQueue::new();
        let ids = ForkIds { max_job_count: 100 };
        let mut tracker = JobTracker::new(ids);
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 20, 100);
        j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
        tracker.register(
            j.id,
            j.total_iters(),
            &(1..=15).map(|i| ids.copy_id(j.id, i)).collect::<Vec<_>>(),
        );
        queue.admit(j).unwrap();
        let mut r = RefHadarE::new(15);
        let ctx = RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue: &queue,
            active: &[],
            delta: None,
            cluster: &cluster,
        };
        let plan = r.plan_round(&ctx, &tracker);
        assert_eq!(plan.scheduled_jobs().len(), 15);
        assert_eq!(plan.total_gpus(), 15, "one GPU per node — the bug");
    }
}
