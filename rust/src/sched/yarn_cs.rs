//! **YARN-CS** baseline — Apache YARN's capacity scheduler as used for the
//! paper's production-default comparison: FIFO admission, *non-preemptive*
//! (a running job keeps its GPUs until completion), heterogeneity-unaware.
//!
//! Non-preemption is why YARN-CS posts the highest GPU utilisation in
//! Fig. 3 while posting the worst total time duration in Fig. 4.

use crate::cluster::gpu::GpuType;
use crate::cluster::state::ClusterState;
use crate::jobs::job::{Job, JobId, JobStatus};
use crate::sched::alloc::{JobAllocation, RoundPlan};
use crate::sched::{RoundCtx, Scheduler};
use std::collections::BTreeMap;

/// The YARN capacity-scheduler baseline (see module docs).
pub struct YarnCs {
    /// Allocations pinned at admission; released only on completion (or a
    /// forced drain preemption).
    running: BTreeMap<JobId, JobAllocation>,
}

impl Default for YarnCs {
    fn default() -> Self {
        Self::new()
    }
}

impl YarnCs {
    /// Fresh scheduler with no pinned allocations.
    pub fn new() -> Self {
        YarnCs {
            running: BTreeMap::new(),
        }
    }

    /// FIFO placement: first free pool that fits the whole gang, mixing
    /// types only if a single type can't fit (capacity scheduler treats
    /// all GPUs as one resource dimension).
    fn place(state: &ClusterState, w: usize, types: &[GpuType])
             -> Option<JobAllocation> {
        // Prefer a single type (consolidated behaviour of CS node labels).
        for &r in types {
            if state.free_of_type(r) >= w {
                let mut alloc = JobAllocation::new();
                let mut need = w;
                for h in 0..state.n_nodes() {
                    if need == 0 {
                        break;
                    }
                    let take = state.free(h, r).min(need);
                    alloc.add(h, r, take);
                    need -= take;
                }
                return Some(alloc);
            }
        }
        // Fall back to any free GPUs (resource-dimension blindness).
        if state.total_free() >= w {
            let mut alloc = JobAllocation::new();
            let mut need = w;
            for (h, g, free) in state.free_slots() {
                if need == 0 {
                    break;
                }
                let take = free.min(need);
                alloc.add(h, g, take);
                need -= take;
            }
            if alloc.total_gpus() == w {
                return Some(alloc);
            }
        }
        None
    }
}

impl Scheduler for YarnCs {
    fn name(&self) -> &'static str {
        "yarn-cs"
    }

    fn preemptive(&self) -> bool {
        false
    }

    /// Even the non-preemptive baseline loses a placement when its node
    /// drains: drop the pin so the job re-queues (FIFO) instead of
    /// re-asserting GPUs that no longer exist.
    fn preempt(&mut self, job: JobId) {
        self.running.remove(&job);
    }

    /// Completion: release the pin immediately (schedule() also sweeps
    /// completed pins defensively at round start).
    fn job_completed(&mut self, job: JobId) {
        self.running.remove(&job);
    }

    fn schedule(&mut self, ctx: &RoundCtx) -> RoundPlan {
        // Drop completed jobs from the pinned set.
        self.running.retain(|id, _| {
            ctx.queue
                .get(*id)
                .map(|j| j.status != JobStatus::Completed && !j.is_complete())
                .unwrap_or(false)
        });

        let mut state = ClusterState::new(ctx.cluster);
        let mut plan = RoundPlan::new();
        // Re-assert pinned allocations.
        for (&id, alloc) in &self.running {
            for a in alloc.assignments(id) {
                state.allocate(a);
            }
            plan.insert(id, alloc.clone());
        }

        // Admit waiting jobs strictly FIFO (head-of-line blocking is part
        // of the baseline's behaviour).
        let mut waiting: Vec<&Job> = ctx
            .active
            .iter()
            .filter_map(|&id| ctx.queue.get(id))
            .filter(|j| !j.is_complete() && !self.running.contains_key(&j.id))
            .collect();
        waiting.sort_by(|a, b| {
            // total_cmp: a NaN arrival must not panic the round.
            a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id))
        });
        let types = ctx.cluster.gpu_types();
        for job in waiting {
            if state.is_full() {
                break;
            }
            // FIFO admission order with backfill: a job that does not fit
            // is skipped (capacity-scheduler leaf queues effectively let
            // smaller jobs start while a big head waits); admitted jobs
            // are never preempted.
            if let Some(alloc) =
                Self::place(&state, job.gpus_requested.max(1), &types)
            {
                for a in alloc.assignments(job.id) {
                    state.allocate(a);
                }
                plan.insert(job.id, alloc.clone());
                self.running.insert(job.id, alloc);
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::ClusterSpec;
    use crate::jobs::model::DlModel;
    use crate::jobs::queue::JobQueue;

    fn mk_job(id: u64, w: usize, arrival: f64) -> Job {
        let mut j = Job::new(id, DlModel::Lstm, arrival, w, 10, 100);
        j.set_throughput(GpuType::V100, 60.0);
        j.set_throughput(GpuType::P100, 40.0);
        j.set_throughput(GpuType::K80, 15.0);
        j
    }

    fn ctx<'a>(queue: &'a JobQueue, active: &'a [JobId],
               cluster: &'a ClusterSpec) -> RoundCtx<'a> {
        RoundCtx {
            round: 0,
            now: 0.0,
            slot_secs: 360.0,
            horizon: 100_000.0,
            queue,
            active,
            delta: None,
            cluster,
        }
    }

    #[test]
    fn fifo_with_backfill() {
        let cluster = ClusterSpec::motivational(); // 6 GPUs
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 5, 0.0)).unwrap(); // takes most of the cluster
        queue.admit(mk_job(2, 4, 1.0)).unwrap(); // cannot fit -> waits
        queue.admit(mk_job(3, 1, 2.0)).unwrap(); // backfills the last GPU
        let active = vec![JobId(1), JobId(2), JobId(3)];
        let mut y = YarnCs::new();
        let plan = y.schedule(&ctx(&queue, &active, &cluster));
        assert!(plan.get(JobId(1)).is_some());
        assert!(plan.get(JobId(2)).is_none(), "4-gang cannot fit");
        assert!(plan.get(JobId(3)).is_some(), "small job backfills");
    }

    #[test]
    fn allocations_are_pinned_until_completion() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 2, 0.0)).unwrap();
        let active = vec![JobId(1)];
        let mut y = YarnCs::new();
        let p1 = y.schedule(&ctx(&queue, &active, &cluster));
        let p2 = y.schedule(&ctx(&queue, &active, &cluster));
        assert_eq!(p1.get(JobId(1)), p2.get(JobId(1)));
        // After completion the pin is dropped.
        queue.get_mut(JobId(1)).unwrap().progress = 1000.0;
        queue.get_mut(JobId(1)).unwrap().status = JobStatus::Completed;
        let p3 = y.schedule(&ctx(&queue, &[], &cluster));
        assert!(p3.get(JobId(1)).is_none());
    }

    #[test]
    fn non_preemptive_flag() {
        assert!(!YarnCs::new().preemptive());
    }

    #[test]
    fn mixes_types_when_no_single_type_fits() {
        let cluster = ClusterSpec::motivational();
        let mut queue = JobQueue::new();
        queue.admit(mk_job(1, 5, 0.0)).unwrap();
        let active = vec![JobId(1)];
        let mut y = YarnCs::new();
        let plan = y.schedule(&ctx(&queue, &active, &cluster));
        let alloc = plan.get(JobId(1)).expect("5 of 6 GPUs free");
        assert_eq!(alloc.total_gpus(), 5);
        assert!(alloc.gpu_types().len() > 1);
    }
}
