//! Discrete-time simulation (paper §IV) and the HadarE forked-round engine
//! (paper §V), plus derived metrics. Both engines also run under a
//! [`crate::cluster::events::EventTimeline`] (node joins, drains,
//! maintenance windows, capacity changes) via their `run_with_events`
//! entry points.

pub mod engine;
pub mod hadare_engine;
pub mod metrics;

pub use engine::{run, run_with_events, RoundRecord, SimConfig, SimResult};
pub use hadare_engine::{
    run as run_hadare, run_with_events as run_hadare_with_events,
    run_with_gang as run_hadare_with_gang, CopyWork, HadarESimResult,
};
pub use metrics::{completion_cdf, Metrics};
