//! Discrete-time simulation (paper §IV) and the HadarE forked-round engine
//! (paper §V), plus derived metrics.

pub mod engine;
pub mod hadare_engine;
pub mod metrics;

pub use engine::{run, RoundRecord, SimConfig, SimResult};
pub use hadare_engine::{run as run_hadare, CopyWork, HadarESimResult};
pub use metrics::{completion_cdf, Metrics};
