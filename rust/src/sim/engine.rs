//! Discrete-time, round-based trace-driven simulator (paper §IV).
//!
//! Each round of length `L` the engine asks the scheduler for a plan,
//! charges the 10-second checkpoint-restart overhead to every job whose
//! allocation changed (paper §IV: "The overhead of each checkpoint-restart
//! is simulated by enforcing a 10-second delay when a job receives a new
//! allocation"), advances progress with the bottleneck-throughput rule
//! (Eq. 1b — all workers run at the slowest device's pace), and records
//! utilisation/time metrics.
//!
//! With a [`crate::cluster::events::EventTimeline`] (via
//! [`run_with_events`]) the cluster is *dynamic*: due events apply at each
//! round boundary, jobs on drained/shrunk nodes are preempted (their next
//! placement pays the checkpoint-restart overhead) and re-queued, the
//! scheduler sees the current cluster every round, and
//! [`SimResult::anu`] reports utilisation normalised by the capacity that
//! was actually *available* over time rather than the nominal capacity.

use crate::cluster::events::{ClusterTimeline, EventTimeline};
use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::{JobId, JobStatus};
use crate::jobs::queue::JobQueue;
use crate::obs;
use crate::obs::export::{RoundTelemetry, TelemetrySink};
use crate::sched::alloc::RoundPlan;
use crate::sched::{RoundCtx, Scheduler, SolverStats};
use std::collections::BTreeMap;
use std::time::Instant;

/// Engine parameters shared by the generic and HadarE round engines.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Round/slot length `L` in seconds (paper default: 6 minutes).
    pub slot_secs: f64,
    /// Checkpoint-restart delay charged on allocation change (10 s).
    pub restart_overhead: f64,
    /// Safety cap on rounds.
    pub max_rounds: u64,
    /// Horizon `T` handed to price-based schedulers.
    pub horizon: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            slot_secs: 360.0,
            restart_overhead: 10.0,
            max_rounds: 20_000,
            horizon: 14.0 * 24.0 * 3600.0,
        }
    }
}

/// Per-job, per-round accounting (drives both figure timelines and the
/// real-training replay in `exec`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundJob {
    /// GPUs allocated to the job this round.
    pub gpus: usize,
    /// Remaining iterations at round start.
    pub remaining_before: f64,
    /// Iterations progressed this round.
    pub progressed: f64,
    /// First node hosting the job this round (single-GPU-node clusters).
    pub node: usize,
}

/// One round's record, enough to redraw Fig. 1 / Fig. 6 style timelines.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    /// Round number (0-based).
    pub round: u64,
    /// Virtual time at round start (seconds).
    pub start: f64,
    /// Per-job accounting (only when timelines are recorded).
    pub jobs: BTreeMap<JobId, RoundJob>,
    /// Busy GPU-seconds this round (excludes restart overhead).
    pub busy_gpu_secs: f64,
    /// GPU-seconds *allocated* this round (scheduled jobs x slot).
    pub alloc_gpu_secs: f64,
    /// Total GPU-seconds available this round (tracks the *current*
    /// cluster under an event timeline).
    pub avail_gpu_secs: f64,
}

/// Simulation outcome + metrics inputs.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Name of the scheduler that produced this run.
    pub scheduler: String,
    /// Total time duration (makespan), seconds.
    pub ttd: f64,
    /// Per-job completion times `f_j - a_j` (seconds).
    pub jct: BTreeMap<JobId, f64>,
    /// Completion instants `f_j` (for the Fig. 4 CDF).
    pub finish_times: Vec<f64>,
    /// Aggregate GPU resource utilisation in [0, 1]: busy time over
    /// *nominal* (initial) capacity x makespan (Fig. 3's GRU).
    pub gru: f64,
    /// Cluster resource utilisation in [0, 1]: busy time over *allocated*
    /// node-slots (the paper's §VI CRU — idle/unallocated nodes don't
    /// enter the denominator, wasted slot tails and restarts do).
    pub cru: f64,
    /// Availability-normalised utilisation in [0, 1]: busy GPU-seconds
    /// over the GPU-seconds actually *available* (the capacity step
    /// function integrated over the makespan). Equal to [`SimResult::gru`]
    /// on a static cluster; the honest utilisation figure under node
    /// churn, where nominal capacity overstates what schedulers could use.
    pub anu: f64,
    /// Rounds executed.
    pub rounds: u64,
    /// Jobs force-preempted by node drains / capacity shrinks.
    pub preemptions: u64,
    /// Cluster events applied over the run.
    pub events_applied: u64,
    /// Wall-clock seconds spent inside `Scheduler::schedule`.
    pub sched_wall_secs: f64,
    /// Mean wall-clock per scheduling round (Fig. 5's y-axis).
    pub sched_wall_per_round: f64,
    /// Per-round records (empty unless requested).
    pub timeline: Vec<RoundRecord>,
    /// Fraction of rounds whose plan differed from the previous round's.
    pub change_fraction: f64,
    /// Solver-internal counters at run end, for schedulers that expose
    /// them ([`Scheduler::solver_stats`]); `None` for the baselines.
    pub solver: Option<SolverStats>,
}

/// Integrate a capacity step function over `[0, ttd]` — the ANU
/// denominator. `segments` holds `(start time, capacity in GPUs)` entries,
/// first at t=0; used by both round engines.
pub(crate) fn integrate_capacity(segments: &[(f64, f64)], ttd: f64) -> f64 {
    let mut total = 0.0;
    for (i, &(t0, gpus)) in segments.iter().enumerate() {
        let t1 = segments
            .get(i + 1)
            .map(|&(t, _)| t)
            .unwrap_or(ttd)
            .min(ttd);
        let t0 = t0.min(ttd);
        if t1 > t0 {
            total += gpus * (t1 - t0);
        }
    }
    total
}

/// Run one scheduler over one workload on a *static* cluster.
/// `record_timeline` keeps per-round records (disable for the 2048-job
/// scalability sweeps).
pub fn run(queue: &mut JobQueue, scheduler: &mut dyn Scheduler,
           cluster: &ClusterSpec, cfg: &SimConfig, record_timeline: bool)
           -> SimResult {
    run_with_events(queue, scheduler, cluster, &EventTimeline::empty(), cfg,
                    record_timeline)
        .expect("the empty event timeline always resolves")
}

/// Run one scheduler over one workload under a cluster event timeline.
///
/// Due events apply at round boundaries: jobs whose previous allocation
/// touches a drained or shrunk node are preempted (the scheduler is told
/// via [`Scheduler::preempt`], the job goes back to `Queued`, and its next
/// placement pays the checkpoint-restart overhead — it changed
/// allocation), and every round's [`RoundCtx`] carries the *current*
/// cluster. Fails only if `events` does not resolve against `cluster`.
pub fn run_with_events(queue: &mut JobQueue, scheduler: &mut dyn Scheduler,
                       cluster: &ClusterSpec, events: &EventTimeline,
                       cfg: &SimConfig, record_timeline: bool)
                       -> Result<SimResult, String> {
    run_observed(queue, scheduler, cluster, events, cfg, record_timeline,
                 None)
}

/// [`run_with_events`] plus telemetry: when `sink` is given, one
/// [`RoundTelemetry`] record is emitted per scheduling round (idle skips
/// to the next arrival emit nothing — no scheduling happened).
///
/// Observation never perturbs plans: the sink only *reads* round state
/// already computed, and the span/metric hooks are gated on
/// [`crate::obs::enabled`] — the same seed yields identical plans and
/// identical non-timing telemetry with tracing on or off.
pub fn run_observed(queue: &mut JobQueue, scheduler: &mut dyn Scheduler,
                    cluster: &ClusterSpec, events: &EventTimeline,
                    cfg: &SimConfig, record_timeline: bool,
                    mut sink: Option<&mut TelemetrySink>)
                    -> Result<SimResult, String> {
    let mut view = ClusterTimeline::new(cluster, events)?;
    let nominal_gpus = cluster.total_gpus() as f64;
    let mut now = 0.0;
    let mut round = 0u64;
    let mut busy_total = 0.0;
    let mut alloc_total = 0.0;
    // (round start, allocated gpu-secs) — kept even without timelines.
    let mut alloc_log: Vec<(f64, f64)> = Vec::new();
    // Capacity step function (segment start, available GPUs) for ANU.
    let mut avail_log: Vec<(f64, f64)> = vec![(0.0, nominal_gpus)];
    let mut preemptions = 0u64;
    let mut last_finish: f64 = 0.0;
    let mut prev_plan = RoundPlan::new();
    let mut sched_wall = 0.0;
    let mut timeline = Vec::new();
    let mut changed_rounds = 0u64;
    // Round boundaries accumulate into this delta until a scheduling
    // round consumes it — idle skips to the next arrival carry their
    // arrivals/completions/events forward instead of dropping them, so
    // the delta a scheduler observes is exact across skipped boundaries.
    let mut carry = crate::sched::RoundDelta::default();

    while !queue.all_complete() && round < cfg.max_rounds {
        let _round_span = obs::trace::span("sim.round");
        let events_before = view.events_applied();
        let preempts_before = preemptions;
        // Apply cluster events due by this round boundary.
        let event_span = obs::trace::span("sim.events");
        let change = view.advance_to(now);
        if change.capacity_changed {
            avail_log.push((now, view.cluster().total_gpus() as f64));
        }
        if !change.affected.is_empty() {
            // Preempt exactly the jobs whose last-round allocation touches
            // a drained/shrunk node; they re-queue and pay the restart
            // overhead on their next placement. Stale entries of jobs
            // that already completed are dropped without counting — no
            // running work was disturbed.
            let hit: Vec<JobId> = prev_plan
                .allocations
                .iter()
                .filter(|(_, a)| {
                    a.slots.keys().any(|(h, _)| change.affected.contains(h))
                })
                .map(|(&id, _)| id)
                .collect();
            for id in hit {
                prev_plan.allocations.remove(&id);
                let live =
                    queue.get(id).map_or(false, |j| !j.is_complete());
                if live {
                    scheduler.preempt(id);
                    queue.note_preempted(id);
                    if let Some(job) = queue.get_mut(id) {
                        job.status = JobStatus::Queued;
                    }
                    preemptions += 1;
                }
            }
        }
        drop(event_span);

        // Delta production: drain this boundary's arrivals into the
        // persistent waiting set and fold in the buffered completions /
        // preemptions plus the cluster events just applied. O(changes),
        // not O(jobs ever admitted).
        let mut boundary = queue.poll_round(now);
        boundary.events = view.events_applied() - events_before;
        carry.merge(boundary);
        let active = queue.waiting();
        if active.is_empty() {
            // Idle until the next arrival; `carry` keeps this boundary's
            // delta for the round that eventually schedules.
            match queue.next_arrival_after(now) {
                Some(t) => {
                    now = t;
                    continue;
                }
                None => break,
            }
        }
        let delta = std::mem::take(&mut carry);
        scheduler.observe_delta(&delta, queue);
        let (plan, round_wall) = {
            let ctx = RoundCtx {
                round,
                now,
                slot_secs: cfg.slot_secs,
                horizon: cfg.horizon,
                queue,
                active: &active,
                delta: Some(&delta),
                cluster: view.cluster(),
            };
            // lint: allow(wall-clock, reason = "sched_wall telemetry only; the timing feeds SimResult reporting, never scheduling decisions")
            let t0 = Instant::now();
            let plan = {
                let _s = obs::trace::span("sched.schedule");
                scheduler.schedule(&ctx)
            };
            let dt = t0.elapsed().as_secs_f64();
            sched_wall += dt;
            (plan, dt)
        };
        let plan_changed = plan_differs(&plan, &prev_plan);
        if plan_changed {
            changed_rounds += 1;
        }

        let mut rec = RoundRecord {
            round,
            start: now,
            jobs: BTreeMap::new(),
            busy_gpu_secs: 0.0,
            alloc_gpu_secs: 0.0,
            avail_gpu_secs: view.cluster().total_gpus() as f64
                * cfg.slot_secs,
        };

        let mut completed_now: Vec<JobId> = Vec::new();
        let mut restart_charges = 0u64;
        for (&id, alloc) in &plan.allocations {
            let job = queue.get_mut(id).expect("plan references live job");
            if job.is_complete() {
                continue;
            }
            let remaining_before = job.remaining_iters();
            // Restart overhead if this job's allocation changed.
            let changed = prev_plan.get(id) != Some(alloc);
            if changed {
                restart_charges += 1;
            }
            let overhead = if changed { cfg.restart_overhead } else { 0.0 };
            let eff = (cfg.slot_secs - overhead).max(0.0);
            // Bottleneck rule (1b): slowest used type gates every worker.
            let x_min = alloc
                .gpu_types()
                .iter()
                .map(|&g| job.throughput_on(g))
                .fold(f64::INFINITY, f64::min);
            if !x_min.is_finite() || x_min <= 0.0 {
                continue;
            }
            let rate = alloc.total_gpus() as f64 * x_min;
            let need = job.remaining_iters();
            let used_secs = (need / rate).min(eff);
            job.progress += rate * used_secs;
            job.status = JobStatus::Running;
            let done = job.is_complete();
            rec.busy_gpu_secs += alloc.total_gpus() as f64 * used_secs;
            rec.alloc_gpu_secs += alloc.total_gpus() as f64 * cfg.slot_secs;
            if record_timeline {
                rec.jobs.insert(
                    id,
                    RoundJob {
                        gpus: alloc.total_gpus(),
                        remaining_before,
                        progressed: rate * used_secs,
                        node: alloc.nodes().first().copied().unwrap_or(0),
                    },
                );
            }
            if done {
                // Through the queue so the waiting-set index and the
                // next round's delta see the completion.
                let f = now + overhead + used_secs;
                queue.complete(id, f);
                last_finish = last_finish.max(f);
                completed_now.push(id);
            }
        }
        // Completion notifications: let stateful schedulers drop per-job
        // caches (Hadar's type orders, Tiresias' attained service, YARN's
        // pins) so they stay bounded by the live job count.
        let completed_count = completed_now.len();
        for id in completed_now {
            scheduler.job_completed(id);
        }

        if obs::enabled() {
            let m = obs::metrics::core();
            m.sim_rounds.add(1);
            m.sim_queue_depth.set(active.len() as f64);
            m.sim_active_jobs.set(active.len() as f64);
            m.sim_delta_arrivals.add(delta.arrivals.len() as u64);
            m.sim_delta_completions.add(delta.completions.len() as u64);
            m.sim_preemptions.add(preemptions - preempts_before);
            m.sim_restart_charges.add(restart_charges);
            m.sched_round_secs.record(round_wall);
        }
        if let Some(s) = sink.as_deref_mut() {
            let t = RoundTelemetry {
                round,
                now,
                scheduler: scheduler.name().to_string(),
                active_jobs: active.len(),
                scheduled_jobs: plan.allocations.len(),
                gpus_allocated: plan
                    .allocations
                    .values()
                    .map(|a| a.total_gpus())
                    .sum(),
                busy_gpu_secs: rec.busy_gpu_secs,
                alloc_gpu_secs: rec.alloc_gpu_secs,
                avail_gpu_secs: rec.avail_gpu_secs,
                plan_changed,
                preemptions: preemptions - preempts_before,
                events_applied: view.events_applied() - events_before,
                completed: completed_count,
                solver: scheduler.solver_stats(),
                sched_wall_secs: round_wall,
            };
            s.emit(&t)
                .map_err(|e| format!("telemetry write failed: {e}"))?;
        }

        busy_total += rec.busy_gpu_secs;
        alloc_log.push((rec.start, rec.alloc_gpu_secs));
        if record_timeline {
            timeline.push(rec);
        }
        prev_plan = plan;
        round += 1;
        now += cfg.slot_secs;
    }

    let ttd = if last_finish > 0.0 { last_finish } else { now };
    // CRU denominator: allocated node-slots, with the final slot clamped
    // at the batch finish (a node is not "allocated" past the experiment).
    for &(start, alloc_secs) in &alloc_log {
        let span = (ttd - start).clamp(0.0, cfg.slot_secs);
        alloc_total += alloc_secs / cfg.slot_secs * span;
    }
    let mut jct = BTreeMap::new();
    let mut finish_times = Vec::new();
    for job in queue.iter() {
        if let (Some(f), Some(c)) = (job.finish_time, job.completion_time()) {
            jct.insert(job.id, c);
            finish_times.push(f);
        }
    }
    finish_times.sort_by(|a, b| a.total_cmp(b));
    let avail_total = integrate_capacity(&avail_log, ttd);
    obs::trace::flush();
    Ok(SimResult {
        scheduler: scheduler.name().to_string(),
        ttd,
        jct,
        finish_times,
        gru: if ttd > 0.0 {
            busy_total / (nominal_gpus * ttd)
        } else {
            0.0
        },
        cru: if alloc_total > 0.0 {
            busy_total / alloc_total
        } else {
            0.0
        },
        anu: if avail_total > 0.0 {
            busy_total / avail_total
        } else {
            0.0
        },
        rounds: round,
        preemptions,
        events_applied: view.events_applied(),
        sched_wall_secs: sched_wall,
        sched_wall_per_round: if round > 0 {
            sched_wall / round as f64
        } else {
            0.0
        },
        timeline,
        change_fraction: if round > 0 {
            changed_rounds as f64 / round as f64
        } else {
            0.0
        },
        solver: scheduler.solver_stats(),
    })
}

fn plan_differs(a: &RoundPlan, b: &RoundPlan) -> bool {
    a.allocations != b.allocations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::GpuType;
    use crate::jobs::job::Job;
    use crate::jobs::model::DlModel;
    use crate::sched;

    fn mk_queue(n: u64, epochs: u64) -> JobQueue {
        let mut q = JobQueue::new();
        for id in 0..n {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, epochs, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            q.admit(j).unwrap();
        }
        q
    }

    #[test]
    fn all_schedulers_complete_small_workload() {
        for name in sched::SCHEDULER_NAMES {
            let cluster = ClusterSpec::motivational();
            let mut queue = mk_queue(4, 2);
            let mut s = sched::by_name(name).unwrap();
            let res = run(&mut queue, s.as_mut(), &cluster,
                          &SimConfig::default(), true);
            assert!(queue.all_complete(), "{name} left work");
            assert!(res.ttd > 0.0);
            assert_eq!(res.jct.len(), 4, "{name}");
            assert!(res.gru > 0.0 && res.gru <= 1.0, "{name} gru={}", res.gru);
        }
    }

    #[test]
    fn restart_overhead_slows_completion() {
        let cluster = ClusterSpec::motivational();
        let mk = || mk_queue(1, 50); // ~5000 iters at 120/s on 2xV100
        let cfg_free = SimConfig {
            restart_overhead: 0.0,
            ..Default::default()
        };
        let cfg_cost = SimConfig {
            restart_overhead: 60.0,
            ..Default::default()
        };
        let mut q1 = mk();
        let r1 = run(&mut q1, &mut sched::hadar::Hadar::new(), &cluster,
                     &cfg_free, false);
        let mut q2 = mk();
        let r2 = run(&mut q2, &mut sched::hadar::Hadar::new(), &cluster,
                     &cfg_cost, false);
        assert!(r2.jct[&JobId(0)] >= r1.jct[&JobId(0)]);
    }

    #[test]
    fn arrivals_are_respected() {
        let cluster = ClusterSpec::motivational();
        let mut q = JobQueue::new();
        let mut j = Job::new(0, DlModel::Lstm, 1000.0, 1, 1, 10);
        j.set_throughput(GpuType::V100, 60.0);
        q.admit(j).unwrap();
        let res = run(&mut q, &mut sched::hadar::Hadar::new(), &cluster,
                      &SimConfig::default(), false);
        let job = q.get(JobId(0)).unwrap();
        assert!(job.finish_time.unwrap() >= 1000.0);
        assert!(res.ttd >= 1000.0);
    }

    #[test]
    fn timeline_records_busy_time() {
        let cluster = ClusterSpec::motivational();
        let mut q = mk_queue(2, 3);
        let res = run(&mut q, &mut sched::hadar::Hadar::new(), &cluster,
                      &SimConfig::default(), true);
        assert!(!res.timeline.is_empty());
        for rec in &res.timeline {
            assert!(rec.busy_gpu_secs <= rec.avail_gpu_secs + 1e-9);
        }
    }

    #[test]
    fn static_cluster_has_anu_equal_gru_and_no_preemptions() {
        let cluster = ClusterSpec::motivational();
        let mut q = mk_queue(3, 2);
        let res = run(&mut q, &mut sched::hadar::Hadar::new(), &cluster,
                      &SimConfig::default(), false);
        assert!((res.anu - res.gru).abs() < 1e-12,
                "anu {} vs gru {}", res.anu, res.gru);
        assert_eq!(res.preemptions, 0);
        assert_eq!(res.events_applied, 0);
    }

    use crate::cluster::events::{EventKind, EventTimeline};
    use crate::cluster::gpu::PcieGen;
    use crate::cluster::node::Node;

    /// Two nodes, one GPU type each: node 0 = 2x V100, node 1 = 2x P100.
    fn duo_cluster() -> ClusterSpec {
        ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 2)], PcieGen::Gen3),
                Node::new(1, "p", &[(GpuType::P100, 2)], PcieGen::Gen3),
            ],
        )
    }

    /// 2-GPU gang at 1 iter/s per GPU on either type (rate 2 it/s).
    fn duo_job(id: u64, epochs: u64) -> Job {
        let mut j = Job::new(id, DlModel::Lstm, 0.0, 2, epochs, 100);
        j.set_throughput(GpuType::V100, 1.0);
        j.set_throughput(GpuType::P100, 1.0);
        j
    }

    #[test]
    fn node_drain_preempts_only_jobs_on_that_node_and_charges_once() {
        // YARN-CS pins J0 on the V100 node and J1 on the P100 node; the
        // V100 node drains at the first round boundary. Exactly J0 is
        // preempted; it pays the 10 s restart exactly once when re-placed.
        let cluster = duo_cluster();
        let mut q = JobQueue::new();
        q.admit(duo_job(0, 50)).unwrap(); // 5000 iters
        q.admit(duo_job(1, 14)).unwrap(); // 1400 iters
        let mut events = EventTimeline::empty();
        events.push(360.0, EventKind::Leave { node: 0 });
        let mut sched = sched::yarn_cs::YarnCs::new();
        let res = run_with_events(&mut q, &mut sched, &cluster, &events,
                                  &SimConfig::default(), true)
            .unwrap();

        assert!(q.all_complete(), "both jobs complete after the drain");
        assert_eq!(res.preemptions, 1, "only the job on the drained node");
        assert_eq!(res.events_applied, 1);
        // J1 never moves off node 1.
        for rec in &res.timeline {
            if let Some(rj) = rec.jobs.get(&JobId(1)) {
                assert_eq!(rj.node, 1, "round {}", rec.round);
            }
        }
        // Round 1: J0 is preempted and cannot be placed (P100 full).
        let r1 = &res.timeline[1];
        assert!(!r1.jobs.contains_key(&JobId(0)));
        assert!(r1.jobs.contains_key(&JobId(1)));
        // Round 2: J0 re-placed, paying the restart overhead once —
        // (360 - 10) s x 2 GPUs x 1 it/s = 700 iterations…
        let r2 = &res.timeline[2];
        assert!((r2.jobs[&JobId(0)].progressed - 700.0).abs() < 1e-6,
                "restart overhead charged on re-placement: {:?}", r2);
        assert_eq!(r2.jobs[&JobId(0)].node, 1);
        // …and round 3 runs the full slot: charged exactly once.
        let r3 = &res.timeline[3];
        assert!((r3.jobs[&JobId(0)].progressed - 720.0).abs() < 1e-6,
                "no second overhead charge: {:?}", r3);
        // Availability-normalised utilisation beats the nominal figure
        // once half the cluster is gone.
        assert!(res.anu > res.gru, "anu {} vs gru {}", res.anu, res.gru);
        assert!(res.anu <= 1.0 + 1e-9);
    }

    #[test]
    fn node_join_expands_capacity_mid_run() {
        let cluster = ClusterSpec::new(
            "solo",
            vec![Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3)],
        );
        let mk = |id: u64| {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, 3, 100);
            j.set_throughput(GpuType::V100, 1.0);
            j.set_throughput(GpuType::P100, 1.0);
            j
        };
        let mut q = JobQueue::new();
        q.admit(mk(0)).unwrap();
        q.admit(mk(1)).unwrap();
        let mut events = EventTimeline::empty();
        events.push(
            360.0,
            EventKind::Join(Node::new(1, "p-new", &[(GpuType::P100, 1)],
                                      PcieGen::Gen3)),
        );
        let mut sched = sched::yarn_cs::YarnCs::new();
        let res = run_with_events(&mut q, &mut sched, &cluster, &events,
                                  &SimConfig::default(), true)
            .unwrap();
        assert!(q.all_complete());
        assert_eq!(res.events_applied, 1);
        assert_eq!(res.preemptions, 0, "joins never preempt");
        assert!((res.timeline[0].avail_gpu_secs - 360.0).abs() < 1e-9);
        assert!((res.timeline[1].avail_gpu_secs - 720.0).abs() < 1e-9);
    }

    #[test]
    fn bad_event_timeline_is_a_clear_error() {
        let cluster = duo_cluster();
        let mut q = JobQueue::new();
        q.admit(duo_job(0, 1)).unwrap();
        let mut events = EventTimeline::empty();
        events.push(10.0, EventKind::Leave { node: 42 });
        let err = run_with_events(&mut q, &mut sched::hadar::Hadar::new(),
                                  &cluster, &events, &SimConfig::default(),
                                  false)
            .unwrap_err();
        assert!(err.contains("not in cluster"), "{err}");
    }

    #[test]
    fn hadar_type_cache_shrinks_as_jobs_complete() {
        // Long trace: 30 jobs trickling in over ~an hour of virtual time.
        // Without the job_completed notification the per-job type-order
        // cache ends the run holding one entry per job ever admitted;
        // with it, every completion is forgotten and the cache drains.
        let cluster = ClusterSpec::sim60();
        let mut q = JobQueue::new();
        for id in 0..30u64 {
            let mut j = Job::new(id, DlModel::Lstm, id as f64 * 120.0, 1,
                                 2, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            q.admit(j).unwrap();
        }
        let mut hadar = crate::sched::hadar::Hadar::new();
        let res = run(&mut q, &mut hadar, &cluster, &SimConfig::default(),
                      false);
        assert!(q.all_complete());
        assert!(res.rounds > 1);
        assert_eq!(hadar.type_cache_len(), 0,
                   "30 jobs admitted, all completed: cache must be empty");
    }

    #[test]
    fn hadar_beats_gavel_when_mixing_is_needed() {
        // One 4-GPU job on the motivational cluster: Gavel can never place
        // it (no single type has 4), Hadar mixes and completes.
        let cluster = ClusterSpec::motivational();
        let mk = || {
            let mut q = JobQueue::new();
            let mut j = Job::new(0, DlModel::ResNet18, 0.0, 4, 5, 100);
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::P100, 25.0);
            j.set_throughput(GpuType::K80, 8.0);
            q.admit(j).unwrap();
            q
        };
        let cfg = SimConfig {
            max_rounds: 200,
            ..Default::default()
        };
        let mut qh = mk();
        run(&mut qh, &mut sched::hadar::Hadar::new(), &cluster, &cfg, false);
        assert!(qh.all_complete(), "hadar completes the mixed-type job");
        let mut qg = mk();
        run(&mut qg, &mut sched::gavel::Gavel::new(), &cluster, &cfg, false);
        assert!(!qg.all_complete(), "gavel cannot place the 4-GPU gang");
    }
}
