//! Discrete-time, round-based trace-driven simulator (paper §IV).
//!
//! Each round of length `L` the engine asks the scheduler for a plan,
//! charges the 10-second checkpoint-restart overhead to every job whose
//! allocation changed (paper §IV: "The overhead of each checkpoint-restart
//! is simulated by enforcing a 10-second delay when a job receives a new
//! allocation"), advances progress with the bottleneck-throughput rule
//! (Eq. 1b — all workers run at the slowest device's pace), and records
//! utilisation/time metrics.

use crate::cluster::spec::ClusterSpec;
use crate::jobs::job::{JobId, JobStatus};
use crate::jobs::queue::JobQueue;
use crate::sched::alloc::RoundPlan;
use crate::sched::{RoundCtx, Scheduler};
use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Round/slot length `L` in seconds (paper default: 6 minutes).
    pub slot_secs: f64,
    /// Checkpoint-restart delay charged on allocation change (10 s).
    pub restart_overhead: f64,
    /// Safety cap on rounds.
    pub max_rounds: u64,
    /// Horizon `T` handed to price-based schedulers.
    pub horizon: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            slot_secs: 360.0,
            restart_overhead: 10.0,
            max_rounds: 20_000,
            horizon: 14.0 * 24.0 * 3600.0,
        }
    }
}

/// Per-job, per-round accounting (drives both figure timelines and the
/// real-training replay in `exec`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundJob {
    pub gpus: usize,
    /// Remaining iterations at round start.
    pub remaining_before: f64,
    /// Iterations progressed this round.
    pub progressed: f64,
    /// First node hosting the job this round (single-GPU-node clusters).
    pub node: usize,
}

/// One round's record, enough to redraw Fig. 1 / Fig. 6 style timelines.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u64,
    pub start: f64,
    pub jobs: BTreeMap<JobId, RoundJob>,
    /// Busy GPU-seconds this round (excludes restart overhead).
    pub busy_gpu_secs: f64,
    /// GPU-seconds *allocated* this round (scheduled jobs x slot).
    pub alloc_gpu_secs: f64,
    /// Total GPU-seconds available this round.
    pub avail_gpu_secs: f64,
}

/// Simulation outcome + metrics inputs.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub scheduler: String,
    /// Total time duration (makespan), seconds.
    pub ttd: f64,
    /// Per-job completion times `f_j - a_j` (seconds).
    pub jct: BTreeMap<JobId, f64>,
    /// Completion instants `f_j` (for the Fig. 4 CDF).
    pub finish_times: Vec<f64>,
    /// Aggregate GPU resource utilisation in [0, 1]: busy time over
    /// total capacity x makespan (Fig. 3's GRU).
    pub gru: f64,
    /// Cluster resource utilisation in [0, 1]: busy time over *allocated*
    /// node-slots (the paper's §VI CRU — idle/unallocated nodes don't
    /// enter the denominator, wasted slot tails and restarts do).
    pub cru: f64,
    pub rounds: u64,
    /// Wall-clock seconds spent inside `Scheduler::schedule`.
    pub sched_wall_secs: f64,
    /// Mean wall-clock per scheduling round (Fig. 5's y-axis).
    pub sched_wall_per_round: f64,
    pub timeline: Vec<RoundRecord>,
    /// Fraction of rounds whose plan differed from the previous round's.
    pub change_fraction: f64,
}

/// Run one scheduler over one workload. `record_timeline` keeps per-round
/// records (disable for the 2048-job scalability sweeps).
pub fn run(queue: &mut JobQueue, scheduler: &mut dyn Scheduler,
           cluster: &ClusterSpec, cfg: &SimConfig, record_timeline: bool)
           -> SimResult {
    let total_gpus = cluster.total_gpus() as f64;
    let mut now = 0.0;
    let mut round = 0u64;
    let mut busy_total = 0.0;
    let mut alloc_total = 0.0;
    // (round start, allocated gpu-secs) — kept even without timelines.
    let mut alloc_log: Vec<(f64, f64)> = Vec::new();
    let mut last_finish: f64 = 0.0;
    let mut prev_plan = RoundPlan::new();
    let mut sched_wall = 0.0;
    let mut timeline = Vec::new();
    let mut changed_rounds = 0u64;

    while !queue.all_complete() && round < cfg.max_rounds {
        let active = queue.active_at(now);
        if active.is_empty() {
            // Idle until the next arrival.
            match queue.next_arrival_after(now) {
                Some(t) => {
                    now = t;
                    continue;
                }
                None => break,
            }
        }
        let plan = {
            let ctx = RoundCtx {
                round,
                now,
                slot_secs: cfg.slot_secs,
                horizon: cfg.horizon,
                queue,
                active: &active,
                cluster,
            };
            let t0 = Instant::now();
            let plan = scheduler.schedule(&ctx);
            sched_wall += t0.elapsed().as_secs_f64();
            plan
        };
        if plan_differs(&plan, &prev_plan) {
            changed_rounds += 1;
        }

        let mut rec = RoundRecord {
            round,
            start: now,
            jobs: BTreeMap::new(),
            busy_gpu_secs: 0.0,
            alloc_gpu_secs: 0.0,
            avail_gpu_secs: total_gpus * cfg.slot_secs,
        };

        for (&id, alloc) in &plan.allocations {
            let job = queue.get_mut(id).expect("plan references live job");
            if job.is_complete() {
                continue;
            }
            let remaining_before = job.remaining_iters();
            // Restart overhead if this job's allocation changed.
            let changed = prev_plan.get(id) != Some(alloc);
            let overhead = if changed { cfg.restart_overhead } else { 0.0 };
            let eff = (cfg.slot_secs - overhead).max(0.0);
            // Bottleneck rule (1b): slowest used type gates every worker.
            let x_min = alloc
                .gpu_types()
                .iter()
                .map(|&g| job.throughput_on(g))
                .fold(f64::INFINITY, f64::min);
            if !x_min.is_finite() || x_min <= 0.0 {
                continue;
            }
            let rate = alloc.total_gpus() as f64 * x_min;
            let need = job.remaining_iters();
            let used_secs = (need / rate).min(eff);
            job.progress += rate * used_secs;
            job.status = JobStatus::Running;
            rec.busy_gpu_secs += alloc.total_gpus() as f64 * used_secs;
            rec.alloc_gpu_secs += alloc.total_gpus() as f64 * cfg.slot_secs;
            if record_timeline {
                rec.jobs.insert(
                    id,
                    RoundJob {
                        gpus: alloc.total_gpus(),
                        remaining_before,
                        progressed: rate * used_secs,
                        node: alloc.nodes().first().copied().unwrap_or(0),
                    },
                );
            }
            if job.is_complete() {
                let f = now + overhead + used_secs;
                job.finish_time = Some(f);
                job.status = JobStatus::Completed;
                last_finish = last_finish.max(f);
            }
        }

        busy_total += rec.busy_gpu_secs;
        alloc_log.push((rec.start, rec.alloc_gpu_secs));
        if record_timeline {
            timeline.push(rec);
        }
        prev_plan = plan;
        round += 1;
        now += cfg.slot_secs;
    }

    let ttd = if last_finish > 0.0 { last_finish } else { now };
    // CRU denominator: allocated node-slots, with the final slot clamped
    // at the batch finish (a node is not "allocated" past the experiment).
    for &(start, alloc_secs) in &alloc_log {
        let span = (ttd - start).clamp(0.0, cfg.slot_secs);
        alloc_total += alloc_secs / cfg.slot_secs * span;
    }
    let mut jct = BTreeMap::new();
    let mut finish_times = Vec::new();
    for job in queue.iter() {
        if let (Some(f), Some(c)) = (job.finish_time, job.completion_time()) {
            jct.insert(job.id, c);
            finish_times.push(f);
        }
    }
    finish_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    SimResult {
        scheduler: scheduler.name().to_string(),
        ttd,
        jct,
        finish_times,
        gru: if ttd > 0.0 {
            busy_total / (total_gpus * ttd)
        } else {
            0.0
        },
        cru: if alloc_total > 0.0 {
            busy_total / alloc_total
        } else {
            0.0
        },
        rounds: round,
        sched_wall_secs: sched_wall,
        sched_wall_per_round: if round > 0 {
            sched_wall / round as f64
        } else {
            0.0
        },
        timeline,
        change_fraction: if round > 0 {
            changed_rounds as f64 / round as f64
        } else {
            0.0
        },
    }
}

fn plan_differs(a: &RoundPlan, b: &RoundPlan) -> bool {
    a.allocations != b.allocations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::GpuType;
    use crate::jobs::job::Job;
    use crate::jobs::model::DlModel;
    use crate::sched;

    fn mk_queue(n: u64, epochs: u64) -> JobQueue {
        let mut q = JobQueue::new();
        for id in 0..n {
            let mut j = Job::new(id, DlModel::Lstm, 0.0, 1, epochs, 100);
            j.set_throughput(GpuType::V100, 60.0);
            j.set_throughput(GpuType::P100, 40.0);
            j.set_throughput(GpuType::K80, 15.0);
            q.admit(j);
        }
        q
    }

    #[test]
    fn all_schedulers_complete_small_workload() {
        for name in sched::SCHEDULER_NAMES {
            let cluster = ClusterSpec::motivational();
            let mut queue = mk_queue(4, 2);
            let mut s = sched::by_name(name).unwrap();
            let res = run(&mut queue, s.as_mut(), &cluster,
                          &SimConfig::default(), true);
            assert!(queue.all_complete(), "{name} left work");
            assert!(res.ttd > 0.0);
            assert_eq!(res.jct.len(), 4, "{name}");
            assert!(res.gru > 0.0 && res.gru <= 1.0, "{name} gru={}", res.gru);
        }
    }

    #[test]
    fn restart_overhead_slows_completion() {
        let cluster = ClusterSpec::motivational();
        let mk = || mk_queue(1, 50); // ~5000 iters at 120/s on 2xV100
        let cfg_free = SimConfig {
            restart_overhead: 0.0,
            ..Default::default()
        };
        let cfg_cost = SimConfig {
            restart_overhead: 60.0,
            ..Default::default()
        };
        let mut q1 = mk();
        let r1 = run(&mut q1, &mut sched::hadar::Hadar::new(), &cluster,
                     &cfg_free, false);
        let mut q2 = mk();
        let r2 = run(&mut q2, &mut sched::hadar::Hadar::new(), &cluster,
                     &cfg_cost, false);
        assert!(r2.jct[&JobId(0)] >= r1.jct[&JobId(0)]);
    }

    #[test]
    fn arrivals_are_respected() {
        let cluster = ClusterSpec::motivational();
        let mut q = JobQueue::new();
        let mut j = Job::new(0, DlModel::Lstm, 1000.0, 1, 1, 10);
        j.set_throughput(GpuType::V100, 60.0);
        q.admit(j);
        let res = run(&mut q, &mut sched::hadar::Hadar::new(), &cluster,
                      &SimConfig::default(), false);
        let job = q.get(JobId(0)).unwrap();
        assert!(job.finish_time.unwrap() >= 1000.0);
        assert!(res.ttd >= 1000.0);
    }

    #[test]
    fn timeline_records_busy_time() {
        let cluster = ClusterSpec::motivational();
        let mut q = mk_queue(2, 3);
        let res = run(&mut q, &mut sched::hadar::Hadar::new(), &cluster,
                      &SimConfig::default(), true);
        assert!(!res.timeline.is_empty());
        for rec in &res.timeline {
            assert!(rec.busy_gpu_secs <= rec.avail_gpu_secs + 1e-9);
        }
    }

    #[test]
    fn hadar_beats_gavel_when_mixing_is_needed() {
        // One 4-GPU job on the motivational cluster: Gavel can never place
        // it (no single type has 4), Hadar mixes and completes.
        let cluster = ClusterSpec::motivational();
        let mk = || {
            let mut q = JobQueue::new();
            let mut j = Job::new(0, DlModel::ResNet18, 0.0, 4, 5, 100);
            j.set_throughput(GpuType::V100, 40.0);
            j.set_throughput(GpuType::P100, 25.0);
            j.set_throughput(GpuType::K80, 8.0);
            q.admit(j);
            q
        };
        let cfg = SimConfig {
            max_rounds: 200,
            ..Default::default()
        };
        let mut qh = mk();
        run(&mut qh, &mut sched::hadar::Hadar::new(), &cluster, &cfg, false);
        assert!(qh.all_complete(), "hadar completes the mixed-type job");
        let mut qg = mk();
        run(&mut qg, &mut sched::gavel::Gavel::new(), &cluster, &cfg, false);
        assert!(!qg.all_complete(), "gavel cannot place the 4-GPU gang");
    }
}
