//! Metrics derived from simulation results: GRU/CRU, TTD, JCT summaries,
//! and the completion CDF of Fig. 4.

use crate::sim::engine::SimResult;
use crate::util::stats;

/// Summary of one run in the paper's reporting vocabulary.
#[derive(Clone, Debug)]
pub struct Metrics {
    /// Scheduler that produced the run.
    pub scheduler: String,
    /// GPU resource utilisation (busy / nominal capacity x makespan,
    /// Fig. 3).
    pub gru: f64,
    /// Cluster resource utilisation (busy / allocated slots, §VI).
    pub cru: f64,
    /// Availability-normalised utilisation (busy / *available*
    /// GPU-seconds) — equals `gru` on a static cluster; the honest figure
    /// under node churn.
    pub anu: f64,
    /// Total time duration (makespan), seconds.
    pub ttd: f64,
    /// Mean job completion time (seconds).
    pub jct_mean: f64,
    /// Fastest job completion time (seconds).
    pub jct_min: f64,
    /// Slowest job completion time (seconds).
    pub jct_max: f64,
    /// Time by which 50% of jobs completed (Fig. 4's gray line).
    pub median_completion: f64,
    /// Jobs that finished.
    pub completed: usize,
    /// Rounds executed.
    pub rounds: u64,
    /// Drain/shrink preemptions from cluster events.
    pub preemptions: u64,
    /// Mean scheduling wall-clock per round (seconds).
    pub sched_wall_per_round: f64,
    /// Fraction of rounds whose plan changed.
    pub change_fraction: f64,
}

impl Metrics {
    /// Summarise one simulation result.
    pub fn from_result(res: &SimResult) -> Self {
        let jcts: Vec<f64> = res.jct.values().copied().collect();
        Metrics {
            scheduler: res.scheduler.clone(),
            gru: res.gru,
            cru: res.cru,
            anu: res.anu,
            ttd: res.ttd,
            jct_mean: stats::mean(&jcts),
            jct_min: if jcts.is_empty() { 0.0 } else { stats::min(&jcts) },
            jct_max: if jcts.is_empty() { 0.0 } else { stats::max(&jcts) },
            median_completion: stats::percentile(&res.finish_times, 50.0),
            completed: res.jct.len(),
            rounds: res.rounds,
            preemptions: res.preemptions,
            sched_wall_per_round: res.sched_wall_per_round,
            change_fraction: res.change_fraction,
        }
    }
}

/// Fig. 4: cumulative fraction of completed jobs at each point in `hours`.
pub fn completion_cdf(res: &SimResult, points_hours: &[f64]) -> Vec<(f64, f64)> {
    let secs: Vec<f64> = points_hours.iter().map(|h| h * 3600.0).collect();
    let total = res.jct.len().max(1) as f64;
    let fracs = stats::ecdf_at(&res.finish_times, &secs);
    points_hours
        .iter()
        .zip(fracs)
        .map(|(&h, f)| (h, f * res.finish_times.len() as f64 / total))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::job::JobId;
    use std::collections::BTreeMap;

    fn fake_result() -> SimResult {
        let mut jct = BTreeMap::new();
        jct.insert(JobId(0), 100.0);
        jct.insert(JobId(1), 300.0);
        SimResult {
            scheduler: "test".into(),
            ttd: 400.0,
            jct,
            finish_times: vec![100.0, 400.0],
            gru: 0.8,
            cru: 0.9,
            anu: 0.85,
            rounds: 4,
            preemptions: 2,
            events_applied: 3,
            sched_wall_secs: 0.04,
            sched_wall_per_round: 0.01,
            timeline: vec![],
            change_fraction: 0.25,
            solver: None,
        }
    }

    #[test]
    fn metrics_summary() {
        let m = Metrics::from_result(&fake_result());
        assert_eq!(m.jct_mean, 200.0);
        assert_eq!(m.jct_min, 100.0);
        assert_eq!(m.jct_max, 300.0);
        assert_eq!(m.completed, 2);
        assert_eq!(m.anu, 0.85);
        assert_eq!(m.preemptions, 2);
        assert!(m.median_completion >= 100.0);
    }

    #[test]
    fn cdf_reaches_one() {
        let res = fake_result();
        let cdf = completion_cdf(&res, &[0.0, 0.05, 0.2]);
        assert_eq!(cdf[0].1, 0.0);
        assert!((cdf[2].1 - 1.0).abs() < 1e-9); // 720s > all finishes
    }
}
