//! HadarE's round engine over *forked* jobs (paper §V), shared between the
//! pure simulation (CRU/TTD/JCT figures) and the PJRT-backed emulation
//! (which layers real training on the same schedule via `exec`).
//!
//! Per round: the HadarE planner assigns whole nodes to copies — every
//! GPU of the node, per the node spec; the Job Tracker divides each
//! parent's remaining steps across its scheduled copies in proportion to
//! **gang** throughput ([`crate::sched::hadare::gang_throughput`]:
//! bottleneck rule + sub-linear intra-node scaling, §V-B); nodes burn
//! their share (bounded by gang slot capacity and the restart overhead);
//! the tracker aggregates completed steps. A parent finishes the moment
//! its aggregated steps reach the target — possibly mid-slot ("early
//! finish", §V-A).
//!
//! Accounting is **per GPU**: a busy 4-GPU gang contributes 4 GPU-seconds
//! per second to `busy_gpu_secs` and 4 × `slot_secs` to `alloc_gpu_secs`,
//! so GRU/CRU/ANU measure the actual 60-GPU `sim60` cluster rather than
//! its 15 nodes.
//!
//! Restart overhead is charged when a node switches *parents* (a model
//! load); a node that idles a round keeps its loaded model, so resuming
//! the same parent later is free.

use crate::cluster::events::{ClusterTimeline, EventTimeline};
use crate::cluster::spec::ClusterSpec;
use crate::forking::forker::{fork, ForkIds};
use crate::forking::tracker::JobTracker;
use crate::jobs::job::{Job, JobId, JobStatus};
use crate::jobs::queue::JobQueue;
use crate::sched::hadare::{gang_throughput, HadarE};
use crate::sched::RoundCtx;
use crate::sim::engine::{
    integrate_capacity, RoundJob, RoundRecord, SimConfig, SimResult,
};
use std::collections::BTreeMap;
use std::time::Instant;

/// What one copy did in one round — the hook `exec` uses to run real
/// training steps for the same schedule.
#[derive(Clone, Debug)]
pub struct CopyWork {
    /// Round number (0-based).
    pub round: u64,
    /// Copy job id (see [`crate::forking::forker::ForkIds`]).
    pub copy: JobId,
    /// The copy's parent job.
    pub parent: JobId,
    /// Node that hosted the copy this round.
    pub node: usize,
    /// GPUs in the node's gang (the copy occupies the whole node).
    pub gpus: usize,
    /// Steps this node's gang completed this round.
    pub steps: f64,
    /// Seconds of the slot the node's gang was busy (per node, not per
    /// GPU — multiply by [`CopyWork::gpus`] for GPU-seconds).
    pub busy_secs: f64,
}

/// HadarE simulation outcome: the usual metrics plus the per-round copy
/// work log.
pub struct HadarESimResult {
    /// The scheduling metrics (same shape as the generic engine's).
    pub sim: SimResult,
    /// Per-(round, copy, node) work records.
    pub work_log: Vec<CopyWork>,
}

/// Run HadarE over `parents` on a *static* `cluster`. `copies` defaults
/// to the node count (Theorem 3's optimum) when `None`.
pub fn run(parents: &[Job], cluster: &ClusterSpec, cfg: &SimConfig,
           copies: Option<u64>) -> HadarESimResult {
    run_with_events(parents, cluster, &EventTimeline::empty(), cfg, copies)
        .expect("the empty event timeline always resolves")
}

/// Run HadarE under a cluster event timeline: due events apply at round
/// boundaries, node drains unbind the copies running there (counted as
/// preemptions; the node's next model load pays the restart overhead),
/// and the planner sees the current node inventory every round. The copy
/// budget stays at the *initial* node count unless `copies` is given —
/// under heavy joins, pass a larger budget to keep every node busy.
pub fn run_with_events(parents: &[Job], cluster: &ClusterSpec,
                       events: &EventTimeline, cfg: &SimConfig,
                       copies: Option<u64>)
                       -> Result<HadarESimResult, String> {
    let mut view = ClusterTimeline::new(cluster, events)?;
    let n_nodes = cluster.nodes.len() as u64;
    let copies = copies.unwrap_or(n_nodes).max(1);
    let ids = ForkIds {
        max_job_count: parents
            .iter()
            .map(|j| j.id.0 + 1)
            .max()
            .unwrap_or(1)
            .max(64),
    };
    let mut tracker = JobTracker::new(ids);
    let mut queue = JobQueue::new();
    for p in parents {
        let copy_jobs = fork(p, copies, ids);
        tracker.register(
            p.id,
            p.total_iters(),
            &copy_jobs.iter().map(|c| c.id).collect::<Vec<_>>(),
        );
        queue.admit(p.clone());
    }

    let mut planner = HadarE::new(copies);
    let nominal_gpus = cluster.total_gpus() as f64;
    let mut now = 0.0;
    let mut round = 0u64;
    let mut busy_total = 0.0;
    let mut alloc_total = 0.0;
    // Capacity step function (segment start, available GPUs) for ANU.
    let mut avail_log: Vec<(f64, f64)> = vec![(0.0, nominal_gpus)];
    let mut preemptions = 0u64;
    let mut last_finish: f64 = 0.0;
    let mut sched_wall = 0.0;
    let mut timeline = Vec::new();
    let mut work_log = Vec::new();
    // Per-parent first-seen finish time.
    let mut finish: BTreeMap<JobId, f64> = BTreeMap::new();
    // Copy most recently bound to each node (restart-overhead
    // bookkeeping). Entries persist while a node idles — the model stays
    // loaded — and are dropped only when the node drains.
    let mut prev_binding: BTreeMap<usize, JobId> = BTreeMap::new();

    while !tracker.all_complete() && round < cfg.max_rounds {
        // Apply cluster events due by this round boundary; drained nodes
        // lose their copy bindings (the tracker keeps the parents'
        // aggregated steps — HadarE is naturally churn-tolerant).
        let change = view.advance_to(now);
        if change.capacity_changed {
            avail_log.push((now, view.cluster().total_gpus() as f64));
        }
        if !change.affected.is_empty() {
            let drained: Vec<usize> = prev_binding
                .keys()
                .copied()
                .filter(|h| change.affected.contains(h))
                .collect();
            for h in drained {
                if let Some(copy) = prev_binding.remove(&h) {
                    // Bindings of already-finished parents are stale —
                    // dropping them disturbs no running work.
                    if !tracker.is_parent_complete(copy) {
                        preemptions += 1;
                    }
                }
            }
        }

        let active = queue.active_at(now);
        let plan = {
            let ctx = RoundCtx {
                round,
                now,
                slot_secs: cfg.slot_secs,
                horizon: cfg.horizon,
                queue: &queue,
                active: &active,
                cluster: view.cluster(),
            };
            let t0 = Instant::now();
            let plan = planner.plan_round(&ctx, &tracker);
            sched_wall += t0.elapsed().as_secs_f64();
            plan
        };

        // Group scheduled copies by parent, collect
        // (copy, node, gang size, gang throughput). A copy's allocation
        // spans exactly one node (possibly several pools of it).
        let mut per_parent: BTreeMap<JobId, Vec<(JobId, usize, usize, f64)>> =
            BTreeMap::new();
        for (&copy, alloc) in &plan.allocations {
            let parent = tracker.resolve(copy);
            let job = queue.get(parent).expect("parent job");
            let node_id = alloc
                .nodes()
                .first()
                .copied()
                .expect("plan allocations are non-empty");
            let node = view
                .cluster()
                .node(node_id)
                .expect("planned node is in the current cluster");
            per_parent.entry(parent).or_default().push((
                copy,
                node_id,
                alloc.total_gpus(),
                gang_throughput(job, node, &planner.gang),
            ));
        }

        let mut rec = RoundRecord {
            round,
            start: now,
            jobs: BTreeMap::new(),
            busy_gpu_secs: 0.0,
            alloc_gpu_secs: 0.0,
            avail_gpu_secs: view.cluster().total_gpus() as f64
                * cfg.slot_secs,
        };
        for (parent, assigned) in &per_parent {
            let throughputs: Vec<f64> =
                assigned.iter().map(|&(_, _, _, x)| x).collect();
            let shares =
                tracker.divide_steps(*parent, &throughputs, cfg.slot_secs);
            let remaining_before =
                tracker.parent(*parent).map(|p| p.remaining()).unwrap_or(0.0);
            rec.jobs.insert(
                *parent,
                RoundJob {
                    gpus: assigned.iter().map(|&(_, _, g, _)| g).sum(),
                    remaining_before,
                    progressed: 0.0, // filled below as copies report
                    node: assigned
                        .first()
                        .map(|&(_, n, _, _)| n)
                        .unwrap_or(0),
                },
            );
            for (&(copy, node, gpus, x), &share) in
                assigned.iter().zip(shares.iter())
            {
                // Restart overhead when the node switches *parents* — a
                // model load. Which copy id carries the parent is
                // irrelevant, and a node that idled keeps its model, so
                // resuming the same parent later is free.
                let switched = prev_binding
                    .get(&node)
                    .map(|c| tracker.resolve(*c))
                    != Some(*parent);
                let overhead =
                    if switched { cfg.restart_overhead } else { 0.0 };
                let eff = (cfg.slot_secs - overhead).max(0.0);
                let steps = share.min(x * eff);
                let busy = if x > 0.0 { steps / x } else { 0.0 };
                tracker.report_steps(copy, steps);
                rec.busy_gpu_secs += busy * gpus as f64;
                rec.alloc_gpu_secs += cfg.slot_secs * gpus as f64;
                if let Some(rj) = rec.jobs.get_mut(parent) {
                    rj.progressed += steps;
                }
                work_log.push(CopyWork {
                    round,
                    copy,
                    parent: *parent,
                    node,
                    gpus,
                    steps,
                    busy_secs: busy,
                });
                // Idle nodes keep their previous binding (model stays
                // loaded); only nodes used this round rebind.
                prev_binding.insert(node, copy);
                // Parent finishing mid-slot: early finish. Notify the
                // planner (same completion protocol as the generic
                // engine's [`crate::sched::Scheduler::job_completed`]) so
                // any per-parent planner state is dropped exactly once.
                if tracker.is_parent_complete(*parent)
                    && !finish.contains_key(parent)
                {
                    let f = now + overhead + busy;
                    finish.insert(*parent, f);
                    last_finish = last_finish.max(f);
                    planner.job_completed(*parent);
                }
            }
        }

        busy_total += rec.busy_gpu_secs;
        timeline.push(rec);
        round += 1;
        now += cfg.slot_secs;
    }

    // Mark queue state + collect metrics.
    let mut jct = BTreeMap::new();
    let mut finish_times = Vec::new();
    for job in queue.iter_mut() {
        if let Some(&f) = finish.get(&job.id) {
            job.finish_time = Some(f);
            job.status = JobStatus::Completed;
            job.progress = job.total_iters();
            jct.insert(job.id, f - job.arrival);
            finish_times.push(f);
        }
    }
    finish_times.sort_by(|a, b| a.total_cmp(b));
    let ttd = if last_finish > 0.0 { last_finish } else { now };
    // CRU denominator: allocated node-slots, with the final slot clamped
    // at the batch finish (a node is not "allocated" past the experiment).
    for rec in &timeline {
        let span = (ttd - rec.start).clamp(0.0, cfg.slot_secs);
        alloc_total += rec.alloc_gpu_secs / cfg.slot_secs * span;
    }
    let avail_total = integrate_capacity(&avail_log, ttd);
    Ok(HadarESimResult {
        sim: SimResult {
            scheduler: "hadare".to_string(),
            ttd,
            jct,
            finish_times,
            gru: if ttd > 0.0 {
                busy_total / (nominal_gpus * ttd)
            } else {
                0.0
            },
            cru: if alloc_total > 0.0 {
                busy_total / alloc_total
            } else {
                0.0
            },
            anu: if avail_total > 0.0 {
                busy_total / avail_total
            } else {
                0.0
            },
            rounds: round,
            preemptions,
            events_applied: view.events_applied(),
            sched_wall_secs: sched_wall,
            sched_wall_per_round: if round > 0 {
                sched_wall / round as f64
            } else {
                0.0
            },
            timeline,
            change_fraction: 0.0,
        },
        work_log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::gpu::GpuType;
    use crate::jobs::model::DlModel;
    use crate::jobs::throughput;
    use crate::trace::workload::{cluster_gpu_pcie, physical_jobs};

    fn cfg() -> SimConfig {
        SimConfig {
            slot_secs: 90.0,
            restart_overhead: 10.0,
            max_rounds: 5000,
            horizon: 1e7,
        }
    }

    #[test]
    fn completes_m5_mix_on_testbed() {
        let cluster = ClusterSpec::testbed5();
        let jobs = physical_jobs("M-5", &cluster, 1.0).unwrap();
        let res = run(&jobs, &cluster, &cfg(), None);
        assert_eq!(res.sim.jct.len(), 5, "all five parents complete");
        assert!(res.sim.gru > 0.5, "gru={}", res.sim.gru);
    }

    #[test]
    fn single_job_uses_all_nodes_and_beats_single_node() {
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut j = Job::new(0, DlModel::MiMa, 0.0, 1, 30, 100);
        j.throughput = throughput::throughput_row(DlModel::MiMa, &pairs);
        let res5 = run(std::slice::from_ref(&j), &cluster, &cfg(), None);
        let res1 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(1));
        assert!(res5.sim.ttd < res1.sim.ttd,
                "forking speeds up: {} vs {}", res5.sim.ttd, res1.sim.ttd);
        // First round uses all five nodes.
        let first_round_nodes: std::collections::BTreeSet<usize> = res5
            .work_log
            .iter()
            .filter(|w| w.round == 0)
            .map(|w| w.node)
            .collect();
        assert_eq!(first_round_nodes.len(), 5);
    }

    #[test]
    fn more_copies_never_hurt_cru_theorem3() {
        // Theorem 3: CRU_1 < CRU_x < CRU_n = CRU_{n+j}.
        let cluster = ClusterSpec::testbed5();
        let pairs = cluster_gpu_pcie(&cluster);
        let mut j = Job::new(0, DlModel::Transformer, 0.0, 1, 40, 100);
        j.throughput =
            throughput::throughput_row(DlModel::Transformer, &pairs);
        let g1 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(1)).sim.gru;
        let g3 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(3)).sim.gru;
        let g5 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(5)).sim.gru;
        let g7 = run(std::slice::from_ref(&j), &cluster, &cfg(), Some(7)).sim.gru;
        assert!(g1 < g3, "{g1} !< {g3}");
        assert!(g3 < g5 + 1e-9, "{g3} !< {g5}");
        assert!((g5 - g7).abs() < 0.05, "n vs n+j: {g5} vs {g7}");
    }

    #[test]
    fn maintenance_window_preempts_bound_copies_and_completes() {
        use crate::cluster::events::{EventKind, EventTimeline};
        let cluster = ClusterSpec::testbed5();
        // 3x the paper-scale epochs: enough work that the run is still
        // going when the node rejoins at t=270 (round 3).
        let jobs = physical_jobs("M-3", &cluster, 3.0).unwrap();
        let mut events = EventTimeline::empty();
        // Drain the fastest node for two slots starting at round 1.
        events.push(90.0, EventKind::Maintenance { node: 3, duration: 180.0 });
        let res =
            run_with_events(&jobs, &cluster, &events, &cfg(), None).unwrap();
        assert_eq!(res.sim.jct.len(), 3, "all parents complete despite churn");
        // HadarE keeps every node busy, so the drained node had a copy.
        assert!(res.sim.preemptions >= 1);
        // leave + rejoin.
        assert_eq!(res.sim.events_applied, 2);
        // No work lands on node 3 while it is away (rounds 1 and 2).
        for w in res.work_log.iter().filter(|w| w.round == 1 || w.round == 2)
        {
            assert_ne!(w.node, 3, "round {} used a drained node", w.round);
        }
        // Capacity only ever shrinks here, so the availability-normalised
        // figure is at least the nominal one.
        assert!(res.sim.anu >= res.sim.gru - 1e-12);
    }

    #[test]
    fn work_log_steps_match_tracker_totals() {
        // Gang throughput must not break §V-B conservation: summed
        // work-log steps equal each parent's total, on the single-GPU
        // testbed and the multi-GPU sim60 alike.
        for cluster in [ClusterSpec::testbed5(), ClusterSpec::sim60()] {
            let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
            let res = run(&jobs, &cluster, &cfg(), None);
            let mut per_parent: BTreeMap<JobId, f64> = BTreeMap::new();
            for w in &res.work_log {
                *per_parent.entry(w.parent).or_insert(0.0) += w.steps;
            }
            for j in &jobs {
                let done = per_parent.get(&j.id).copied().unwrap_or(0.0);
                assert!((done - j.total_iters()).abs() < 1e-6,
                        "{}: parent {} steps {} vs {}", cluster.name, j.id,
                        done, j.total_iters());
            }
        }
    }

    #[test]
    fn sim60_round0_allocates_all_60_gpus() {
        // The bugfix, engine-level: with unfinished parents, round 0
        // books 60 GPU-slots (4 per node on all 15 nodes) — the pre-gang
        // engine booked 15 and let 45 GPUs idle against `nominal_gpus =
        // 60` in GRU.
        let cluster = ClusterSpec::sim60();
        let jobs = physical_jobs("M-3", &cluster, 1.0).unwrap();
        let res = run(&jobs, &cluster, &cfg(), None);
        let r0 = &res.sim.timeline[0];
        assert!((r0.alloc_gpu_secs - 60.0 * 90.0).abs() < 1e-6,
                "round 0 allocates every GPU: {}", r0.alloc_gpu_secs);
        let mut gpus_by_node: BTreeMap<usize, usize> = BTreeMap::new();
        for w in res.work_log.iter().filter(|w| w.round == 0) {
            *gpus_by_node.entry(w.node).or_insert(0) += w.gpus;
        }
        assert_eq!(gpus_by_node.len(), 15, "every node hosts a copy");
        assert!(gpus_by_node.values().all(|&g| g == 4),
                "each copy takes the node's whole 4-GPU gang");
        assert_eq!(res.sim.jct.len(), 3, "all parents complete");
    }

    #[test]
    fn theorem3_gru_monotone_on_multi_gpu_cluster() {
        // Theorem 3 re-asserted on sim60: GRU_1 < GRU_x < GRU_n, and a
        // budget beyond the node count changes nothing (one copy per
        // node per parent).
        let cluster = ClusterSpec::sim60();
        let mut j = Job::new(0, DlModel::Transformer, 0.0, 1, 500, 100);
        j.set_throughput(GpuType::V100, 3.0);
        j.set_throughput(GpuType::P100, 2.0);
        j.set_throughput(GpuType::K80, 1.0);
        let gru = |copies: u64| {
            run(std::slice::from_ref(&j), &cluster, &cfg(), Some(copies))
                .sim
                .gru
        };
        let g1 = gru(1);
        let g5 = gru(5);
        let g15 = gru(15);
        let g20 = gru(20);
        assert!(g1 < g5, "{g1} !< {g5}");
        assert!(g5 < g15, "{g5} !< {g15}");
        assert!((g15 - g20).abs() < 1e-12,
                "budget beyond node count is inert: {g15} vs {g20}");
        assert!(g15 > 0.9, "full fan-out keeps ~every GPU busy: {g15}");
    }

    #[test]
    fn idle_node_resuming_same_parent_pays_no_restart() {
        // Regression for the restart-overhead mischarge: bindings were
        // wiped every round, so a node that idled re-paid the overhead
        // for the parent it already had loaded. Two maintenance windows
        // on the fast node force the slow node through a
        // host→idle→resume cycle of the same parent.
        use crate::cluster::events::{EventKind, EventTimeline};
        use crate::cluster::gpu::PcieGen;
        use crate::cluster::node::Node;
        let cluster = ClusterSpec::new(
            "duo",
            vec![
                Node::new(0, "v", &[(GpuType::V100, 1)], PcieGen::Gen3),
                Node::new(1, "k", &[(GpuType::K80, 1)], PcieGen::Gen3),
            ],
        );
        let mut p = Job::new(0, DlModel::Lstm, 0.0, 1, 20, 100); // 2000 it
        p.set_throughput(GpuType::V100, 2.0);
        p.set_throughput(GpuType::K80, 1.0);
        let mut events = EventTimeline::empty();
        // Fast node away rounds 1-2 and again rounds 4-5.
        events.push(90.0, EventKind::Maintenance { node: 0, duration: 180.0 });
        events.push(360.0, EventKind::Maintenance { node: 0, duration: 180.0 });
        let res = run_with_events(std::slice::from_ref(&p), &cluster,
                                  &events, &cfg(), Some(1))
            .unwrap();
        // Round 1: the K80 node loads the model for the first time — it
        // pays the 10 s overhead (80 of 90 s at 1 it/s).
        let w1: Vec<&CopyWork> =
            res.work_log.iter().filter(|w| w.round == 1).collect();
        assert_eq!(w1.len(), 1);
        assert_eq!(w1[0].node, 1);
        assert!((w1[0].steps - 80.0).abs() < 1e-9, "first load pays: {:?}",
                w1[0]);
        // Round 3: back on the V100 node; the K80 node idles but keeps
        // its loaded model.
        assert!(res.work_log.iter().any(|w| w.round == 3 && w.node == 0));
        // Round 4: the K80 node resumes the *same* parent — no second
        // overhead charge (the full 90 steps, not 80).
        let w4: Vec<&CopyWork> =
            res.work_log.iter().filter(|w| w.round == 4).collect();
        assert_eq!(w4.len(), 1);
        assert_eq!(w4[0].node, 1);
        assert!((w4[0].steps - 90.0).abs() < 1e-9,
                "idle node keeps its model loaded: {:?}", w4[0]);
        assert_eq!(res.sim.jct.len(), 1, "the job still completes");
    }
}
